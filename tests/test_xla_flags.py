"""XLA_FLAGS composition: the dry-run's forced device count must MERGE with
the user's exported flags, never clobber them (launch/xla_flags.py —
stdlib-only, importable before jax)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.xla_flags import force_host_device_count, merge_xla_flags


def test_merge_from_empty():
    assert merge_xla_flags(None, "--a=1") == "--a=1"
    assert merge_xla_flags("", "--a=1", "--b") == "--a=1 --b"


def test_merge_preserves_existing_order_and_values():
    got = merge_xla_flags("--x=1 --y=2", "--z=3")
    assert got == "--x=1 --y=2 --z=3"


def test_merge_user_wins_on_name_conflict():
    """A flag already present (by name) keeps the USER's value — the
    requested one is dropped, whatever its value."""
    got = merge_xla_flags("--xla_force_host_platform_device_count=4",
                          "--xla_force_host_platform_device_count=512")
    assert got == "--xla_force_host_platform_device_count=4"
    # valueless and valued spellings are the same flag
    assert merge_xla_flags("--flag", "--flag=2") == "--flag"


def test_merge_is_idempotent():
    once = merge_xla_flags("--a=1", "--b=2")
    assert merge_xla_flags(once, "--b=2") == once


def test_force_host_device_count_mutates_environ():
    env = {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    got = force_host_device_count(env, 8)
    assert env["XLA_FLAGS"] == got
    assert got == ("--xla_cpu_enable_fast_math=false "
                   "--xla_force_host_platform_device_count=8")
    # user already forced a count: theirs survives
    env2 = {"XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    assert force_host_device_count(env2, 512) == \
        "--xla_force_host_platform_device_count=4"
    # unset env var: created from scratch
    env3 = {}
    assert force_host_device_count(env3, 2) == \
        "--xla_force_host_platform_device_count=2"


@pytest.mark.slow
def test_dryrun_import_preserves_user_flags(tmp_path):
    """Importing launch.dryrun used to OVERWRITE XLA_FLAGS wholesale; now a
    pre-set sentinel flag must survive the import, alongside the dry-run's
    forced 512 host devices."""
    script = tmp_path / "probe.py"
    script.write_text(
        "import os\n"
        "import repro.launch.dryrun  # noqa: F401 (import-time env setup)\n"
        "flags = os.environ['XLA_FLAGS'].split()\n"
        "assert '--xla_cpu_enable_fast_math=false' in flags, flags\n"
        "assert '--xla_force_host_platform_device_count=512' in flags, flags\n"
        "print('FLAGS_MERGED_OK')\n")
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_cpu_enable_fast_math=false"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(repo / "src")
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "FLAGS_MERGED_OK" in proc.stdout
