"""Training substrate: loss decreases, grad-accum equivalence, optimizers,
int8 compressed all-reduce, checkpoint resume equivalence, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import get_reduced_config
from repro.data import make_batches
from repro.launch.mesh import compat_make_mesh
from repro.models import NULL_SH, init_params
from repro.training import (TrainHParams, checkpoint, init_train_state,
                            int8_allreduce, make_optimizer,
                            make_optimizer_for, make_train_step)


def _setup(arch="llama3_2_1b", accum=1, optimizer=None):
    cfg = get_reduced_config(arch)
    if optimizer:
        cfg = cfg.replace(optimizer=optimizer)
    hp = TrainHParams(learning_rate=5e-3, grad_accum=accum, remat=True)
    opt = make_optimizer_for(cfg, hp)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, NULL_SH, opt, hp))
    return cfg, state, step


def test_loss_decreases():
    cfg, state, step = _setup()
    batches = make_batches(cfg, batch_size=4, seq_len=64, seed=0)
    losses = []
    batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
    for i in range(8):
        state, metrics = step(state, batch)  # overfit one batch
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_grad_accum_equivalence():
    cfg1, s1, step1 = _setup(accum=1)
    cfg2, s2, step2 = _setup(accum=2)
    batches = make_batches(cfg1, batch_size=4, seq_len=32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
    s1b, m1 = step1(s1, batch)
    s2b, m2 = step2(s2, batch)
    p1 = jax.tree.leaves(s1b["params"])
    p2 = jax.tree.leaves(s2b["params"])
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(p1, p2))
    assert err < 5e-5, f"grad-accum diverges from full batch: {err}"


def test_adafactor_runs():
    cfg, state, step = _setup(optimizer="adafactor")
    batches = make_batches(cfg, batch_size=2, seq_len=32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # factored stats are O(rows+cols), not O(rows*cols)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    n_stats = sum(x.size for x in jax.tree.leaves(state["opt"]))
    assert n_stats < 0.6 * n_params


def test_int8_allreduce_accuracy():
    from jax.sharding import PartitionSpec as P

    devs = jax.devices()
    mesh = compat_make_mesh((len(devs),), ("x",))
    n = mesh.devices.size
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(n, 64, 8), jnp.float32)

    f = compat.shard_map(lambda v: int8_allreduce(v[0], "x"), mesh=mesh,
                         in_specs=P("x"), out_specs=P())
    got = f(x)
    want = np.sum(np.asarray(x), axis=0)
    rel = np.abs(np.asarray(got) - want) / (np.abs(want) + 1e-3)
    assert rel.mean() < 0.05, rel.mean()  # int8 quantisation error bound


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg, state, step = _setup()
    batches = make_batches(cfg, batch_size=2, seq_len=32, seed=2)
    b1 = {k: jnp.asarray(v) for k, v in next(batches).items()}
    b2 = {k: jnp.asarray(v) for k, v in next(batches).items()}
    state1, _ = step(state, b1)
    path = checkpoint.save(str(tmp_path), 1, state1)
    assert os.path.exists(path)
    restored, step_no = checkpoint.restore(str(tmp_path), state1)
    assert step_no == 1
    for a, b in zip(jax.tree.leaves(state1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resume-equivalence: continuing from restored == continuing directly
    s_direct, _ = step(state1, b2)
    s_resumed, _ = step(restored, b2)
    for a, b in zip(jax.tree.leaves(s_direct["params"]),
                    jax.tree.leaves(s_resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-7)


def test_data_pipeline_deterministic():
    cfg = get_reduced_config("llama3_2_1b")
    a = next(make_batches(cfg, 4, 64, seed=3, start_step=5))
    b = next(make_batches(cfg, 4, 64, seed=3, start_step=5))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(make_batches(cfg, 4, 64, seed=4, start_step=5))
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 64)
    assert a["tokens"].min() >= 0
    assert a["tokens"].max() < cfg.vocab_size
