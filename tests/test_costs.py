"""Unit tests for the dry-run cost extraction (HLO collective parsing +
ring-model wire bytes + roofline terms)."""
import numpy as np

from repro.launch.costs import (CostSummary, parse_collectives,
                                roofline_terms)

HLO = """
  %all-reduce.2 = f32[1,512,1024]{2,1,0} all-reduce(%x), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%add
  %all-gather.1 = bf16[16,4096]{1,0} all-gather(%y), channel_id=2, replica_groups=[16,32]<=[512], dimensions={0}
  %reduce-scatter.3 = f32[8,128]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[64,8]<=[512], dimensions={0}
  %all-to-all.9 = bf16[256,64]{1,0} all-to-all(%w), channel_id=4, replica_groups=[2,256]<=[512]
  %collective-permute.5 = f32[4,4]{1,0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
  %all-reduce.7 = f32[10]{0} all-reduce(%u), channel_id=6, replica_groups=[512,1]<=[512], to_apply=%add
"""


def test_parse_collectives_ring_model():
    out = parse_collectives(HLO)
    # group size 1 (last all-reduce) contributes nothing
    assert out["count"] == 5
    ar = 2 * 15 / 16 * (512 * 1024 * 4)  # f32[1,512,1024], g=16
    ag = 31 / 32 * (16 * 4096 * 2)  # bf16, g=32
    rs = 7 * (8 * 128 * 4)  # g=8, (g-1) * out
    a2a = 255 / 256 * (256 * 64 * 2)
    cp = 4 * 4 * 4
    want = ar + ag + rs + a2a + cp
    assert abs(out["wire_bytes"] - want) / want < 1e-9
    assert set(out["by_kind"]) == {"all-reduce", "all-gather",
                                   "reduce-scatter", "all-to-all",
                                   "collective-permute"}


def test_roofline_terms_dominance():
    c = CostSummary(flops=197e12, bytes_accessed=819e9 / 2,
                    coll_wire_bytes=50e9 / 4)
    t = roofline_terms(c, 256)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 0.5) < 1e-9
    assert abs(t["collective_s"] - 0.25) < 1e-9
    assert t["dominant"] == "compute"
    assert t["compute_fraction_of_bound"] == 1.0
    # the tpu estimate is half the HLO figure, floored by the analytic floor
    t2 = roofline_terms(c, 256, mem_floor_bytes=819e9)
    assert abs(t2["memory_s_tpu_est"] - 1.0) < 1e-9


def test_scaled_add():
    a = CostSummary(flops=1.0, bytes_accessed=2.0, coll_wire_bytes=3.0,
                    coll_count=1, coll_by_kind={"all-reduce": 3.0})
    b = CostSummary()
    b.scaled_add(a, 5.0)
    assert b.flops == 5.0 and b.bytes_accessed == 10.0
    assert b.coll_by_kind["all-reduce"] == 15.0
