"""Exact assigned-architecture configs (assignment table values)."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config

EXACT = {
    "deepseek_v2_236b": dict(n_layers=60, d_model=5120, n_heads=128,
                             n_kv_heads=128, d_ff=1536, vocab_size=102400,
                             n_experts=160, moe_top_k=6, n_shared_experts=2,
                             kv_lora_rank=512),
    "llama4_scout_17b_a16e": dict(n_layers=48, d_model=5120, n_heads=40,
                                  n_kv_heads=8, d_ff=8192,
                                  vocab_size=202048, n_experts=16,
                                  moe_top_k=1),
    "qwen2_5_32b": dict(n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
                        d_ff=27648, vocab_size=152064, qkv_bias=True),
    "gemma3_4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
                      d_ff=10240, vocab_size=262144, local_global_period=6),
    "llama3_2_1b": dict(n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
                        d_ff=8192, vocab_size=128256),
    "olmo_1b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
                    d_ff=8192, vocab_size=50304, norm_kind="nonparametric"),
    "chameleon_34b": dict(n_layers=48, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=22016, vocab_size=65536),
    "seamless_m4t_large_v2": dict(n_enc_layers=24, n_dec_layers=24,
                                  d_model=1024, n_heads=16, n_kv_heads=16,
                                  d_ff=8192, vocab_size=256206),
    "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
                      d_ff=14336, vocab_size=32000, ssm_state=64),
    "rwkv6_7b": dict(n_layers=32, d_model=4096, d_ff=14336,
                     vocab_size=65536, attn_kind="none"),
}

PARAM_RANGES = {  # published sizes, billions (sanity band)
    "deepseek_v2_236b": (220, 250), "llama4_scout_17b_a16e": (100, 115),
    "qwen2_5_32b": (30, 35), "gemma3_4b": (3.5, 4.5),
    "llama3_2_1b": (1.0, 1.5), "olmo_1b": (1.0, 1.4),
    "chameleon_34b": (32, 36), "seamless_m4t_large_v2": (1.2, 2.6),
    "zamba2_7b": (6.3, 7.7), "rwkv6_7b": (6.3, 7.9),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_values(arch):
    cfg = get_config(arch)
    for field, want in EXACT[arch].items():
        assert getattr(cfg, field) == want, (arch, field)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_band(arch):
    cfg = get_config(arch)
    lo, hi = PARAM_RANGES[arch]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B not in [{lo},{hi}]"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_same_family(arch):
    cfg, red = get_config(arch), get_reduced_config(arch)
    assert red.family == cfg.family
    assert red.attn_kind == cfg.attn_kind
    assert red.is_moe == cfg.is_moe
    assert red.is_enc_dec == cfg.is_enc_dec
    assert red.param_count() < 5e6


def test_moe_active_params():
    cfg = get_config("deepseek_v2_236b")
    assert 18e9 < cfg.active_param_count() < 25e9  # ~21B active


def test_shapes_assignment():
    # long_500k only for sub-quadratic archs; others document the skip
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = {s.name for s in cfg.shapes()}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= names
        if arch in ("gemma3_4b", "zamba2_7b", "rwkv6_7b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
            assert "long_500k" in cfg.skip_reasons()
