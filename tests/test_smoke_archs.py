"""Per-arch smoke: REDUCED config, one forward/train step + prefill/decode
on CPU, asserting output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import (NULL_SH, decode_step, init_params, prefill,
                          train_loss)


def _batch(cfg, B=2, S=32):
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(2, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.is_enc_dec:
        frames = jnp.asarray(rng.randn(B, S, cfg.frame_dim), jnp.float32)
        return {"frames": frames, "tokens": toks}
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_reduced_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    loss, metrics = jax.jit(
        lambda p, b: train_loss(p, cfg, NULL_SH, b))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: train_loss(p, cfg, NULL_SH, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_reduced_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, caches = prefill(params, cfg, NULL_SH, batch, cache_len=S + 4)
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches2 = decode_step(params, cfg, NULL_SH, caches, tok, S)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)
