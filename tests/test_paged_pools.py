"""Paged cache pools: property-based fuzzing of the ``PagePool`` allocator
(no double-booked page, free+live conservation, tables only reference live
pages, deterministic replay), page-granular eq. (5)/(20) accounting on
``CachePool``, and the engine-level preemption/oversubscription scenarios —
mid-decode swap-out resumes bit-exact, preemption composes with server
failover replay, and a cohort the slab layout refuses is served to
completion under paged admission (the vLLM-style "book pages, not
worst-case slots" unlock on the paper's block-slot budgets).

Uses the conftest hypothesis shim when hypothesis is not installed: the
property tests draw a seed and drive ``random.Random(seed)`` themselves so
the operation sequences are identical under either backend.
"""
import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import CachePool, PagePool, pages_for
from repro.serving.kv_cache import TRASH_PAGE

# ---------------------------------------------------------------------------
# pages_for
# ---------------------------------------------------------------------------


def test_pages_for_ceil_division():
    assert pages_for(0, 4) == 0
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2
    assert pages_for(16, 16) == 1
    assert pages_for(17, 16) == 2


# ---------------------------------------------------------------------------
# PagePool: property-based allocator fuzzing
# ---------------------------------------------------------------------------


def _random_ops(pool: PagePool, rng: random.Random, n_ops: int):
    """Drive a random alloc/grow/free sequence against a model of the live
    set, checking the allocator invariants after every operation.  Returns
    the operation log (for replay-determinism checks)."""
    live_rows = {}  # row -> page count (the model)
    log = []
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.45 and len(live_rows) < pool.n_rows:
            # grow a fresh or existing row by a random amount
            row = rng.randrange(pool.n_rows)
            have = live_rows.get(row, 0)
            want = min(have + rng.randint(1, 3), pool.max_pages_per_row)
            if want > have and pool.can_grow(row, want):
                got = pool.grow_to(row, want)
                log.append(("grow", row, want, tuple(got)))
                live_rows[row] = want
        elif op < 0.7 and live_rows:
            row = rng.choice(sorted(live_rows))
            have = live_rows[row]
            want = min(have + rng.randint(1, 4), pool.max_pages_per_row)
            if want > have and pool.can_grow(row, want):
                got = pool.grow_to(row, want)
                log.append(("grow", row, want, tuple(got)))
                live_rows[row] = want
        elif live_rows:
            row = rng.choice(sorted(live_rows))
            freed = pool.free_row(row)
            log.append(("free", row, tuple(freed)))
            del live_rows[row]
        pool.check_invariants()
        # model agreement: per-row live counts and global conservation
        for row in range(pool.n_rows):
            assert pool.count[row] == live_rows.get(row, 0)
        assert pool.used_pages + pool.free_pages == pool.n_pages
        assert pool.used_pages == sum(live_rows.values())
    return log


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_pagepool_random_ops_preserve_invariants(seed):
    """Random alloc/grow/free sequences: no double-booked page, free+live
    conservation, tables only reference live page ids, stale table slots
    stay at TRASH_PAGE."""
    rng = random.Random(seed)
    pool = PagePool(n_pages=rng.randint(4, 24), n_rows=rng.randint(2, 8),
                    max_pages_per_row=rng.randint(2, 6))
    _random_ops(pool, rng, n_ops=60)
    # explicit no-double-booking sweep on the final state (check_invariants
    # covered every intermediate state already)
    live = [int(p) for row in range(pool.n_rows)
            for p in pool.pages_of(row)]
    assert len(live) == len(set(live))
    assert all(1 <= p <= pool.n_pages for p in live)
    assert TRASH_PAGE not in live


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_pagepool_deterministic_replay(seed):
    """The same seed replays to the identical operation log, page-id
    assignments, and final table — the allocator has no hidden state."""
    logs, tables = [], []
    for _ in range(2):
        rng = random.Random(seed)
        pool = PagePool(n_pages=rng.randint(4, 24),
                        n_rows=rng.randint(2, 8),
                        max_pages_per_row=rng.randint(2, 6))
        logs.append(_random_ops(pool, rng, n_ops=40))
        tables.append(pool.table.copy())
    assert logs[0] == logs[1]
    np.testing.assert_array_equal(tables[0], tables[1])


def test_pagepool_exhaustion_and_width_overflow():
    pool = PagePool(n_pages=3, n_rows=2, max_pages_per_row=4)
    pool.grow_to(0, 2)
    assert pool.can_grow(1, 1) and not pool.can_grow(1, 2)
    with pytest.raises(RuntimeError, match="page"):
        pool.grow_to(1, 2)  # only 1 free page left
    with pytest.raises(RuntimeError, match="page"):
        pool.grow_to(0, 5)  # beyond the table width
    # failed grows must not leak pages
    pool.check_invariants()
    assert pool.free_pages == 1


def test_pagepool_free_recycles_lifo():
    """Freed pages return to the free list and get reused — the pool
    round-trips through full occupancy."""
    pool = PagePool(n_pages=4, n_rows=2, max_pages_per_row=4)
    first = pool.grow_to(0, 4)
    assert pool.free_pages == 0
    pool.free_row(0)
    assert pool.free_pages == 4
    second = pool.grow_to(1, 4)
    assert sorted(first) == sorted(second)  # same physical pages recycled
    pool.check_invariants()


# ---------------------------------------------------------------------------
# CachePool: page-granular eq. (5) accounting
# ---------------------------------------------------------------------------


def _paged_pool(**kw):
    from repro.configs import get_reduced_config
    args = dict(n_rows=4, max_len=8, cap_slots=4, layout="paged",
                page_size=2)
    args.update(kw)
    return CachePool(get_reduced_config("llama3_2_1b"),
                     ("decoder", "decoder"), **args)


def test_cache_pool_page_units_accounting():
    """A session through k blocks holding p pages charges k*p units of the
    eq. (5) budget; growth re-charges, release refunds exactly."""
    pool = _paged_pool()
    cap = pool.cap_units
    assert cap == pool.cap_slots * pool.max_pages
    assert pool.usage() == (0, cap)
    pool.alloc(sid=7, k_blocks=2, n_pages=1)
    assert pool.usage() == (2, cap)           # 2 blocks x 1 page
    pool.grow_pages(7, 3)
    assert pool.usage() == (6, cap)           # 2 blocks x 3 pages
    pool.alloc(sid=8, k_blocks=1, n_pages=2)
    assert pool.usage() == (8, cap)
    pool.release(7)
    assert pool.usage() == (2, cap)
    pool.release(8)
    assert pool.usage() == (0, cap)
    pool.pages.check_invariants()
    assert pool.pages.free_pages == pool.pages.n_pages


def test_cache_pool_worst_case_solo_fit_bound():
    """Admission rejects a session whose WORST-case pages could never fit
    even alone — the deadlock-freedom precondition for preemption."""
    pool = _paged_pool()
    # worst fits: admitted on prompt pages only
    assert pool.fits(1, k_blocks=2, n_pages=1, worst_pages=pool.max_pages)
    # worst exceeds the table width -> refuse outright
    assert not pool.fits(1, k_blocks=2, n_pages=1,
                         worst_pages=pool.max_pages + 1)
    # worst exceeds the unit budget solo -> refuse
    too_many_blocks = pool.cap_units // pool.max_pages + 1
    assert not pool.fits(1, k_blocks=too_many_blocks, n_pages=1,
                         worst_pages=pool.max_pages)


def test_cache_pool_paged_books_pages_not_slots():
    """The co-residency unlock: short sessions book prompt pages, so more
    of them fit than the slab's worst-case slot budget admits."""
    slab = _paged_pool(layout="slab", page_size=0)
    paged = _paged_pool()
    n_slab = n_paged = 0
    for sid in range(16):
        if slab.fits(sid, k_blocks=2):
            slab.alloc(sid, 2)
            n_slab += 1
    for sid in range(16):
        if paged.fits(sid, 2, n_pages=1, worst_pages=paged.max_pages):
            paged.alloc(sid, 2, n_pages=1)
            n_paged += 1
    assert n_paged > n_slab


# ---------------------------------------------------------------------------
# Engine scenarios: preemption, resume parity, failover composition,
# oversubscription
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def _llama():
    import jax
    from repro.configs import get_reduced_config
    from repro.models import init_params
    cfg = get_reduced_config("llama3_2_1b")
    params = init_params(jax.random.PRNGKey(0), cfg)[0]
    return cfg, params


def _build_system(_llama, layout, mem=2000.0, max_new=6, n_servers=2,
                  max_sessions=4, page_size=None):
    from repro.core import LLMSpec, Problem, ServerSpec, Workload
    from repro.serving import GeoServingSystem
    cfg, params = _llama
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=mem, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3,
                   workload=Workload(4, max_new))
    return GeoServingSystem(cfg, params, prob, algorithm="proposed", R=2,
                            max_new_tokens=max_new,
                            max_sessions=max_sessions, decode_mode="fused",
                            cache_layout=layout, page_size=page_size)


def _admit(_llama, system, lengths, n_new, seed=0):
    from repro.core import shortest_path_route
    cfg, _ = _llama
    rng = np.random.RandomState(seed)
    sids = []
    for n in lengths:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(
            rng.randint(2, cfg.vocab_size, n), 0, route, n_new))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    return sids


def _run_to_completion(system, sids, n_new, max_rounds=500):
    rounds = 0
    while any(system.sessions[s].n_generated < n_new for s in sids):
        system.decode_round()
        rounds += 1
        assert rounds < max_rounds, "decode did not converge (livelock?)"
    return [list(system.sessions[s].tokens) for s in sids], \
        [float(system.sessions[s].virtual_time) for s in sids]


@pytest.fixture(scope="module")
def _reference_streams(_llama):
    """Unpreempted big-memory slab run: the bit-exactness oracle for every
    preemption scenario below (2 sessions, 2 servers, 6 new tokens)."""
    system = _build_system(_llama, "slab")
    sids = _admit(_llama, system, (4, 5), n_new=6)
    return _run_to_completion(system, sids, n_new=6)


def test_preempt_mid_decode_resumes_bit_exact(_llama, _reference_streams):
    """Swap a session out mid-decode, keep driving rounds: the resume
    replay rebuilds its caches and the finished stream is identical to
    the never-preempted run, while the virtual clock exceeds the slab
    oracle's by EXACTLY the billed resume-replay cost (replay is
    re-execution — it is not free)."""
    ref_toks, ref_vts = _reference_streams
    system = _build_system(_llama, "paged", page_size=2)
    sids = _admit(_llama, system, (4, 5), n_new=6)
    system.decode_round(sids)
    system.preempt_session(sids[0])
    sess = system.sessions[sids[0]]
    assert sess.state == "preempted" and sess.n_preemptions == 1
    # swapped out: holds no rows anywhere
    assert all(sids[0] not in srv.pool.rows
               for srv in system.servers.values())
    toks, vts = _run_to_completion(system, sids, n_new=6)
    assert toks == ref_toks
    # paged clock = slab clock + billed replay, per session
    replays = [float(system.sessions[s].replay_time) for s in sids]
    assert replays[0] > 0.0 and replays[1] == 0.0
    assert vts == pytest.approx([r + p for r, p in zip(ref_vts, replays)])
    assert system.sessions[sids[0]].n_replays >= 1
    assert system.round_stats["resumes"] >= 1
    assert system.round_stats["replay_s"] == pytest.approx(sum(replays))


def test_preemption_composes_with_failover(_llama, _reference_streams):
    """Kill a route server WHILE the session sits swapped out: resume
    skips the dead hop and the next traverse's failover replay splices a
    replacement chain — streams still bit-exact."""
    ref_toks, _ = _reference_streams
    system = _build_system(_llama, "paged", page_size=2, n_servers=4)
    sids = _admit(_llama, system, (4, 5), n_new=6)
    system.decode_round(sids)
    system.preempt_session(sids[0])
    dead = system.sessions[sids[0]].route.servers[0]
    system.kill_server(dead)
    toks, _ = _run_to_completion(system, sids, n_new=6)
    assert toks == ref_toks
    assert dead not in system.sessions[sids[0]].route.servers


def test_crash_of_preemption_victim_mid_swap(_llama, _reference_streams):
    """Silent crash (no oracle: ``inject_crash``) of a route server WHILE
    the victim sits swapped out: the resume dispatch misses its deadline,
    timeout detection bills the wait, failover splices around the dead
    hop — streams still bit-exact, and the billed recovery shows up on
    the session."""
    ref_toks, _ = _reference_streams
    system = _build_system(_llama, "paged", page_size=2, n_servers=4)
    sids = _admit(_llama, system, (4, 5), n_new=6)
    system.decode_round(sids)
    system.preempt_session(sids[0])
    dead = system.sessions[sids[0]].route.servers[0]
    system.inject_crash(dead)  # crashed but still "alive" until detected
    toks, _ = _run_to_completion(system, sids, n_new=6)
    assert toks == ref_toks
    victim = system.sessions[sids[0]]
    assert dead not in victim.route.servers
    assert victim.n_detections >= 1
    assert victim.recovery_time > 0.0  # detect + backoff + replay billed
    assert not system.servers[dead].alive
    assert dead in system.suspected_servers()
    # the other session's stream is untouched and no page state leaked
    for srv in system.servers.values():
        srv.pool.pages.check_invariants()


def test_retire_preempted_session_is_clean(_llama):
    """Retiring a swapped-out session releases nothing twice and leaves
    every pool empty."""
    system = _build_system(_llama, "paged", page_size=2)
    sids = _admit(_llama, system, (4,), n_new=6)
    system.decode_round(sids)
    system.preempt_session(sids[0])
    assert system.retire_session(sids[0]) is not None
    assert all(u == 0 for u, _ in system.slot_usage().values())
    for srv in system.servers.values():
        srv.pool.pages.check_invariants()


def test_oversubscription_slab_refuses_paged_serves(_llama):
    """The acceptance scenario: a 10-session cohort the slab layout's
    worst-case admission refuses is fully admitted under paged accounting
    and served TO COMPLETION, preempting under page pressure mid-decode —
    streams bit-exact vs an uncontended slab reference."""
    n_new, lengths = 30, [4] * 10
    ref = _build_system(_llama, "slab", mem=5000.0, max_new=n_new,
                        max_sessions=12)
    ref_toks, _ = _run_to_completion(
        ref, _admit(_llama, ref, lengths, n_new), n_new)

    from repro.core import shortest_path_route
    cfg, _ = _llama
    slab = _build_system(_llama, "slab", mem=250.0, max_new=n_new,
                         max_sessions=12)
    rng = np.random.RandomState(0)
    sids = []
    for n in lengths:
        route, _ = shortest_path_route(slab.problem,
                                       slab.alive_placement(), 0)
        sids.append(slab.create_session(
            rng.randint(2, cfg.vocab_size, n), 0, route, n_new))
    admitted = slab.try_admit_sessions(sids)
    assert len(admitted) < len(lengths), \
        "scenario must oversubscribe the slab budget"

    paged = _build_system(_llama, "paged", mem=250.0, max_new=n_new,
                          max_sessions=12, page_size=2)
    psids = _admit(_llama, paged, lengths, n_new)  # asserts ALL admitted
    toks, _ = _run_to_completion(paged, psids, n_new, max_rounds=3000)
    assert toks == ref_toks
    assert paged.round_stats["preemptions"] >= 1
    assert paged.round_stats["resumes"] >= 1


def test_scheduler_reports_preemptions(_llama):
    """End-to-end through ContinuousBatchingScheduler on the oversubscribed
    topology: every request completes (none dropped) and the preemption
    count surfaces on ServedRequest."""
    from repro.serving import ContinuousBatchingScheduler
    cfg, _ = _llama
    n_new = 30
    system = _build_system(_llama, "paged", mem=250.0, max_new=n_new,
                           max_sessions=12, page_size=2)
    sched = ContinuousBatchingScheduler(system, R=12)
    rng = np.random.RandomState(0)
    for rid in range(10):
        sched.submit(rid, rng.randint(2, cfg.vocab_size, 4),
                     arrival=0.0, n_new=n_new)
    results = sched.run()
    assert len(results) == 10
    assert not any(r.dropped for r in results)
    assert all(len(r.tokens) >= 4 + n_new for r in results)
    # every swap-out belongs to some retired request: the per-request
    # counts reconcile exactly with the engine's round_stats
    assert (sum(r.n_preemptions for r in results)
            == system.round_stats["preemptions"] >= 1)
