"""Continuous-batching multi-session engine: batched-vs-serial bit-exact
parity, scheduler invariants under load (no cache-slot overbooking,
FIFO-within-client), failover replay with concurrent sessions, and
engine-vs-simulator cross-validation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import LLMSpec, Problem, ServerSpec, Workload
from repro.models import NULL_SH, decode_step, init_params, prefill
from repro.serving import ContinuousBatchingScheduler, GeoServingSystem
from repro.sim import SimConfig, simulate
from repro.sim.workload import burst_requests, poisson_requests, prompts_for


def _build(arch="llama3_2_1b", n_servers=4, R=2, mem=900.0,
           max_sessions=8, l_out=8, max_new=8, tau_pre=0.002):
    cfg = get_reduced_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=50.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=mem, tau=0.01 * (j + 1),
                          tau_prefill_base=tau_pre,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, l_out))
    system = GeoServingSystem(cfg, params, prob, algorithm="proposed", R=R,
                              max_new_tokens=max_new,
                              max_sessions=max_sessions)
    return cfg, params, prob, system


def _run_sessions(system, prompts, n_new, batched: bool):
    """Run sessions through create/admit/decode_round; ``batched`` runs them
    co-resident, else strictly one-at-a-time.  Returns per-session
    (tokens, [logits per generated token])."""
    from repro.core import shortest_path_route

    out = []
    sids = []
    logit_hist = {}
    for toks in prompts:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sid = system.create_session(toks, 0, route, n_new)
        sids.append(sid)
        if not batched:
            assert system.try_admit_session(sid)
            logit_hist[sid] = [np.asarray(system.sessions[sid].last_logits)]
            while system.sessions[sid].n_generated < n_new:
                system.decode_round([sid])
                logit_hist[sid].append(
                    np.asarray(system.sessions[sid].last_logits))
            out.append(list(system.sessions[sid].tokens))
            system.retire_session(sid)
    if batched:
        for sid in sids:
            assert system.try_admit_session(sid), "pool must fit all sessions"
            logit_hist[sid] = [np.asarray(system.sessions[sid].last_logits)]
        while True:
            advance = [s for s in sids
                       if system.sessions[s].n_generated < n_new]
            if not advance:
                break
            system.decode_round(advance)
            for sid in advance:
                logit_hist[sid].append(
                    np.asarray(system.sessions[sid].last_logits))
        for sid in sids:
            out.append(list(system.sessions[sid].tokens))
            system.retire_session(sid)
    return out, [logit_hist[s] for s in sids]


@pytest.mark.parametrize("arch", ["llama3_2_1b", "rwkv6_7b"])
def test_batched_vs_serial_bitexact(arch):
    """Per-session logits must be IDENTICAL whether a session decodes alone
    or co-resident with 3 neighbours — the fixed-shape pooled step makes
    this structural, not approximate."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(2, 64, 4) for _ in range(4)]
    n_new = 5

    _, _, _, sys_serial = _build(arch)
    toks_serial, logits_serial = _run_sessions(sys_serial, prompts, n_new,
                                               batched=False)
    _, _, _, sys_batched = _build(arch)
    toks_batched, logits_batched = _run_sessions(sys_batched, prompts, n_new,
                                                 batched=True)
    assert toks_serial == toks_batched
    for ls, lb in zip(logits_serial, logits_batched):
        assert len(ls) == len(lb) == n_new
        for a, b in zip(ls, lb):
            np.testing.assert_array_equal(a, b)  # bit-for-bit


def test_batched_matches_monolithic():
    """Co-resident pooled decoding still equals the monolithic stack."""
    cfg, params, prob, system = _build()
    rng = np.random.RandomState(1)
    prompts = [rng.randint(2, cfg.vocab_size, 4) for _ in range(3)]
    n_new = 5
    toks, _ = _run_sessions(system, prompts, n_new, batched=True)
    for p, got in zip(prompts, toks):
        logits, caches = prefill(params, cfg, NULL_SH,
                                 {"tokens": jnp.asarray(p)[None]},
                                 cache_len=len(p) + n_new + 4)
        ref = [int(jnp.argmax(logits[0]))]
        pos = len(p)
        for _ in range(n_new - 1):
            lg, caches = decode_step(params, cfg, NULL_SH, caches,
                                     jnp.asarray([ref[-1]]), pos)
            ref.append(int(jnp.argmax(lg[0])))
            pos += 1
        assert got[len(p):] == ref


def test_eight_concurrent_sessions():
    """A burst of 10 arrivals must hold >= 8 interleaved sessions."""
    cfg, params, prob, system = _build(R=2, mem=2000.0, max_sessions=12,
                                       l_out=6, max_new=6)
    sched = ContinuousBatchingScheduler(system, R=8)
    rng = np.random.RandomState(2)
    for req in burst_requests(10):
        sched.submit(req.rid, rng.randint(2, cfg.vocab_size, 4),
                     req.arrival, n_new=6)
    served = sched.run()
    assert len(served) == 10 and not any(r.dropped for r in served)
    assert sched.max_concurrency >= 8
    # everything retired: no leaked rows or block-slots
    for used, cap in system.slot_usage().values():
        assert used == 0


def test_scheduler_invariants_under_load():
    """Tight memory + high rate: sessions must defer (re-admission path),
    the block-slot budget must never be overbooked, and starts within a
    client must be FIFO."""
    cfg, params, prob, system = _build(R=1, mem=180.0, max_sessions=4,
                                       l_out=6, max_new=6)
    # cap per server: floor((180 - 50*m)/s_c), s_c = 1.0 * 10 tokens = 10
    sched = ContinuousBatchingScheduler(system, R=1)
    rng = np.random.RandomState(3)
    for req in poisson_requests(8, rate=20.0, seed=4):
        sched.submit(req.rid, rng.randint(2, cfg.vocab_size, 4),
                     req.arrival, n_new=6)

    # monitor the overbooking invariant at every decode round
    orig_round = system.decode_round
    peaks = []

    def checked_round(sids=None):
        for j, (used, cap) in system.slot_usage().items():
            assert used <= cap, f"server {j} overbooked: {used}/{cap}"
        peaks.append(system.concurrency())
        return orig_round(sids)

    system.decode_round = checked_round
    served = sched.run()
    assert len(served) == 8 and not any(r.dropped for r in served)
    # FIFO within the single client: starts follow arrival order
    starts = [r.start for r in served]
    assert all(s2 >= s1 - 1e-9 for s1, s2 in zip(starts, starts[1:]))
    # the tight-memory scenario must actually exercise waiting or deferral
    assert any(r.wait > 0 for r in served) or \
        any(r.n_deferrals > 0 for r in served)
    for used, cap in system.slot_usage().values():
        assert used == 0


def test_failover_with_concurrent_sessions():
    """Kill a server while >= 2 sessions are co-resident: both must keep
    generating the exact no-failure token streams."""
    cfg, params, prob, system = _build(n_servers=4, R=2)
    rng = np.random.RandomState(5)
    prompts = [rng.randint(2, cfg.vocab_size, 4) for _ in range(2)]
    n_new = 6

    # reference: no-failure monolithic streams
    refs = []
    for p in prompts:
        logits, caches = prefill(params, cfg, NULL_SH,
                                 {"tokens": jnp.asarray(p)[None]},
                                 cache_len=len(p) + n_new + 4)
        seq = [int(jnp.argmax(logits[0]))]
        pos = len(p)
        for _ in range(n_new - 1):
            lg, caches = decode_step(params, cfg, NULL_SH, caches,
                                     jnp.asarray([seq[-1]]), pos)
            seq.append(int(jnp.argmax(lg[0])))
            pos += 1
        refs.append(seq)

    from repro.core import shortest_path_route
    sids = []
    for p in prompts:
        route, _ = shortest_path_route(prob, system.alive_placement(), 0)
        sid = system.create_session(p, 0, route, n_new)
        assert system.try_admit_session(sid)
        sids.append(sid)
    # two shared rounds, then kill the first server on session 0's route
    system.decode_round(sids)
    system.decode_round(sids)
    victim = system.sessions[sids[0]].route.servers[0]
    system.kill_server(victim)
    while any(system.sessions[s].n_generated < n_new for s in sids):
        system.decode_round(
            [s for s in sids if system.sessions[s].n_generated < n_new])
    for sid, p, ref in zip(sids, prompts, refs):
        sess = system.sessions[sid]
        assert victim not in sess.route.servers
        assert sess.tokens[len(p):] == ref, \
            "post-failover generation must be identical"
        system.retire_session(sid)


def test_double_failover_multi_hop_chain_exact():
    """A dead server replaced by a TWO-server chain, then the later
    replacement hop dies too: its replay must use hop-local input history
    (activations entering ITS block range), keeping generation bit-exact."""
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=8)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=50.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(0, 900.0, 0.005)] + [
        ServerSpec(j, 330.0, 0.01 + 0.005 * j) for j in range(1, 6)]
    rtt = np.full((1, 6), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, 8))
    system = GeoServingSystem(cfg, params, prob, R=2, max_new_tokens=8)
    rng = np.random.RandomState(5)
    toks = rng.randint(2, cfg.vocab_size, 4)

    logits, caches = prefill(params, cfg, NULL_SH,
                             {"tokens": jnp.asarray(toks)[None]},
                             cache_len=16)
    ref = [int(jnp.argmax(logits[0]))]
    pos = len(toks)
    for _ in range(6):
        lg, caches = decode_step(params, cfg, NULL_SH, caches,
                                 jnp.asarray([ref[-1]]), pos)
        ref.append(int(jnp.argmax(lg[0])))
        pos += 1

    sid, lg = system.submit(toks)
    seq = [int(jnp.argmax(lg[0]))]
    for step in range(6):
        if step == 1:
            system.kill_server(system.sessions[sid].route.servers[0])
        if step == 3:
            route = system.sessions[sid].route.servers
            assert len(route) >= 2, f"expected multi-hop chain, got {route}"
            system.kill_server(route[-1])  # the LATER replacement hop
        lgx = system.decode(sid, seq[-1])
        seq.append(int(jnp.argmax(lgx[0])))
    assert seq == ref, "double failover must stay bit-exact"


@pytest.mark.parametrize("R", [1, 4, 8])
def test_engine_vs_simulator_tolerance(R):
    """Same Poisson trace through the simulator and the real engine: mean
    per-token and first-token times agree within 10%."""
    from benchmarks.engine_validation import cross_validate

    eng, simm, err = cross_validate(R, n_requests=8, rate=1.5, seed=1)
    assert err["per_token_all"] < 0.10, (eng, simm)
    assert err["first_token"] < 0.10, (eng, simm)


def test_trace_consistency_engine_and_sim_accounting():
    """The engine's virtual accounting reproduces eq. (1) exactly when there
    is no contention: wait == 0, per_token == route cost."""
    from repro.core import route_per_token_time, route_prefill_time, \
        shortest_path_route

    cfg, params, prob, system = _build(l_out=4, max_new=4)
    sched = ContinuousBatchingScheduler(system, R=2)
    rng = np.random.RandomState(7)
    sched.submit(0, rng.randint(2, cfg.vocab_size, 4), 0.0, n_new=4)
    (r,) = sched.run()
    route, _ = shortest_path_route(prob, system.placement, 0)
    assert r.wait == 0.0
    np.testing.assert_allclose(r.first_token,
                               route_prefill_time(prob, route, 0), rtol=1e-9)
    np.testing.assert_allclose(r.per_token_rest,
                               route_per_token_time(prob, route, 0),
                               rtol=1e-9)
