"""Engine rounds are backend-independent: ``GeoServingSystem`` must produce
IDENTICAL round results — token streams, admission/grouping decisions, and
virtual-clock accounting — with ``backend="xla"`` and ``backend="pallas"``
(interpret mode off-TPU), with per-round logits agreeing to float-eps.

Scenarios cover every kernel<->oracle gap the pooled call sites exercise:
mixed-position pooled rows (co-resident sessions with different prompt
lengths), windowed gemma3, ALiBi bloom, MLA deepseek decode, rwkv and
hybrid (zamba2) recurrent pools, enc-dec (seamless) cross-attention with
mixed encoder lengths, and chunked prefill (q_start).  The CI
``kernel-parity`` job runs this file with ``REPRO_PALLAS_INTERPRET=1`` so
kernel changes cannot land without oracle parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                        shortest_path_route)
from repro.models import init_params
from repro.serving import GeoServingSystem

# per-round logits across backends: different compute substrates (online-
# softmax kernels vs dense oracle), so float-eps — tokens must be EXACT
LOGIT_TOL = dict(atol=5e-4, rtol=5e-4)

_PARAMS_CACHE = {}


def _params_for(cfg):
    if cfg.name not in _PARAMS_CACHE:
        _PARAMS_CACHE[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)[0]
    return _PARAMS_CACHE[cfg.name]


def _build(arch, backend, n_servers=2, max_new=4, **kw):
    cfg = get_reduced_config(arch)
    params = _params_for(cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=1000.0, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, max_new))
    system = GeoServingSystem(cfg, params, prob, algorithm="proposed", R=2,
                              max_new_tokens=max_new, max_sessions=4,
                              backend=backend, **kw)
    return cfg, system


def _serve(system, jobs, n_new):
    """Admit ``jobs`` [(prompt, frames|None), ...] as ONE coalesced batch
    (mixed lengths -> mixed positions in the pooled rows), decode all to
    completion.  Returns (token lists, logits histories, virtual times)."""
    sids = []
    for prompt, frames in jobs:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(prompt, 0, route, n_new,
                                          frames=frames))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    hist = {sid: [np.asarray(system.sessions[sid].last_logits)]
            for sid in sids}
    while True:
        todo = [s for s in sids if system.sessions[s].n_generated < n_new]
        if not todo:
            break
        system.decode_round(todo)
        for sid in todo:
            hist[sid].append(np.asarray(system.sessions[sid].last_logits))
    toks = [list(system.sessions[s].tokens) for s in sids]
    vts = [float(system.sessions[s].virtual_time) for s in sids]
    for sid in sids:
        system.retire_session(sid)
    return toks, [hist[s] for s in sids], vts


def _jobs_for(cfg, lengths, enc_lens=None, seed=0):
    rng = np.random.RandomState(seed)
    jobs = []
    for i, n in enumerate(lengths):
        frames = None
        if cfg.is_enc_dec:
            frames = rng.randn(enc_lens[i], cfg.frame_dim).astype(np.float32)
        jobs.append((rng.randint(2, cfg.vocab_size, n), frames))
    return jobs


def _assert_backend_parity(arch, lengths=(4, 6, 5), enc_lens=None, n_new=4,
                           **kw):
    results = {}
    for backend in ("xla", "pallas"):
        cfg, system = _build(arch, backend, **kw)
        jobs = _jobs_for(cfg, lengths, enc_lens=enc_lens)
        results[backend] = _serve(system, jobs, n_new)
    toks_x, hist_x, vt_x = results["xla"]
    toks_p, hist_p, vt_p = results["pallas"]
    assert toks_x == toks_p, \
        f"{arch}: token streams differ across backends"
    assert vt_x == vt_p, \
        f"{arch}: virtual-clock accounting differs across backends"
    for hx, hp in zip(hist_x, hist_p):
        assert len(hx) == len(hp) == n_new
        for a, b in zip(hx, hp):
            np.testing.assert_allclose(a, b, **LOGIT_TOL)


# one scenario per kernel<->oracle gap -----------------------------------

def test_backend_parity_decoder_mixed_positions():
    """Plain GQA decoder; co-resident sessions at different prompt lengths
    decode at different per-row positions inside one pooled step."""
    _assert_backend_parity("llama3_2_1b", lengths=(4, 7, 5))


def test_backend_parity_windowed_gemma3():
    """Sliding-window + local:global pattern: the traced per-layer window
    flows into the kernels as a dynamic scalar."""
    _assert_backend_parity("gemma3_4b", lengths=(4, 6))


def test_backend_parity_alibi_bloom():
    """ALiBi slopes in prefill and decode."""
    _assert_backend_parity("bloom_176b", lengths=(4, 6, 5))


def test_backend_parity_mla_deepseek():
    """MLA: unabsorbed per-head prefill + absorbed latent-space decode with
    the faithful 1/sqrt(nope+rope) scale."""
    _assert_backend_parity("deepseek_v2_236b", lengths=(4, 6))


def test_backend_parity_rwkv():
    """Recurrent pools: wkv6 kernel prefill with carried-state out; decode
    stays on the (elementwise) XLA step on both backends."""
    _assert_backend_parity("rwkv6_7b", lengths=(4, 6, 4))


def test_backend_parity_hybrid_zamba2():
    """Hybrid stacks: ssd kernel for the mamba mixers + flash/decode
    kernels for the parameter-shared attention blocks."""
    _assert_backend_parity("zamba2_7b", lengths=(4, 6), n_new=3)


def test_backend_parity_encdec_seamless():
    """Enc-dec: non-causal encoder prefill, cross-attention with per-row
    kv_len over the over-allocated cross cache, mixed encoder lengths."""
    _assert_backend_parity("seamless_m4t_large_v2", lengths=(4, 6, 5),
                           enc_lens=(5, 8, 5))


def test_backend_parity_chunked_prefill():
    """Chunked prefill: prompts longer than the largest bucket run in
    chunks whose suffix queries mask via the kernels' static q_start."""
    _assert_backend_parity("llama3_2_1b", lengths=(9, 11), n_new=3,
                           prefill_buckets=(4,), max_seq_len=24)


def test_backend_validation():
    with pytest.raises(ValueError, match="pallas"):
        _build("llama3_2_1b", "tpu-only")
