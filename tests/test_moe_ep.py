"""Pure-EP shard_map MoE dispatch == global sort-dispatch (no-drop regime).

The EP path (hillclimb A, EXPERIMENTS.md §Perf) pads experts and dispatches
via all_to_all inside shard_map; with generous capacity both paths compute
the same routed-expert mixture.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.launch.mesh import compat_make_mesh
from repro.models.layers import NULL_SH, ShardingCtx
from repro.models import moe as moe_mod


def _pad_params(params, E, E_alloc):
    out = dict(params)
    for k in ("wg", "wu", "wo"):
        w = params[k]
        pad = np.zeros((E_alloc - E,) + w.shape[1:], w.dtype)
        out[k] = jnp.concatenate([w, jnp.asarray(pad)], axis=0)
    return out


def test_ep_matches_global():
    cfg = get_reduced_config("deepseek_v2_236b").replace(capacity_factor=8.0)
    E = cfg.n_experts
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    assert params["wg"].shape[0] == E  # reduced config stays unpadded
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model), jnp.float32) * 0.3

    ref, aux_ref = moe_mod.apply_moe(params, cfg, NULL_SH, x)

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    sh = ShardingCtx(mesh, {"batch": "data", "seq_act": None})
    padded = _pad_params(params, E, 2 * E)
    got, aux = moe_mod._apply_moe_ep(padded, cfg, sh, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
    assert float(aux["moe_drop_frac"]) < 1e-6
    np.testing.assert_allclose(float(aux["moe_aux_loss"]),
                               float(aux_ref["moe_aux_loss"]), rtol=1e-4)


def test_expert_alloc_padding_rule():
    assert moe_mod.expert_alloc(160) == 256
    assert moe_mod.expert_alloc(64) == 256
    assert moe_mod.expert_alloc(16) == 16  # small-E archs unpadded
    assert moe_mod.expert_alloc(8) == 8
    assert moe_mod.expert_alloc(300) == 512


def test_ep_eligibility_guards():
    cfg = get_reduced_config("deepseek_v2_236b")
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((2, 16, cfg.d_model), jnp.float32)
    # no mesh -> always global path
    assert not moe_mod._ep_eligible(params, cfg, NULL_SH, x)
