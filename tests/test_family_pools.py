"""Family-polymorphic state pools: hybrid (zamba2) and enc-dec (seamless)
stacks served end-to-end through the geo engine — engine-vs-monolithic
parity, solo-vs-grouped bit-exactness through the pooled programs, exact-
length (no-padding) prefill-group semantics for recurrent-state stacks,
mid-stream failover replay on hybrid and enc-dec routes, per-family τ
weights, and per-session sampling policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, Route, ServerSpec, Workload,
                        route_per_token_time, route_prefill_time,
                        shortest_path_route)
from repro.models import (NULL_SH, decode_step, init_params, prefill,
                          stack_block_kinds)
from repro.serving import (ContinuousBatchingScheduler, GeoServingSystem,
                           SamplingSpec, bucket_for, new_block_cache,
                           state_spec_for, state_specs)

_PARAMS_CACHE = {}


def _params_for(cfg):
    if cfg.name not in _PARAMS_CACHE:
        _PARAMS_CACHE[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)[0]
    return _PARAMS_CACHE[cfg.name]


def _build(arch, n_servers=3, R=2, mem=1000.0, max_sessions=8, l_out=8,
           max_new=8):
    cfg = get_reduced_config(arch)
    params = _params_for(cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=mem, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3,
                   workload=Workload(4, l_out))
    system = GeoServingSystem(cfg, params, prob, algorithm="proposed", R=R,
                              max_new_tokens=max_new,
                              max_sessions=max_sessions)
    return cfg, params, prob, system


def _frames_for(cfg, rng, n):
    return rng.randn(n, cfg.frame_dim).astype(np.float32)


def _monolithic_ref(cfg, params, prompt, n_new, frames=None):
    batch = {"tokens": jnp.asarray(prompt)[None]}
    if frames is not None:
        batch["frames"] = jnp.asarray(frames)[None]
    logits, caches = prefill(params, cfg, NULL_SH, batch,
                             cache_len=len(prompt) + n_new + 4)
    seq = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, caches = decode_step(params, cfg, NULL_SH, caches,
                                 jnp.asarray([seq[-1]]), pos)
        seq.append(int(jnp.argmax(lg[0])))
        pos += 1
    return seq


def _run_engine_sessions(system, jobs, n_new, coalesce):
    """jobs: [(prompt, frames|None), ...].  Admit (batched when coalesce),
    decode to completion.  Returns (token lists, per-session logit lists)."""
    sids = []
    for prompt, frames in jobs:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(prompt, 0, route, n_new,
                                          frames=frames))
    hist = {}
    if coalesce:
        assert system.try_admit_sessions(sids) == sids
        system.drain_prefill()
        for sid in sids:
            hist[sid] = [np.asarray(system.sessions[sid].last_logits)]
        while True:
            todo = [s for s in sids
                    if system.sessions[s].n_generated < n_new]
            if not todo:
                break
            system.decode_round(todo)
            for sid in todo:
                hist[sid].append(
                    np.asarray(system.sessions[sid].last_logits))
        out = [list(system.sessions[sid].tokens) for sid in sids]
        for sid in sids:
            system.retire_session(sid)
    else:
        out = []
        for sid in sids:
            assert system.try_admit_session(sid)
            hist[sid] = [np.asarray(system.sessions[sid].last_logits)]
            while system.sessions[sid].n_generated < n_new:
                system.decode_round([sid])
                hist[sid].append(
                    np.asarray(system.sessions[sid].last_logits))
            out.append(list(system.sessions[sid].tokens))
            system.retire_session(sid)
    return out, [hist[s] for s in sids]


# ---------------------------------------------------------------------------
# StateSpec dispatch
# ---------------------------------------------------------------------------


def test_state_spec_dispatch_and_kinds():
    z = get_reduced_config("zamba2_7b")  # 7 layers, period 3
    assert stack_block_kinds(z) == ("mamba", "mamba", "mamba_shared",
                                    "mamba", "mamba", "mamba_shared",
                                    "mamba")
    s = get_reduced_config("seamless_m4t_large_v2")  # 2 enc + 2 dec
    assert stack_block_kinds(s) == ("enc", "enc", "dec", "dec")
    zspecs = state_specs(z)
    assert all(sp.recurrent for sp in zspecs)
    assert zspecs[2].needs_emb0 and not zspecs[0].needs_emb0
    sspecs = state_specs(s)
    assert not sspecs[0].decode_active and sspecs[2].cross


def test_unknown_kind_raises_value_error():
    cfg = get_reduced_config("llama3_2_1b")
    with pytest.raises(ValueError, match="decoder"):
        new_block_cache(cfg, "transfusion", 1, 8)
    with pytest.raises(ValueError, match="rwkv"):
        state_spec_for("diffusion")
    with pytest.raises(ValueError, match="block kinds"):
        stack_block_kinds(cfg.replace(family="holographic"))


def test_bucket_for_family_rules():
    z = state_specs(get_reduced_config("zamba2_7b"))
    r = state_specs(get_reduced_config("rwkv6_7b"))
    d = state_specs(get_reduced_config("llama3_2_1b"))
    s = state_specs(get_reduced_config("seamless_m4t_large_v2"))
    # recurrent state (mamba AND rwkv): exact length, never padded
    assert bucket_for((8, 16), 5, z) == 5
    assert bucket_for((8, 16), 5, r) == 5
    # attention-only stacks bucket (enc-dec decoders included)
    assert bucket_for((8, 16), 5, d) == 8
    assert bucket_for((8, 16), 5, s) == 8
    assert bucket_for((8, 16), 17, d) is None  # overflow -> chunked


def test_per_family_tau_weights():
    llm = LLMSpec("w", 4, 10.0, 1.0, block_tau=(0.5, 0.5, 2.0, 1.0))
    assert llm.tau_weight(0, 4) == 4.0
    assert llm.tau_weight(0, 2) == 1.0
    assert llm.tau_weight(2, 4) == 3.0
    np.testing.assert_allclose(llm.tau_cumweights(), [0, 0.5, 1.0, 3.0, 4.0])
    servers = [ServerSpec(0, 100.0, 0.01, tau_prefill_base=0.004),
               ServerSpec(1, 100.0, 0.02, tau_prefill_base=0.004)]
    rtt = np.array([[0.1, 0.1]])
    prob = Problem(llm, servers, 1, rtt, rtt, workload=Workload(4, 8))
    route = Route(servers=(0, 1), blocks=(2, 2))
    # hop 0 carries weight 1.0, hop 1 weight 3.0 — NOT the uniform 2/2
    np.testing.assert_allclose(route_per_token_time(prob, route, 0),
                               0.1 + 1.0 * 0.01 + 0.1 + 3.0 * 0.02)
    np.testing.assert_allclose(route_prefill_time(prob, route, 0),
                               0.1 + 1.0 * 0.004 + 0.1 + 3.0 * 0.004)


# ---------------------------------------------------------------------------
# Engine vs monolithic (token streams; logits to float-eps across programs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["zamba2_7b", "seamless_m4t_large_v2"])
def test_engine_matches_monolithic(arch):
    cfg, params, prob, system = _build(arch)
    rng = np.random.RandomState(0)
    toks = rng.randint(2, cfg.vocab_size, 6)
    frames = _frames_for(cfg, rng, 5) if cfg.is_enc_dec else None
    n_new = 5
    ref = _monolithic_ref(cfg, params, toks, n_new, frames=frames)

    sid, logits = system.submit(toks, frames=frames)
    batch = {"tokens": jnp.asarray(toks)[None]}
    if frames is not None:
        batch["frames"] = jnp.asarray(frames)[None]
    ref_logits, caches = prefill(params, cfg, NULL_SH, batch,
                                 cache_len=len(toks) + n_new + 4)
    # logits agree to float-eps (engine and monolithic are different jitted
    # programs; XLA fusion jitters the last bits), tokens exactly
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(ref_logits[0]), rtol=2e-4,
                               atol=1e-5)
    seq = [int(jnp.argmax(ref_logits[0]))]
    pos = len(toks)
    for _ in range(n_new - 1):
        lg_ref, caches = decode_step(params, cfg, NULL_SH, caches,
                                     jnp.asarray([seq[-1]]), pos)
        lg = system.decode(sid, seq[-1])
        np.testing.assert_allclose(np.asarray(lg[0]),
                                   np.asarray(lg_ref[0]), rtol=2e-4,
                                   atol=1e-5)
        seq.append(int(jnp.argmax(lg_ref[0])))
        pos += 1
    assert seq == ref
    system.finish(sid)


# ---------------------------------------------------------------------------
# Solo vs grouped (bit-exact: the SAME pooled program, different mask bits)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["zamba2_7b", "seamless_m4t_large_v2"])
def test_solo_vs_grouped_bitexact(arch):
    cfg, _, _, sys_solo = _build(arch)
    rng = np.random.RandomState(1)
    # hybrid: mixed lengths -> exact-length groups; enc-dec: equal enc lens
    lengths = [4, 6, 4]
    jobs = [(rng.randint(2, cfg.vocab_size, n),
             _frames_for(cfg, rng, 5) if cfg.is_enc_dec else None)
            for n in lengths]
    n_new = 4
    toks_solo, logits_solo = _run_engine_sessions(sys_solo, jobs, n_new,
                                                  coalesce=False)
    _, _, _, sys_grp = _build(arch)
    toks_grp, logits_grp = _run_engine_sessions(sys_grp, jobs, n_new,
                                                coalesce=True)
    assert toks_solo == toks_grp
    for ls, lg in zip(logits_solo, logits_grp):
        assert len(ls) == len(lg) == n_new
        for a, b in zip(ls, lg):
            np.testing.assert_array_equal(a, b)  # bit-for-bit


def test_mamba_exact_length_prefill_groups():
    """Recurrent-state stacks must never pad: mixed-length hybrid admissions
    form one exact-length group per length, each group's chunk plan is a
    single exact-length shot, and results are bit-identical to solo runs
    (checked above); here we pin the grouping/plan semantics."""
    cfg, _, _, system = _build("zamba2_7b")
    rng = np.random.RandomState(2)
    prompts = [rng.randint(2, cfg.vocab_size, n) for n in (4, 7, 4)]
    sids = []
    for p in prompts:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(p, 0, route, 4))
    assert system.try_admit_sessions(sids) == sids
    groups = {(g.bucket, tuple(s.sid for s in g.members))
              for g in system._prefill_groups}
    assert groups == {(4, (sids[0], sids[2])), (7, (sids[1],))}, \
        "exact-length grouping: equal lengths coalesce, no padding"
    assert system._prefill_plan(7) == [(0, 7, 7)]  # one exact-length shot
    assert system._prefill_plan(4) == [(0, 4, 4)]
    system.drain_prefill()
    for sid in sids:
        assert system.sessions[sid].state == "active"
        system.retire_session(sid)


def test_encdec_mixed_enc_lengths_group_separately():
    """Enc-dec groups are keyed by encoder length too (the pooled encoder
    pass is exact-length); decoder prompts still bucket."""
    cfg, _, _, system = _build("seamless_m4t_large_v2")
    rng = np.random.RandomState(3)
    jobs = [(rng.randint(2, cfg.vocab_size, 5), _frames_for(cfg, rng, 4)),
            (rng.randint(2, cfg.vocab_size, 6), _frames_for(cfg, rng, 9)),
            (rng.randint(2, cfg.vocab_size, 4), _frames_for(cfg, rng, 4))]
    sids = []
    for p, f in jobs:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(p, 0, route, 4, frames=f))
    assert system.try_admit_sessions(sids) == sids
    keys = {(g.bucket, g.enc_len, tuple(s.sid for s in g.members))
            for g in system._prefill_groups}
    assert keys == {(8, 4, (sids[0], sids[2])), (8, 9, (sids[1],))}
    system.drain_prefill()
    toks = {}
    while any(system.sessions[s].n_generated < 4 for s in sids):
        system.decode_round()
    for sid in sids:
        toks[sid] = list(system.sessions[sid].tokens)
        system.retire_session(sid)
    # each matches its own monolithic reference
    params = _params_for(cfg)
    for sid, (p, f) in zip(sids, jobs):
        assert toks[sid][len(p):] == _monolithic_ref(cfg, params, p, 4,
                                                     frames=f)


# ---------------------------------------------------------------------------
# Failover replay on hybrid / enc-dec routes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["zamba2_7b", "seamless_m4t_large_v2"])
def test_failover_mid_stream_exact(arch):
    """Kill a route server while two sessions are co-resident mid-stream:
    both streams must continue bit-identically to the no-failure engine run
    (replay goes through the same pooled programs)."""
    cfg, _, _, ref_sys = _build(arch, n_servers=4)
    rng = np.random.RandomState(4)
    jobs = [(rng.randint(2, cfg.vocab_size, 5),
             _frames_for(cfg, rng, 5) if cfg.is_enc_dec else None)
            for _ in range(2)]
    n_new = 6
    ref_toks, ref_logits = _run_engine_sessions(ref_sys, jobs, n_new,
                                                coalesce=True)

    _, _, _, system = _build(arch, n_servers=4)
    sids = []
    for p, f in jobs:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(p, 0, route, n_new, frames=f))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    system.decode_round(sids)
    system.decode_round(sids)
    victim = system.sessions[sids[0]].route.servers[0]
    system.kill_server(victim)
    while any(system.sessions[s].n_generated < n_new for s in sids):
        system.decode_round(
            [s for s in sids if system.sessions[s].n_generated < n_new])
    for sid, ref in zip(sids, ref_toks):
        sess = system.sessions[sid]
        assert victim not in sess.route.servers
        assert list(sess.tokens) == ref, \
            "post-failover stream must equal the no-failure stream"
        system.retire_session(sid)


# ---------------------------------------------------------------------------
# Scheduler end-to-end + hybrid cross-validation
# ---------------------------------------------------------------------------


def test_encdec_chunked_billing_counts_enc_hops_once():
    """A chunked enc-dec prompt pays per-chunk protocol cost only on hops
    it actually traverses: encoder-only hops are traversed (and billed)
    exactly once, decoder hops once per chunk."""
    cfg = get_reduced_config("seamless_m4t_large_v2")
    params = _params_for(cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    # mem caps every server at 2 hosted blocks -> the first hop of any
    # route covers exactly the 2 encoder blocks (a pure-encoder hop)
    servers = [ServerSpec(j, mem_bytes=250.0, tau=0.01,
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005) for j in range(3)]
    rtt = np.full((1, 3), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, 4))
    system = GeoServingSystem(cfg, params, prob, R=2, max_new_tokens=4,
                              prefill_buckets=(4,), max_seq_len=16)
    rng = np.random.RandomState(9)
    toks = rng.randint(2, cfg.vocab_size, 7)  # chunks (0,4,4), (4,3,4)
    sid, _ = system.submit(toks, frames=_frames_for(cfg, rng, 5))
    sess = system.sessions[sid]
    n_enc = cfg.n_enc_layers
    expected = 0.0
    for off, span, _ in [(0, 4, 4), (4, 3, 4)]:
        e = 0
        for j, k in zip(sess.route.servers, sess.route.blocks):
            if max(e, n_enc) < e + k or off == 0:  # dec hop, or first round
                expected += (prob.rtt_prefill[0, j]
                             + k * prob.servers[j].tau_prefill(span))
            e += k
    assert e == cfg.n_layers
    assert sess.route.blocks[0] <= n_enc, "first hop must be encoder-only"
    np.testing.assert_allclose(sess.prefill_time, expected, rtol=1e-12)
    system.finish(sid)


def test_encdec_through_scheduler():
    cfg, _, _, system = _build("seamless_m4t_large_v2", mem=900.0,
                               l_out=5, max_new=5)
    sched = ContinuousBatchingScheduler(system, R=4)
    rng = np.random.RandomState(5)
    for rid in range(4):
        sched.submit(rid, rng.randint(2, cfg.vocab_size, 5), 0.0, n_new=5,
                     frames=_frames_for(cfg, rng, 6))
    served = sched.run()
    assert len(served) == 4 and not any(r.dropped for r in served)
    for used, cap in system.slot_usage().values():
        assert used == 0


@pytest.mark.parametrize("R", [4])
def test_engine_vs_simulator_hybrid_tolerance(R):
    """Same Poisson trace through the simulator (weighted eq. (1)) and the
    hybrid-stack engine: mean per-token and first-token times within 10%."""
    from benchmarks.engine_validation import cross_validate

    eng, simm, err = cross_validate(R, n_requests=6, rate=1.5, seed=1,
                                    arch="zamba2_7b")
    assert err["per_token_all"] < 0.10, (eng, simm)
    assert err["first_token"] < 0.10, (eng, simm)


# ---------------------------------------------------------------------------
# Sampling policies
# ---------------------------------------------------------------------------


def test_sampling_greedy_default_matches_argmax():
    cfg, params, _, system = _build("llama3_2_1b")
    rng = np.random.RandomState(6)
    toks = rng.randint(2, cfg.vocab_size, 5)
    sid, _ = system.submit(toks, sampling=SamplingSpec(kind="greedy"))
    sess = system.sessions[sid]
    while sess.n_generated < 5:
        system.decode_round([sid])
    got = list(sess.tokens[len(toks):])
    system.retire_session(sid)
    assert got == _monolithic_ref(cfg, params, toks, 5)


def test_sampling_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        SamplingSpec(kind="beam")
    with pytest.raises(ValueError, match="temperature"):
        SamplingSpec(kind="temperature", temperature=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingSpec(kind="top_k", top_k=0)


def test_sampling_seeded_deterministic_and_topk_support():
    cfg, params, _, system = _build("llama3_2_1b")
    rng = np.random.RandomState(7)
    toks = rng.randint(2, cfg.vocab_size, 5)
    spec = SamplingSpec(kind="top_k", temperature=0.8, top_k=3, seed=11)

    def run_once(sys_):
        sid, _ = sys_.submit(toks, sampling=spec)
        sess = sys_.sessions[sid]
        logits_hist = [np.asarray(sess.last_logits)]
        while sess.n_generated < 6:
            sys_.decode_round([sid])
            logits_hist.append(np.asarray(sess.last_logits))
        out = list(sess.tokens[len(toks):])
        sys_.retire_session(sid)
        return out, logits_hist

    out1, hist1 = run_once(system)
    _, _, _, system2 = _build("llama3_2_1b")
    out2, _ = run_once(system2)
    assert out1 == out2, "same (seed, token index) must draw the same stream"
    # every sampled token lies within the top-k of the logits it came from
    for tok, lg in zip(out1, hist1[:-1]):
        topk = set(np.argsort(lg)[-spec.top_k:])
        assert tok in topk, (tok, topk)


def test_sampling_solo_vs_grouped_identical():
    """The sampling key is a pure function of (seed, token index), so a
    stochastic session draws the identical stream alone or co-resident."""
    rng = np.random.RandomState(8)
    prompts = [rng.randint(2, 64, 4) for _ in range(3)]
    specs = [SamplingSpec(kind="temperature", temperature=0.7, seed=i)
             for i in range(3)]

    def run(coalesce):
        _, _, _, system = _build("llama3_2_1b")
        sids = []
        for p, sp in zip(prompts, specs):
            route, _ = shortest_path_route(system.problem,
                                           system.alive_placement(), 0)
            sids.append(system.create_session(p, 0, route, 5, sampling=sp))
        if coalesce:
            assert system.try_admit_sessions(sids) == sids
            system.drain_prefill()
            while any(system.sessions[s].n_generated < 5 for s in sids):
                system.decode_round(
                    [s for s in sids
                     if system.sessions[s].n_generated < 5])
            out = [list(system.sessions[s].tokens) for s in sids]
            for s in sids:
                system.retire_session(s)
            return out
        out = []
        for sid in sids:
            assert system.try_admit_session(sid)
            while system.sessions[sid].n_generated < 5:
                system.decode_round([sid])
            out.append(list(system.sessions[sid].tokens))
            system.retire_session(sid)
        return out

    assert run(False) == run(True)
