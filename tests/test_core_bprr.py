"""Core BPRR: Lemma 3.1 feasibility, performance models, CG-BP structure,
bounds, MILP optimality — including hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LLMSpec, Placement, Problem, ServerSpec, Workload,
                        capacity, cg_bp, cg_feasible_R, cg_upper_bound,
                        conservative_m, lower_bound, max_feasible_R,
                        petals_bp, petals_route, route_blocks,
                        route_feasible, route_per_token_time,
                        shortest_path_route)
from repro.core.milp import brute_force_bprr, solve_bprr_milp

SETTINGS = settings(max_examples=25, deadline=None)


def _problem(rng, L=5, n=4, C=2, mem_scale=6.0):
    llm = LLMSpec("t", L, block_bytes=4.0, cache_bytes_per_token=0.25)
    servers = [ServerSpec(j, mem_bytes=float(4.0 * (1 + rng.integers(1, int(mem_scale)))),
                          tau=float(0.05 + 0.3 * rng.random()))
               for j in range(n)]
    rtt = 0.02 + 0.3 * rng.random((C, n))
    return Problem(llm, servers, C, rtt, 4 * rtt, workload=Workload(2, 2))


@SETTINGS
@given(st.integers(0, 10_000))
def test_cg_bp_invariants(seed):
    rng = np.random.default_rng(seed)
    prob = _problem(rng)
    R = int(rng.integers(1, 6))
    pl, info = cg_bp(prob, R)
    m = conservative_m(prob, R)
    assert (pl.m == m).all()
    # conservative m: worst-case memory always feasible (line 1 rationale)
    worst = prob.s_m * pl.m + prob.s_c * R * pl.m
    assert (worst <= prob.mem() + 1e-9).all()
    # capacity (15) >= R whenever the server hosts blocks
    cap = capacity(prob, pl.m)
    assert (cap[pl.m > 0] >= R).all()
    # block ranges valid
    assert (pl.a >= 0).all() and (pl.a + pl.m <= prob.L).all()
    if info.feasible:
        # remark after Lemma 3.3: fastest K servers tile the blocks
        order = info.order
        e = 0
        for rank, j in enumerate(order[: info.K]):
            if pl.m[j] <= 0:
                continue
            if rank < info.K - 1:
                assert pl.a[j] == e
                e += pl.m[j]
            else:
                assert pl.a[j] == prob.L - pl.m[j]


@SETTINGS
@given(st.integers(0, 10_000))
def test_feasible_routes_and_bound(seed):
    rng = np.random.default_rng(seed)
    prob = _problem(rng)
    R = int(rng.integers(1, 5))
    pl, info = cg_bp(prob, R)
    if not info.feasible:
        assert not cg_feasible_R(prob, R) or pl.feasible_cover(prob.L)
        return
    ub = cg_upper_bound(prob, R)
    lb = lower_bound(prob)
    assert lb <= ub + 1e-9
    for c in range(prob.n_clients):
        route, cost = shortest_path_route(prob, pl, c)
        assert route is not None
        # Lemma 3.1 feasibility of the produced chain
        assert route_feasible(pl, prob.L, route.servers)
        assert sum(route.blocks) == prob.L
        t = route_per_token_time(prob, route, c)
        assert abs(t - cost) < 1e-9
        # Theorem 3.5: achieved per-token time within the bound
        assert t <= ub + 1e-9


@SETTINGS
@given(st.integers(0, 10_000))
def test_lemma31_random_chains(seed):
    rng = np.random.default_rng(seed)
    L, n = 6, 5
    a = rng.integers(0, L, n)
    m = np.minimum(rng.integers(1, L + 1, n), L - a)
    pl = Placement(a=a, m=m)
    perm = rng.permutation(n)[: rng.integers(1, n + 1)]
    chain = tuple(int(x) for x in perm)
    ok = route_feasible(pl, L, chain)
    # manual induction check (paper's proof)
    e = 0
    manual = True
    for j in chain:
        if not (m[j] > 0 and a[j] <= e <= a[j] + m[j] - 1):
            manual = False
            break
        e = a[j] + m[j]
    manual = manual and e == L
    assert ok == manual


def test_milp_matches_bruteforce():
    rng = np.random.default_rng(3)
    llm = LLMSpec("t", 3, block_bytes=4.0, cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=float(14 + 4 * rng.random()),
                          tau=float(0.1 + 0.2 * rng.random()))
               for j in range(3)]
    rtt = 0.05 + 0.2 * rng.random((2, 3))
    prob = Problem(llm, servers, 2, rtt, rtt * 5, workload=Workload(2, 1))
    reqs = [0, 1]
    res = solve_bprr_milp(prob, reqs)
    bf, _ = brute_force_bprr(prob, reqs)
    assert res.status == 0
    assert abs(res.objective - bf) < 1e-6
    for r, route in enumerate(res.routes):
        assert route_feasible(res.placement, prob.L, route.servers)


def test_fig5_suboptimality_example():
    """The paper's Fig. 5: CG-BPRR = L(t+tau) vs OPT = t + tau*L."""
    L, t, tau = 3, 1.0, 0.1
    s_c = 1.0
    llm = LLMSpec("toy", L, L * s_c, 0.0, cache_bytes_const=s_c)
    servers = [ServerSpec(j, (L + 1) * L * s_c, tau) for j in range(L * L)]
    prob = Problem(llm, servers, 1, np.full((1, L * L), t),
                   np.full((1, L * L), t))
    pl, info = cg_bp(prob, L * L)
    assert (pl.m == 1).all()
    route, _ = shortest_path_route(prob, pl, 0)
    assert abs(route_per_token_time(prob, route, 0) - L * (t + tau)) < 1e-9


def test_max_feasible_R_monotone():
    rng = np.random.default_rng(0)
    prob = _problem(rng, mem_scale=8)
    Rmax = max_feasible_R(prob)
    if Rmax >= 1:
        assert cg_feasible_R(prob, Rmax)
    assert not cg_feasible_R(prob, Rmax + 1)


def test_petals_route_feasible():
    rng = np.random.default_rng(1)
    prob = _problem(rng, n=5)
    pl = petals_bp(prob)
    if pl.feasible_cover(prob.L):
        route = petals_route(prob, pl, 0)
        assert route is not None
        assert route_feasible(pl, prob.L, route.servers)
