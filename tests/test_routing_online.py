"""Routing: DP optimality vs exhaustive search, jax == numpy, WS-RR waiting
(eq 20), and the online controller guarantees (Corollaries 3.6/3.7)."""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LLMSpec, OnlineBPRR, Problem, ServerSpec,
                        ServerState, Workload, cg_bp, edge_waiting_times,
                        jax_shortest_paths, route_blocks, route_feasible,
                        route_per_token_time, shortest_path_route, ws_rr)

SETTINGS = settings(max_examples=20, deadline=None)


def _problem(rng, L=4, n=4, C=2):
    llm = LLMSpec("t", L, block_bytes=4.0, cache_bytes_per_token=0.25)
    servers = [ServerSpec(j, mem_bytes=float(4 * rng.integers(2, 6)),
                          tau=float(0.05 + 0.3 * rng.random()))
               for j in range(n)]
    rtt = 0.02 + 0.3 * rng.random((C, n))
    return Problem(llm, servers, C, rtt, 4 * rtt, workload=Workload(2, 4))


def _all_feasible_chains(prob, pl):
    n = prob.n_servers
    for r in range(1, n + 1):
        for perm in itertools.permutations(range(n), r):
            if route_feasible(pl, prob.L, perm):
                yield perm


@SETTINGS
@given(st.integers(0, 10_000))
def test_dp_equals_exhaustive(seed):
    rng = np.random.default_rng(seed)
    prob = _problem(rng)
    pl, info = cg_bp(prob, 2)
    if not info.feasible:
        return
    for c in range(prob.n_clients):
        route, cost = shortest_path_route(prob, pl, c)
        best = min(
            route_per_token_time(prob, route_blocks(pl, ch), c)
            for ch in _all_feasible_chains(prob, pl))
        assert abs(cost - best) < 1e-9


@SETTINGS
@given(st.integers(0, 10_000))
def test_jax_routing_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    prob = _problem(rng)
    pl, info = cg_bp(prob, 2)
    if not info.feasible:
        return
    best, _ = jax_shortest_paths(prob, pl, l_max_weight=1.0)
    for c in range(prob.n_clients):
        _, cost = shortest_path_route(prob, pl, c)
        assert abs(float(best[c]) - cost) < 1e-4


def test_edge_waiting_eq20():
    """Hand-built instance checking eq (20) exactly."""
    llm = LLMSpec("t", 2, block_bytes=10.0, cache_bytes_per_token=1.0)
    # memory 26: m=2 blocks -> slots = (26 - 20)/s_c ; s_c = 2 tokens * 1.0
    prob = Problem(llm, [ServerSpec(0, 26.0, 0.1)], 1,
                   np.array([[0.01]]), np.array([[0.05]]),
                   workload=Workload(1, 1))
    pl, _ = cg_bp(prob, 1)
    assert pl.m[0] == 2
    # slots = floor((26 - 20)/2) = 3 block-slots
    # two active sessions, 2 blocks each -> 4/3 used?? -> only one fits
    states = {0: ServerState(remaining=[5.0, 9.0], blocks=[2, 2])}
    wait = edge_waiting_times(prob, pl, states)
    # new session needs k=2 blocks; free = 3-4 < 0... after first ends: 3-2=1,
    # after both end: 3 -> wait = 9.0
    assert wait[prob.n_servers, 0] == 9.0
    states = {0: ServerState(remaining=[5.0], blocks=[1])}
    wait = edge_waiting_times(prob, pl, states)
    assert wait[prob.n_servers, 0] == 0.0  # 3-1 = 2 >= 2 free now


def test_online_no_wait_within_R():
    """Corollary 3.6/3.7: concurrency <= R ⇒ zero waiting, and the
    completion time is within the guarantee (22)."""
    rng = np.random.default_rng(5)
    prob = _problem(rng, n=5)
    R = 3
    pl, info = cg_bp(prob, R)
    if not info.feasible:
        pytest.skip("infeasible random instance")
    ctl = OnlineBPRR(prob, R=R)
    ends = []
    for i in range(R):
        route, start, end, sid = ctl.admit(i % prob.n_clients, 0.0)
        assert route is not None
        assert start == 0.0, "no waiting while concurrency <= R"
        ends.append(end)
        assert end <= ctl.guarantee() + prob.workload.l_out * 1e-6 + \
            route_per_token_time(prob, route, i % prob.n_clients) * 0 + \
            ctl.guarantee()  # loose: end <= guarantee (22) since start=0
    # over-subscription may wait but must stay finite
    route, start, end, sid = ctl.admit(0, 0.0)
    assert route is not None and np.isfinite(start)


def test_online_elastic_replacement():
    rng = np.random.default_rng(6)
    prob = _problem(rng, n=5)
    ctl = OnlineBPRR(prob, R=2)
    old = ctl.placement
    # server 0 dies: zero memory
    import dataclasses

    servers = list(prob.servers)
    servers[0] = dataclasses.replace(servers[0], mem_bytes=0.0)
    prob2 = Problem(prob.llm, servers, prob.n_clients, prob.rtt_token,
                    prob.rtt_prefill, prob.workload)
    ctl.replace_servers(prob2)
    assert ctl.placement.m[0] == 0
    if ctl.placement.feasible_cover(prob.L):
        route, start, end, sid = ctl.admit(0, 0.0)
        assert 0 not in route.servers


def test_replace_servers_invalidates_route_cache():
    """Regression guard: ``replace_servers`` must REPLACE the memoized
    RouteCostCache.  The cache holds the routing graph, per-client edge
    costs, and the eq. (20) slot capacities — all functions of τ, memory
    and placement — so serving costs from a stale cache after churn would
    silently mis-route.  After churn, every memoized input must equal a
    cache built from scratch on the new problem."""
    import dataclasses

    from repro.core import RouteCostCache

    rng = np.random.default_rng(7)
    prob = _problem(rng, n=5)
    ctl = OnlineBPRR(prob, R=2)
    # warm the per-client memo on the old topology
    for c in range(prob.n_clients):
        ctl._route_cache.cost(c)
        ctl._route_cache.cost(c, avg_over_tokens=True)
    old_cache = ctl._route_cache

    # churn: every server doubles τ and gains memory (placement may move)
    servers = [dataclasses.replace(s, tau=s.tau * 2.0,
                                   mem_bytes=s.mem_bytes + 4.0)
               for s in prob.servers]
    prob2 = Problem(prob.llm, servers, prob.n_clients, prob.rtt_token,
                    prob.rtt_prefill, prob.workload)
    ctl.replace_servers(prob2)

    assert ctl._route_cache is not old_cache, "stale cache survived churn"
    fresh = RouteCostCache(ctl.problem, ctl.placement)
    np.testing.assert_array_equal(ctl._route_cache.total_slots,
                                  fresh.total_slots)
    for c in range(prob.n_clients):
        for avg in (False, True):
            np.testing.assert_array_equal(ctl._route_cache.cost(c, avg),
                                          fresh.cost(c, avg))
    # and the stale memo really is stale: doubled τ moved the edge costs
    assert not np.array_equal(old_cache.cost(0), fresh.cost(0))


def test_calibrated_problem_gets_fresh_route_cache():
    """Regression guard (PR 9): an ``OnlineBPRR`` built from the engine's
    ``calibrated_problem()`` — and one whose τ vector is swapped in via
    ``replace_servers`` — must serve edge costs computed from the
    CALIBRATED τ, not a memo warmed on the spec'd uniform τ.  The
    calibrated vector is what makes heterogeneous device groups matter to
    placement/routing, so a stale cache here silently reverts the system
    to uniform-τ decisions."""
    import jax

    from repro.core import RouteCostCache, with_server_taus
    from repro.configs import get_reduced_config
    from repro.models import init_params
    from repro.serving import GeoServingSystem

    cfg = get_reduced_config("llama3_2_1b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=1000.0, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005) for j in range(2)]
    rtt = np.full((1, 2), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, 4))
    system = GeoServingSystem(cfg, params, prob, R=2, max_new_tokens=4,
                              max_sessions=4)
    cal = system.calibrated_problem()
    assert not np.array_equal(cal.tau(), prob.tau())

    # fresh controller on the calibrated problem: memo belongs to cal
    ctl = OnlineBPRR(cal, R=2)
    fresh = RouteCostCache(ctl.problem, ctl.placement)
    np.testing.assert_array_equal(ctl._route_cache.cost(0), fresh.cost(0))
    assert not np.array_equal(ctl._route_cache.cost(0),
                              RouteCostCache(prob, ctl.placement).cost(0))

    # τ swap through replace_servers: the warmed uniform-τ memo must die
    ctl2 = OnlineBPRR(prob, R=2)
    stale = ctl2._route_cache
    stale.cost(0)
    stale.cost(0, True)  # warm both memo keys
    ctl2.replace_servers(cal)
    assert ctl2._route_cache is not stale, "stale cache survived τ swap"
    fresh2 = RouteCostCache(ctl2.problem, ctl2.placement)
    for avg in (False, True):
        np.testing.assert_array_equal(ctl2._route_cache.cost(0, avg),
                                      fresh2.cost(0, avg))
    assert not np.array_equal(stale.cost(0), fresh2.cost(0))
