"""Pallas kernel sweeps: shapes x dtypes vs pure oracles (interpret mode).

Assignment requirement: for each kernel, sweep shapes/dtypes and
assert_allclose against the ref.py pure-jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, decode_attention,
                           decode_attention_ref, flash_attention, ssd,
                           ssd_ref, wkv6, wkv6_ref)

TOLS = {jnp.float32: 5e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Kv,D,window", [
    (1, 64, 2, 2, 16, None),
    (2, 100, 4, 2, 16, None),  # GQA + ragged blocks
    (1, 96, 4, 1, 32, None),  # MQA
    (2, 80, 2, 2, 16, 24),  # sliding window
])
def test_flash_attention_sweep(dtype, B, S, H, Kv, D, window):
    rng = np.random.RandomState(hash((B, S, H)) % 1000)
    q = jnp.asarray(rng.randn(B, S, H, D), dtype) * 0.3
    k = jnp.asarray(rng.randn(B, S, Kv, D), dtype) * 0.3
    v = jnp.asarray(rng.randn(B, S, Kv, D), dtype) * 0.3
    out = flash_attention(q, k, v, causal=True, window=window, block_q=32,
                          block_kv=32, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    ref = attention_ref(qf, kf, vf, causal=True, window=window)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Kv,Dk,Dv,T,pos", [
    (2, 4, 2, 16, 16, 128, 100),
    (1, 8, 1, 24, 16, 200, 63),  # MLA-like: MQA with asymmetric K/V dims
    (2, 2, 2, 32, 32, 96, 95),
])
def test_decode_attention_sweep(dtype, B, H, Kv, Dk, Dv, T, pos):
    rng = np.random.RandomState(hash((B, H, T)) % 1000)
    q = jnp.asarray(rng.randn(B, 1, H, Dk), dtype) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, Dk), dtype) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, Dv), dtype) * 0.3
    out = decode_attention(q, ck, cv, pos, block_kv=64, interpret=True)
    G = H // Kv
    qf = q.reshape(B, Kv, G, Dk).reshape(B * Kv, G, Dk)
    kf = ck.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dk)
    vf = cv.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dv)
    ref = decode_attention_ref(qf, kf, vf, pos)
    ref = ref.reshape(B, Kv, G, Dv).reshape(B, 1, H, Dv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 37, 3, 8, 8),
    (1, 64, 2, 16, 16),
    (2, 20, 1, 8, 16),  # chunk > padded seq handled
])
def test_wkv6_sweep(dtype, B, S, H, hd, chunk):
    rng = np.random.RandomState(hash((B, S, H)) % 1000)
    r = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.4
    k = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.4
    v = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.4
    lw = jnp.clip(jnp.asarray(-np.exp(rng.randn(B, S, H, hd) * 0.5 - 1),
                              dtype), -5.0, -1e-4)
    u = jnp.asarray(rng.randn(H, hd), dtype) * 0.3
    out = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    to = lambda x: np.asarray(
        x.astype(jnp.float32)).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    uf = np.broadcast_to(np.asarray(u, np.float32)[None],
                         (B, H, hd)).reshape(B * H, hd)
    ref = wkv6_ref(to(r), to(k), to(v), to(lw), uf)
    ref = np.asarray(ref).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,S,H,p,n,chunk", [
    (2, 45, 3, 8, 4, 16),
    (1, 64, 2, 16, 8, 32),
    (1, 10, 1, 8, 4, 16),
])
def test_ssd_sweep(dtype, B, S, H, p, n, chunk):
    rng = np.random.RandomState(hash((B, S, p)) % 1000)
    x = jnp.asarray(rng.randn(B, S, H, p), dtype) * 0.4
    Bm = jnp.asarray(rng.randn(B, S, n), dtype) * 0.4
    Cm = jnp.asarray(rng.randn(B, S, n), dtype) * 0.4
    dt = jnp.asarray(np.abs(rng.randn(B, S, H)) * 0.5 + 0.1, dtype)
    A = jnp.asarray(-np.abs(rng.randn(H)) - 0.2, dtype)
    D = jnp.asarray(rng.randn(H), dtype)
    out = ssd(x, Bm, Cm, dt, A, D, chunk=chunk, interpret=True)
    xf = np.asarray(x, np.float32).transpose(0, 2, 1, 3).reshape(B * H, S, p)
    dtf = np.asarray(dt, np.float32).transpose(0, 2, 1).reshape(B * H, S)
    Af = np.broadcast_to(np.asarray(A, np.float32)[None], (B, H)).reshape(-1)
    Df = np.broadcast_to(np.asarray(D, np.float32)[None], (B, H)).reshape(-1)
    ref = ssd_ref(xf, np.asarray(Bm, np.float32), np.asarray(Cm, np.float32),
                  dtf, Af, Df)
    ref = np.asarray(ref).reshape(B, H, S, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               atol=1e-4, rtol=1e-3)


def test_ssd_kernel_matches_model_mamba():
    """The kernel and repro.models.ssm.apply_mamba_full agree through the
    full block math (same chunked formulation, different substrate)."""
    import jax

    from repro.configs import get_reduced_config
    from repro.models import NULL_SH
    from repro.models.ssm import apply_mamba_full, init_mamba

    cfg = get_reduced_config("zamba2_7b")
    params, _ = init_mamba(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 24, cfg.d_model), jnp.float32) * 0.2
    y_model, _ = apply_mamba_full(params, cfg, NULL_SH, x)
    assert np.isfinite(np.asarray(y_model)).all()
