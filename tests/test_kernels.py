"""Pallas kernel sweeps: shapes x dtypes x masking features vs pure oracles
(interpret mode).

For each kernel, sweep shapes/dtypes and assert_allclose against the
ref.py pure-jnp oracle — including every kernel<->oracle semantic gap the
pooled serving call sites exercise: per-row ``pos`` at mixed positions,
sliding-window + ALiBi masking, cross-attention ``kv_len``, chunked-prefill
``q_start``, MLA faithful scale, and carried recurrent state in/out.
Degenerate-grid regressions (T < block_kv; T % block_kv == 1 at
pos == T-1; fully-masked KV blocks under a small window) are pinned
explicitly, as are the ``*_unsupported`` dispatch guards.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_ref, decode_attention,
                           decode_attention_ref,
                           decode_attention_unsupported, flash_attention,
                           flash_attention_unsupported, ssd, ssd_ref, wkv6,
                           wkv6_ref)

TOLS = {jnp.float32: 5e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


def _gqa_flat(q, k, v):
    B, Sq, H, Dk = q.shape
    Kv, Dv = k.shape[2], v.shape[-1]
    Skv = k.shape[1]
    return (q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dk),
            k.transpose(0, 2, 1, 3).reshape(B * Kv, Skv, Dk),
            v.transpose(0, 2, 1, 3).reshape(B * Kv, Skv, Dv))


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Kv,D,window", [
    (1, 64, 2, 2, 16, None),
    (2, 100, 4, 2, 16, None),  # GQA + ragged blocks
    (1, 96, 4, 1, 32, None),  # MQA
    (2, 80, 2, 2, 16, 24),  # sliding window
])
def test_flash_attention_sweep(dtype, B, S, H, Kv, D, window):
    rng = np.random.RandomState(hash((B, S, H)) % 1000)
    q = jnp.asarray(rng.randn(B, S, H, D), dtype) * 0.3
    k = jnp.asarray(rng.randn(B, S, Kv, D), dtype) * 0.3
    v = jnp.asarray(rng.randn(B, S, Kv, D), dtype) * 0.3
    out = flash_attention(q, k, v, causal=True, window=window, block_q=32,
                          block_kv=32, interpret=True)
    qf, kf, vf = _gqa_flat(q, k, v)
    ref = attention_ref(qf, kf, vf, causal=True, window=window)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_small_window_fully_masked_blocks():
    """A kv block entirely outside the window must contribute exact zeros:
    NEG_INF is finite, so an unguarded exp(s - m) of an all-masked block
    would be 1 and corrupt the softmax denominator."""
    rng = np.random.RandomState(0)
    B, S, H, D = 1, 96, 2, 16
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    # window 4 << block_kv 16: for late queries, several mid blocks run
    # (below the causal diagonal) but are entirely window-masked
    out = flash_attention(q, k, v, causal=True, window=4, block_q=16,
                          block_kv=16, interpret=True)
    qf, kf, vf = _gqa_flat(q, k, v)
    ref = attention_ref(qf, kf, vf, causal=True, window=4)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_flash_attention_q_start_chunked_prefill():
    """Chunked prefill: the suffix chunk's queries over the full key range
    must equal the corresponding rows of the one-shot computation."""
    rng = np.random.RandomState(1)
    B, S, H, Kv, D = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
    full = flash_attention(q, k, v, causal=True, block_q=16, block_kv=16,
                           interpret=True)
    off = 32
    chunk = flash_attention(q[:, off:], k, v, causal=True, q_start=off,
                            block_q=16, block_kv=16, interpret=True)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(full[:, off:]),
                               atol=5e-5, rtol=5e-5)
    # and against the oracle with the same offset
    qf = q[:, off:].transpose(0, 2, 1, 3).reshape(B * H, S - off, D)
    _, kf, vf = _gqa_flat(q, k, v)
    ref = attention_ref(qf, kf, vf, causal=True, q_start=off)
    ref = ref.reshape(B, H, S - off, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_flash_attention_alibi_slopes():
    from repro.models.layers import alibi_slopes

    rng = np.random.RandomState(2)
    B, S, H, D = 2, 40, 4, 16
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    slopes = alibi_slopes(H)
    out = flash_attention(q, k, v, causal=True, slopes=slopes, block_q=16,
                          block_kv=16, interpret=True)
    qf, kf, vf = _gqa_flat(q, k, v)
    sl = np.broadcast_to(np.asarray(slopes)[None], (B, H)).reshape(B * H)
    ref = attention_ref(qf, kf, vf, causal=True, slopes=sl)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_flash_attention_non_causal_cross_shapes():
    """Cross-attention shape regime: Sq != Skv and Dv != Dk, non-causal."""
    rng = np.random.RandomState(3)
    B, Sq, Skv, H, Kv, Dk, Dv = 2, 7, 19, 4, 2, 16, 8
    q = jnp.asarray(rng.randn(B, Sq, H, Dk), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, Skv, Kv, Dk), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, Skv, Kv, Dv), jnp.float32) * 0.3
    out = flash_attention(q, k, v, causal=False, block_q=4, block_kv=8,
                          interpret=True)
    qf, kf, vf = _gqa_flat(q, k, v)
    ref = attention_ref(qf, kf, vf, causal=False)
    ref = ref.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_flash_attention_dynamic_traced_window():
    """gemma3's local:global pattern makes the window a traced per-layer
    scalar inside the scanned pooled step — the kernel takes it as a
    dynamic input, so one trace serves both local and global layers."""
    rng = np.random.RandomState(4)
    B, S, H, D = 1, 32, 2, 16
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3

    @jax.jit
    def scan_windows(q, k, v, wins):
        def body(_, w):
            return None, flash_attention(q, k, v, causal=True, window=w,
                                         block_q=8, block_kv=8,
                                         interpret=True)
        return jax.lax.scan(body, None, wins)[1]

    outs = scan_windows(q, k, v, jnp.asarray([5, 1 << 30]))
    qf, kf, vf = _gqa_flat(q, k, v)
    for i, w in enumerate((5, None)):
        ref = attention_ref(qf, kf, vf, causal=True, window=w)
        ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(ref),
                                   atol=5e-5, rtol=5e-5)


def test_flash_attention_guard_raises():
    assert flash_attention_unsupported() is None
    assert flash_attention_unsupported(causal=False) is None
    assert "window" in flash_attention_unsupported(causal=False, window=8)
    assert "q_start" in flash_attention_unsupported(causal=False, q_start=4)
    # non-causal ALiBi would bias from arange(Sq), not the caller's true
    # query positions — must fall back to XLA, not silently diverge
    assert "ALiBi" in flash_attention_unsupported(causal=False,
                                                  slopes=jnp.ones((2,)))
    assert flash_attention_unsupported(slopes=jnp.ones((2,))) is None
    q = jnp.zeros((1, 4, 2, 8))
    with pytest.raises(ValueError, match="window"):
        flash_attention(q, q[:, :, :2], q[:, :, :2], causal=False, window=8,
                        interpret=True)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


def _decode_flat(q, ck, cv):
    B, _, H, Dk = q.shape
    T, Kv = ck.shape[1], ck.shape[2]
    G = H // Kv
    return (q.reshape(B, Kv, G, Dk).reshape(B * Kv, G, Dk),
            ck.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dk),
            cv.transpose(0, 2, 1, 3).reshape(B * Kv, T, cv.shape[-1]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Kv,Dk,Dv,T,pos", [
    (2, 4, 2, 16, 16, 128, 100),
    (1, 8, 1, 24, 16, 200, 63),  # MLA-like: MQA with asymmetric K/V dims
    (2, 2, 2, 32, 32, 96, 95),
])
def test_decode_attention_sweep(dtype, B, H, Kv, Dk, Dv, T, pos):
    rng = np.random.RandomState(hash((B, H, T)) % 1000)
    q = jnp.asarray(rng.randn(B, 1, H, Dk), dtype) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, Dk), dtype) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, Dv), dtype) * 0.3
    out = decode_attention(q, ck, cv, pos, block_kv=64, interpret=True)
    G = H // Kv
    qf, kf, vf = _decode_flat(q, ck, cv)
    ref = decode_attention_ref(qf, kf, vf, pos)
    ref = ref.reshape(B, Kv, G, Dv).reshape(B, 1, H, Dv)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_per_row_pos():
    """Pooled cache rows decode at DIFFERENT positions — the scalar-pos
    kernel of old would mask every row at the same length."""
    rng = np.random.RandomState(5)
    B, H, Kv, D, T = 4, 4, 2, 16, 96
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    pos = jnp.asarray([3, 40, 77, 95])
    out = decode_attention(q, ck, cv, pos, block_kv=32, interpret=True)
    qf, kf, vf = _decode_flat(q, ck, cv)
    ref = decode_attention_ref(qf, kf, vf, jnp.repeat(pos, Kv))
    ref = ref.reshape(B, Kv, H // Kv, D).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)
    # per-row results equal the scalar-pos call row by row
    for i in range(B):
        solo = decode_attention(q[i: i + 1], ck[i: i + 1], cv[i: i + 1],
                                int(pos[i]), block_kv=32, interpret=True)
        np.testing.assert_array_equal(np.asarray(solo[0]), np.asarray(out[i]))


@pytest.mark.parametrize("window", [4, 24])
def test_decode_attention_sliding_window(window):
    """Sliding-window decode incl. blocks fully outside the window (the
    NEG_INF exp(0)=1 regression: unguarded, a fully window-masked block
    adds block_kv to the denominator)."""
    rng = np.random.RandomState(6)
    B, H, Kv, D, T = 2, 4, 2, 16, 96
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    pos = jnp.asarray([90, 50])
    out = decode_attention(q, ck, cv, pos, window=window, block_kv=16,
                           interpret=True)
    qf, kf, vf = _decode_flat(q, ck, cv)
    ref = decode_attention_ref(qf, kf, vf, jnp.repeat(pos, Kv),
                               window=window)
    ref = ref.reshape(B, Kv, H // Kv, D).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_decode_attention_alibi_slopes():
    from repro.models.layers import alibi_slopes

    rng = np.random.RandomState(7)
    B, H, Kv, D, T = 2, 4, 2, 16, 64
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    pos = jnp.asarray([63, 10])
    slopes = alibi_slopes(H)
    out = decode_attention(q, ck, cv, pos, slopes=slopes, block_kv=16,
                           interpret=True)
    qf, kf, vf = _decode_flat(q, ck, cv)
    G = H // Kv
    sl = np.broadcast_to(np.asarray(slopes).reshape(Kv, G)[None],
                         (B, Kv, G)).reshape(B * Kv, G)
    ref = decode_attention_ref(qf, kf, vf, jnp.repeat(pos, Kv), slopes=sl)
    ref = ref.reshape(B, Kv, G, D).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_decode_attention_cross_kv_len():
    """Enc-dec cross decode: non-causal over an over-allocated cache, per-
    row kv_len masks the invalid tail."""
    rng = np.random.RandomState(8)
    B, H, Kv, D, T = 3, 4, 2, 16, 40
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    kv_len = jnp.asarray([5, 17, 40])
    out = decode_attention(q, ck, cv, 0, causal=False, kv_len=kv_len,
                           block_kv=16, interpret=True)
    qf, kf, vf = _decode_flat(q, ck, cv)
    ref = decode_attention_ref(qf, kf, vf, jnp.zeros((B * Kv,), jnp.int32),
                               causal=False, kv_len=jnp.repeat(kv_len, Kv))
    ref = ref.reshape(B, Kv, H // Kv, D).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_decode_attention_mla_faithful_scale():
    """MLA absorbed decode scales by 1/sqrt(nope+rope), not the
    1/sqrt(lora+rope) that q_eff's width implies — the kernel takes the
    faithful scale directly where the XLA helper needs a q pre-scale."""
    rng = np.random.RandomState(9)
    B, H, lora, rope, nope, T = 2, 4, 24, 8, 16, 48
    q = jnp.asarray(rng.randn(B, 1, H, lora + rope), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, 1, lora + rope), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, 1, lora), jnp.float32) * 0.3
    scale = 1.0 / np.sqrt(nope + rope)
    out = decode_attention(q, ck, cv, T - 1, scale=scale, block_kv=16,
                           interpret=True)
    qf, kf, vf = _decode_flat(q, ck, cv)
    ref = decode_attention_ref(qf, kf, vf, T - 1, scale=scale)
    ref = ref.reshape(B, 1, H, lora)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


@pytest.mark.parametrize("T,block_kv", [
    (5, 64),  # T < block_kv: degenerate single-block grid
    (65, 16),  # T % block_kv == 1: one-position trailing block
    (33, 32),
])
def test_decode_attention_padding_regressions(T, block_kv):
    """pos == T-1 with ragged cache padding: the zero-padded tail must
    never leak into the softmax."""
    rng = np.random.RandomState(T)
    B, H, Kv, D = 2, 4, 2, 16
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    out = decode_attention(q, ck, cv, T - 1, block_kv=block_kv,
                           interpret=True)
    qf, kf, vf = _decode_flat(q, ck, cv)
    ref = decode_attention_ref(qf, kf, vf, T - 1)
    ref = ref.reshape(B, Kv, H // Kv, D).reshape(B, 1, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5,
                               rtol=5e-5)


def test_decode_attention_guard_raises():
    assert decode_attention_unsupported() is None
    assert decode_attention_unsupported(causal=False, kv_len=4) is None
    assert "window" in decode_attention_unsupported(causal=False, window=8)
    q = jnp.zeros((1, 1, 2, 8))
    c = jnp.zeros((1, 4, 2, 8))
    with pytest.raises(ValueError, match="window"):
        decode_attention(q, c, c, 0, causal=False, window=8, interpret=True)


# ---------------------------------------------------------------------------
# WKV6
# ---------------------------------------------------------------------------


def _wkv_flat(x):
    B, S, H, hd = x.shape
    return np.asarray(x.astype(jnp.float32)).transpose(0, 2, 1, 3).reshape(
        B * H, S, hd)


def _wkv_inputs(rng, B, S, H, hd, dtype=jnp.float32):
    r = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.4
    k = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.4
    v = jnp.asarray(rng.randn(B, S, H, hd), dtype) * 0.4
    lw = jnp.clip(jnp.asarray(-np.exp(rng.randn(B, S, H, hd) * 0.5 - 1),
                              dtype), -5.0, -1e-4)
    u = jnp.asarray(rng.randn(H, hd), dtype) * 0.3
    return r, k, v, lw, u


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (2, 37, 3, 8, 8),
    (1, 64, 2, 16, 16),
    (2, 20, 1, 8, 16),  # chunk > padded seq handled
])
def test_wkv6_sweep(B, S, H, hd, chunk):
    rng = np.random.RandomState(hash((B, S, H)) % 1000)
    r, k, v, lw, u = _wkv_inputs(rng, B, S, H, hd)
    out, state = wkv6(r, k, v, lw, u, chunk=chunk, interpret=True)
    uf = np.broadcast_to(np.asarray(u, np.float32)[None],
                         (B, H, hd)).reshape(B * H, hd)
    ref, ref_state = wkv6_ref(_wkv_flat(r), _wkv_flat(k), _wkv_flat(v),
                              _wkv_flat(lw), uf)
    ref = np.asarray(ref).reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(state).reshape(B * H, hd, hd), np.asarray(ref_state),
        atol=1e-4, rtol=1e-3)


def test_wkv6_carried_state_resume():
    """Splitting a sequence and carrying the state across the split must
    reproduce the one-shot run — the contract that lets the kernel serve
    the pooled recurrent state (and chunked resume)."""
    rng = np.random.RandomState(10)
    B, S, H, hd, cut = 2, 26, 2, 8, 11  # ragged halves (pad exercised)
    r, k, v, lw, u = _wkv_inputs(rng, B, S, H, hd)
    out_full, s_full = wkv6(r, k, v, lw, u, chunk=8, interpret=True)
    o1, s1 = wkv6(r[:, :cut], k[:, :cut], v[:, :cut], lw[:, :cut], u,
                  chunk=8, interpret=True)
    o2, s2 = wkv6(r[:, cut:], k[:, cut:], v[:, cut:], lw[:, cut:], u, s1,
                  chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(out_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# SSD (Mamba2)
# ---------------------------------------------------------------------------


def _ssd_inputs(rng, B, S, H, p, n, dtype=jnp.float32):
    x = jnp.asarray(rng.randn(B, S, H, p), dtype) * 0.4
    Bm = jnp.asarray(rng.randn(B, S, n), dtype) * 0.4
    Cm = jnp.asarray(rng.randn(B, S, n), dtype) * 0.4
    dt = jnp.asarray(np.abs(rng.randn(B, S, H)) * 0.5 + 0.1, dtype)
    A = jnp.asarray(-np.abs(rng.randn(H)) - 0.2, dtype)
    D = jnp.asarray(rng.randn(H), dtype)
    return x, Bm, Cm, dt, A, D


def _ssd_ref_args(x, Bm, Cm, dt, A, D):
    B, S, H, p = x.shape
    xf = np.asarray(x, np.float32).transpose(0, 2, 1, 3).reshape(B * H, S, p)
    dtf = np.asarray(dt, np.float32).transpose(0, 2, 1).reshape(B * H, S)
    Af = np.broadcast_to(np.asarray(A, np.float32)[None], (B, H)).reshape(-1)
    Df = np.broadcast_to(np.asarray(D, np.float32)[None], (B, H)).reshape(-1)
    return xf, np.asarray(Bm, np.float32), np.asarray(Cm, np.float32), \
        dtf, Af, Df


@pytest.mark.parametrize("B,S,H,p,n,chunk", [
    (2, 45, 3, 8, 4, 16),
    (1, 64, 2, 16, 8, 32),
    (1, 10, 1, 8, 4, 16),
])
def test_ssd_sweep(B, S, H, p, n, chunk):
    rng = np.random.RandomState(hash((B, S, p)) % 1000)
    x, Bm, Cm, dt, A, D = _ssd_inputs(rng, B, S, H, p, n)
    out, state = ssd(x, Bm, Cm, dt, A, D, chunk=chunk, interpret=True)
    ref, ref_state = ssd_ref(*_ssd_ref_args(x, Bm, Cm, dt, A, D))
    ref = np.asarray(ref).reshape(B, H, S, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(state).reshape(B * H, p, n), np.asarray(ref_state),
        atol=1e-4, rtol=1e-3)


def test_ssd_carried_state_resume():
    rng = np.random.RandomState(11)
    B, S, H, p, n, cut = 2, 30, 2, 8, 4, 13
    x, Bm, Cm, dt, A, D = _ssd_inputs(rng, B, S, H, p, n)
    out_full, s_full = ssd(x, Bm, Cm, dt, A, D, chunk=8, interpret=True)
    o1, s1 = ssd(x[:, :cut], Bm[:, :cut], Cm[:, :cut], dt[:, :cut], A, D,
                 chunk=8, interpret=True)
    o2, s2 = ssd(x[:, cut:], Bm[:, cut:], Cm[:, cut:], dt[:, cut:], A, D,
                 s1, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(out_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-3)


def test_ssd_kernel_matches_model_mamba():
    """The kernel and repro.models.ssm.apply_mamba_full agree through the
    full block math (same chunked formulation, different substrate)."""
    import jax

    from repro.configs import get_reduced_config
    from repro.models import NULL_SH
    from repro.models.ssm import apply_mamba_full, init_mamba

    cfg = get_reduced_config("zamba2_7b")
    params, _ = init_mamba(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 24, cfg.d_model), jnp.float32) * 0.2
    y_xla, st_xla = apply_mamba_full(params, cfg, NULL_SH, x)
    y_pl, st_pl = apply_mamba_full(params, cfg, NULL_SH, x,
                                   backend="pallas")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_xla),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_pl["ssm"]),
                               np.asarray(st_xla["ssm"]), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(st_pl["conv"]),
                                  np.asarray(st_xla["conv"]))


def test_rwkv_tm_backends_agree():
    """apply_rwkv_tm_full routes the recurrence through the wkv6 kernel on
    the pallas backend; outputs and carried state match the jnp path."""
    import jax

    from repro.configs import get_reduced_config
    from repro.models import NULL_SH
    from repro.models.ssm import apply_rwkv_tm_full, init_rwkv_tm

    cfg = get_reduced_config("rwkv6_7b")
    params = init_rwkv_tm(jax.random.PRNGKey(0), cfg)[0]
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2, 19, cfg.d_model), jnp.float32) * 0.2
    y_xla, st_xla = apply_rwkv_tm_full(params, cfg, NULL_SH, x)
    y_pl, st_pl = apply_rwkv_tm_full(params, cfg, NULL_SH, x,
                                     backend="pallas")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_xla),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_pl["wkv"]),
                               np.asarray(st_xla["wkv"]), atol=1e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# Runtime knobs
# ---------------------------------------------------------------------------


def test_runtime_interpret_env_override(monkeypatch):
    from repro.kernels.runtime import default_interpret, resolve_backend

    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "false")
    assert default_interpret() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert default_interpret() is (jax.default_backend() != "tpu")
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError, match="pallas"):
        resolve_backend("cuda")
