"""Device-resident decode rounds: per-round dispatch contract (ONE embed,
ONE fused lm_head+sample tail, one fused gather+step+scatter per
(hop, server)), donation safety of the pooled cache trees, and
round-for-round parity of the fused path against the pre-refactor
``decode_mode="serial"`` reference on decoder / rwkv / hybrid / enc-dec
scenarios — tokens and the virtual clock identical, logits to float-ulp
(the fused tail's round-width GEMM may order per-row reductions
differently than the width-1 reference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                        shortest_path_route)
from repro.models import init_params
from repro.serving import GeoServingSystem, SamplingSpec

# fused round tail vs per-session reference lm_head: same values up to the
# GEMM-width reduction order — a few float32 ulps on these scales
LOGIT_TOL = dict(atol=5e-6, rtol=1e-4)

_PARAMS_CACHE = {}


def _params_for(cfg):
    if cfg.name not in _PARAMS_CACHE:
        _PARAMS_CACHE[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)[0]
    return _PARAMS_CACHE[cfg.name]


def _build(arch, decode_mode, n_servers=2, max_new=4, cache_layout="slab",
           page_size=None):
    cfg = get_reduced_config(arch)
    params = _params_for(cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=1000.0, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3,
                   workload=Workload(4, max_new))
    system = GeoServingSystem(cfg, params, prob, algorithm="proposed", R=2,
                              max_new_tokens=max_new, max_sessions=4,
                              decode_mode=decode_mode,
                              cache_layout=cache_layout, page_size=page_size)
    return cfg, system


# one scenario per state family: decoder / recurrent / hybrid / enc-dec —
# shared by the fused-vs-serial and the paged-vs-slab parity matrices
FAMILY_SCENARIOS = [
    ("llama3_2_1b", (4, 6, 5), None),       # decoder (mixed positions)
    ("rwkv6_7b", (4, 6, 4), None),          # recurrent pools
    ("zamba2_7b", (4, 6), None),            # hybrid (emb0 threading)
    ("seamless_m4t_large_v2", (4, 6, 5), (5, 8, 5)),  # enc-dec (cross-KV)
]


def _jobs_for(cfg, lengths, enc_lens=None, seed=0):
    rng = np.random.RandomState(seed)
    jobs = []
    for i, n in enumerate(lengths):
        frames = None
        if cfg.is_enc_dec:
            frames = rng.randn(enc_lens[i], cfg.frame_dim).astype(np.float32)
        jobs.append((rng.randint(2, cfg.vocab_size, n), frames))
    return jobs


def _admit(system, jobs, n_new, sampling=None):
    sids = []
    for prompt, frames in jobs:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(prompt, 0, route, n_new,
                                          frames=frames, sampling=sampling))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    return sids


def _serve(system, jobs, n_new, sampling=None):
    """Admit as one batch, decode to completion round for round.  Returns
    (token lists, per-round logits histories, virtual times)."""
    sids = _admit(system, jobs, n_new, sampling=sampling)
    hist = {sid: [np.asarray(system.sessions[sid].last_logits)]
            for sid in sids}
    while True:
        todo = [s for s in sids if system.sessions[s].n_generated < n_new]
        if not todo:
            break
        system.decode_round(todo)
        for sid in todo:
            hist[sid].append(np.asarray(system.sessions[sid].last_logits))
    toks = [list(system.sessions[s].tokens) for s in sids]
    vts = [float(system.sessions[s].virtual_time) for s in sids]
    for sid in sids:
        system.retire_session(sid)
    return toks, [hist[s] for s in sids], vts


# ---------------------------------------------------------------------------
# Fused vs pre-refactor reference: round-for-round equality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,lengths,enc_lens", FAMILY_SCENARIOS)
def test_fused_matches_serial_reference(arch, lengths, enc_lens):
    """Token streams and virtual-clock accounting must be IDENTICAL between
    the device-resident rounds and the pre-refactor per-session reference,
    round for round; logits agree to float-ulp."""
    results = {}
    for mode in ("fused", "serial"):
        cfg, system = _build(arch, mode)
        jobs = _jobs_for(cfg, lengths, enc_lens=enc_lens)
        results[mode] = _serve(system, jobs, n_new=4)
    toks_f, hist_f, vt_f = results["fused"]
    toks_s, hist_s, vt_s = results["serial"]
    assert toks_f == toks_s, f"{arch}: fused tokens diverge from reference"
    assert vt_f == vt_s, f"{arch}: virtual clock diverges"
    for hf, hs in zip(hist_f, hist_s):
        assert len(hf) == len(hs) == 4
        for a, b in zip(hf, hs):
            np.testing.assert_allclose(a, b, **LOGIT_TOL)


# ---------------------------------------------------------------------------
# Paged vs slab layout: the exact-reference-twin contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,lengths,enc_lens", FAMILY_SCENARIOS)
@pytest.mark.parametrize("mode", ["fused", "serial"])
def test_paged_matches_slab(arch, lengths, enc_lens, mode):
    """cache_layout="paged" must be BIT-exact against the slab reference —
    tokens, logits, and the virtual clock — for every state family, both
    decode modes, grouped and solo.  The paged step only re-indexes the
    self-KV time axis through the page table around the UNCHANGED step
    body, so any divergence is an aliasing/indexing bug, not float noise."""
    results = {}
    for layout in ("slab", "paged"):
        cfg, system = _build(arch, mode, cache_layout=layout, page_size=2)
        jobs = _jobs_for(cfg, lengths, enc_lens=enc_lens)
        grouped = _serve(system, jobs, n_new=4)
        solo = [_serve(system, [job], n_new=4) for job in jobs]
        results[layout] = (grouped, solo)
    (toks_s, hist_s, vt_s), solo_s = results["slab"]
    (toks_p, hist_p, vt_p), solo_p = results["paged"]
    assert toks_p == toks_s, f"{arch}/{mode}: paged tokens diverge"
    assert vt_p == vt_s, f"{arch}/{mode}: paged virtual clock diverges"
    for hp, hs in zip(hist_p, hist_s):
        for a, b in zip(hp, hs):
            np.testing.assert_array_equal(a, b)  # bit-for-bit
    for (tp, _, vp), (ts, _, vs) in zip(solo_p, solo_s):
        assert tp == ts and vp == vs, f"{arch}/{mode}: solo diverges"


def test_fused_matches_serial_stochastic_sampling():
    """The fused tail derives PRNG keys on device from raw (seed, index)
    rows — the streams must equal the host-side ``key_for`` reference,
    across the full uint32 seed range (seeds >= 2**31 ride the round's
    uint32 buffer; wider seeds are rejected at spec construction)."""
    with pytest.raises(ValueError, match="seed"):
        SamplingSpec(kind="temperature", seed=2 ** 32)
    spec = SamplingSpec(kind="top_k", temperature=0.7, top_k=12,
                        seed=2 ** 31 + 13)
    results = {}
    for mode in ("fused", "serial"):
        cfg, system = _build("llama3_2_1b", mode, max_new=6)
        results[mode] = _serve(system, _jobs_for(cfg, (4, 6)), n_new=6,
                               sampling=spec)
    assert results["fused"][0] == results["serial"][0]


def test_fused_failover_matches_reference():
    """Failover mid-generation on the fused path: lazy hop records must
    replay to the exact no-failure streams."""
    cfg, ref = _build("llama3_2_1b", "serial", n_servers=4, max_new=6)
    jobs = _jobs_for(cfg, (4, 5))
    toks_ref, _, _ = _serve(ref, jobs, n_new=6)

    cfg, system = _build("llama3_2_1b", "fused", n_servers=4, max_new=6)
    sids = _admit(system, jobs, n_new=6)
    system.decode_round(sids)
    victim = system.sessions[sids[0]].route.servers[0]
    system.kill_server(victim)
    while any(system.sessions[s].n_generated < 6 for s in sids):
        system.decode_round(
            [s for s in sids if system.sessions[s].n_generated < 6])
    for sid, ref_toks in zip(sids, toks_ref):
        assert victim not in system.sessions[sid].route.servers
        assert list(system.sessions[sid].tokens) == ref_toks


# ---------------------------------------------------------------------------
# Per-round dispatch contract
# ---------------------------------------------------------------------------


def test_one_embed_one_tail_dispatch_per_round():
    """Exactly ONE embed dispatch and ONE lm_head+sample dispatch per
    decode round, however many sessions share it — counted both by the
    engine's own round_stats and by wrapping the jitted callables."""
    cfg, system = _build("llama3_2_1b", "fused", max_new=5)
    sids = _admit(system, _jobs_for(cfg, (4, 6, 5)), n_new=5)

    calls = {"embed": 0, "tail": 0}
    orig_embed, orig_tail = system._embed, system._round_tail

    def counting_embed(*a, **k):
        calls["embed"] += 1
        return orig_embed(*a, **k)

    def counting_tail(*a, **k):
        calls["tail"] += 1
        return orig_tail(*a, **k)

    system._embed = counting_embed
    system._round_tail = counting_tail
    base = dict(system.round_stats)
    n_rounds = 4
    for _ in range(n_rounds):
        out = system.decode_round(sids)
        assert len(out) == len(sids)
    assert calls == {"embed": n_rounds, "tail": n_rounds}
    assert system.round_stats["rounds"] - base["rounds"] == n_rounds
    assert (system.round_stats["embed_dispatches"]
            - base["embed_dispatches"]) == n_rounds
    assert (system.round_stats["tail_dispatches"]
            - base["tail_dispatches"]) == n_rounds
    # one fused gather+step+scatter per (hop, server) per round
    hops = len(system.sessions[sids[0]].route.servers)
    assert (system.round_stats["hop_dispatches"]
            - base["hop_dispatches"]) == n_rounds * hops


def test_solo_and_grouped_share_one_round_program():
    """The fixed round width makes solo == grouped structural on the fused
    path: per-session tokens AND logits are bit-for-bit identical."""
    jobs_all = None
    results = {}
    for tag, solo in (("grouped", False), ("solo", True)):
        cfg, system = _build("llama3_2_1b", "fused", max_new=5)
        jobs_all = _jobs_for(cfg, (4, 6, 5))
        if solo:
            toks, hist = [], []
            for job in jobs_all:
                t, h, _ = _serve(system, [job], n_new=5)
                toks += t
                hist += h
        else:
            toks, hist, _ = _serve(system, jobs_all, n_new=5)
        results[tag] = (toks, hist)
    assert results["solo"][0] == results["grouped"][0]
    for hs, hg in zip(results["solo"][1], results["grouped"][1]):
        for a, b in zip(hs, hg):
            np.testing.assert_array_equal(a, b)  # bit-for-bit


# ---------------------------------------------------------------------------
# Donation safety
# ---------------------------------------------------------------------------


def _pool_leaves(system):
    return {j: jax.tree.leaves(srv.pool.tree)
            for j, srv in system.servers.items()}


def test_donated_pool_never_reread():
    """The pooled steps donate their cache trees: after a round, every
    pre-round pool leaf is DEAD (the step consumed its buffer in place).
    The engine must keep decoding correctly afterwards — i.e. it rebound
    every pool reference and never touches the poisoned tree."""
    cfg, system = _build("llama3_2_1b", "fused", max_new=6)
    sids = _admit(system, _jobs_for(cfg, (4, 6)), n_new=6)
    before = _pool_leaves(system)
    system.decode_round(sids)
    donated = [leaf for leaves in before.values() for leaf in leaves
               if leaf.is_deleted()]
    assert donated, "decode round must donate the pool trees"
    # the old tree is poison: any read must raise, not return stale data
    dead = donated[0]
    with pytest.raises(RuntimeError, match="deleted"):
        _ = dead + 0
    # and the engine keeps producing the reference stream on the NEW pools
    cfg, ref = _build("llama3_2_1b", "serial", max_new=6)
    toks_ref, _, _ = _serve(ref, _jobs_for(cfg, (4, 6)), n_new=6)
    while any(system.sessions[s].n_generated < 6 for s in sids):
        system.decode_round(sids)
    assert [list(system.sessions[s].tokens) for s in sids] == toks_ref


def test_donated_prefill_pool_never_reread():
    """The batched prefill step donates too: admitting a bucket group kills
    the pre-prefill pool leaves."""
    cfg, system = _build("llama3_2_1b", "fused", max_new=4)
    before = _pool_leaves(system)
    _admit(system, _jobs_for(cfg, (4, 6)), n_new=4)
    assert any(leaf.is_deleted() for leaves in before.values()
               for leaf in leaves), "pooled prefill must donate the pool"


def test_stale_tree_reuse_raises():
    """Holding a pool tree across a donated step and calling again with it
    is a contract violation — jax must refuse loudly (this is what makes
    'a donated pool is never re-read' testable rather than silent)."""
    cfg, system = _build("llama3_2_1b", "fused", max_new=4)
    sids = _admit(system, _jobs_for(cfg, (4,)), n_new=4)
    srv = next(iter(system.servers.values()))
    stale = srv.pool.tree
    system.decode_round(sids)  # donates `stale`, rebinds pool.tree
    N, d = srv.pool.n_rows, cfg.d_model
    # RuntimeError when jax trips on the dead array while tracing;
    # ValueError (invalid buffer) when the program was already compiled
    with pytest.raises((RuntimeError, ValueError), match="deleted"):
        srv._step(srv.run_params, srv.shared, stale,
                  jnp.zeros((N, 1, d), jnp.float32),
                  jnp.zeros((N,), jnp.int32), srv._dummy, srv._zero_encl,
                  jnp.zeros((srv.m, N), bool), srv.layer_ids)


def test_retirement_and_readmission_after_donation():
    """Slot bookkeeping survives donated pools: retire a cohort, admit a
    fresh one, streams match a fresh engine."""
    cfg, system = _build("llama3_2_1b", "fused", max_new=4)
    jobs1 = _jobs_for(cfg, (4, 6), seed=0)
    jobs2 = _jobs_for(cfg, (5, 4), seed=1)
    _serve(system, jobs1, n_new=4)
    got, _, _ = _serve(system, jobs2, n_new=4)
    cfg, fresh = _build("llama3_2_1b", "fused", max_new=4)
    want, _, _ = _serve(fresh, jobs2, n_new=4)
    assert got == want
    for used, cap in system.slot_usage().values():
        assert used == 0
