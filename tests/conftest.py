"""Shared test fixtures / shims.

``hypothesis`` is an optional dev dependency (declared in
requirements-dev.txt).  When it is missing we install a tiny API-compatible
fallback into ``sys.modules`` *before* test collection so the property tests
in test_core_bprr.py / test_routing_online.py / test_simulator.py still
collect and run: ``@given(st.integers(a, b))`` draws a fixed number of
deterministic pseudo-random examples per test instead of hypothesis' guided
search.  With real hypothesis installed the shim is inert.
"""
from __future__ import annotations

import os
import sys

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401

    # bounded CI profile: the property suites cap their example budget so
    # the engine-bench-smoke job stays fast (select with
    # HYPOTHESIS_PROFILE=ci; the default profile is untouched locally)
    hypothesis.settings.register_profile(
        "ci", max_examples=int(os.environ.get("REPRO_CI_EXAMPLES", "20")),
        deadline=None)
    if os.environ.get("HYPOTHESIS_PROFILE"):
        hypothesis.settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
except ImportError:  # build the minimal fallback
    import random
    import types

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        """A strategy is just a draw(rng) callable."""

        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value=0, max_value=1 << 30):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def _settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        """Returns a decorator stamping the example budget on the test."""

        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn

        return deco

    def _given(*strategies, **kw_strategies):
        def deco(fn):
            # NOTE: no functools.wraps — pytest must see a ZERO-argument
            # signature (the drawn values are not fixtures).
            def run():
                n = getattr(run, "_shim_max_examples", _DEFAULT_EXAMPLES)
                if os.environ.get("HYPOTHESIS_PROFILE") == "ci":
                    # mirror the real-hypothesis "ci" profile's bound
                    n = min(n, int(os.environ.get("REPRO_CI_EXAMPLES", "20")))
                rng = random.Random(0xB9A11)
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*drawn, **kw)

            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            run.hypothesis_shim = True
            return run

        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    mod.assume = lambda cond: None
    mod.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.booleans = _booleans
    st_mod.sampled_from = _sampled_from

    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
