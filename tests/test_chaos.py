"""Chaos harness: deterministic fault injection through the serving engine.

The properties under test (docs/concurrency.md "Failure model"):

* **Fault-free-twin exactness** — greedy token streams are bit-equal to a
  run without faults, for affected sessions (failover replay rebuilds
  bit-identical caches) AND unaffected ones (which must also keep their
  exact virtual clock).
* **Session conservation** — every admitted session ends served or failed
  with a machine-readable reason; nothing vanishes.
* **Billed recovery** — a crash costs its victims timeout detection,
  backoff probes, and replay compute on the virtual clock, so the faulted
  clock is strictly greater than the twin's.
* **Typed capacity failures** — a failover with no free slots defers (and
  later completes) instead of hard-failing.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, Route, RouteCostCache, ServerSpec,
                        Workload, route_per_token_time, route_prefill_time)
from repro.models import init_params
from repro.serving import (FailureDetector, FaultEvent, FaultPlan,
                           GeoServingSystem, NoCapacityError)
from repro.serving.faults import recovery_replay_cost
from repro.sim import fault_schedule, simulate_faults
from repro.sim.workload import poisson_requests

ARCH = "llama3_2_1b"


def _build(n_servers=8, mem=900.0, l_in=4, l_out=10, max_new=10,
           max_sessions=12, R=4, fault_plan=None, detector=None, **kw):
    cfg = get_reduced_config(ARCH)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=50.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=mem, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3,
                   workload=Workload(l_in, l_out))
    system = GeoServingSystem(cfg, params, prob, R=R,
                              max_new_tokens=max_new,
                              max_sessions=max_sessions,
                              fault_plan=fault_plan, detector=detector,
                              **kw)
    return cfg, prob, system


def _single_hop_route(system, j) -> Route:
    a, m = int(system.placement.a[j]), int(system.placement.m[j])
    assert a == 0 and m == system.problem.L, "toy placement must replicate"
    return Route(servers=(int(j),), blocks=(m,))


def _admit_on(system, cfg, host_servers, n_new, seed=0):
    """One session per entry of ``host_servers``, each on its own
    single-hop route (so faults on server j hit exactly session j)."""
    rng = np.random.RandomState(seed)
    sids = []
    for j in host_servers:
        sids.append(system.create_session(
            rng.randint(2, cfg.vocab_size, system.problem.workload.l_in),
            0, _single_hop_route(system, j), n_new))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    return sids


def _drive(system, sids, n_new, max_rounds=400):
    """Decode rounds until every session leaves (done/failed), retiring
    finished sessions eagerly so their stalled clocks never gate
    virtual-clock fault delivery.  Returns {sid: retired session}."""
    out = {}
    rounds = 0
    while True:
        livesids = [s for s in sids if s not in out]
        for sid in livesids:
            sess = system.sessions[sid]
            if sess.state == "failed" or sess.n_generated >= n_new:
                out[sid] = system.retire_session(sid)
        if len(out) == len(sids):
            return out
        system.decode_round()
        rounds += 1
        assert rounds < max_rounds, "chaos run did not converge (livelock?)"


# ---------------------------------------------------------------------------
# The ISSUE acceptance scenario: 8 servers, >=3 crashes + rejoin + straggler
# ---------------------------------------------------------------------------


def test_chaos_acceptance_8_servers():
    cfg, prob, twin_sys = _build()
    hosts = [0, 1, 2, 3, 4, 5]
    n_new = 10

    # fault-free twin first: its per-session clocks are the oracle
    twin_sids = _admit_on(twin_sys, cfg, hosts, n_new)
    twin = _drive(twin_sys, twin_sids, n_new)

    # fault times on the virtual clock: after every victim has decoded a
    # couple of rounds, before anyone finishes (analytic eq. (1) paces)
    pre = {j: route_prefill_time(prob, Route((j,), (prob.L,)), 0)
           for j in hosts}
    ptok = {j: route_per_token_time(prob, Route((j,), (prob.L,)), 0)
            for j in hosts}
    T = max(pre[j] + 1.2 * ptok[j] for j in (1, 2, 3))
    plan = FaultPlan((
        FaultEvent(T, "crash", 1),
        FaultEvent(T, "crash", 2),
        FaultEvent(T, "crash", 3),
        FaultEvent(T + 0.1, "rejoin", 2),
        FaultEvent(T, "straggler_start", 4, factor=4.0),
        FaultEvent(T + 2.0 * ptok[4], "straggler_end", 4),
    ))
    assert plan.count("crash") >= 3 and plan.count("rejoin") >= 1
    assert plan.count("straggler_start") >= 1

    _, _, system = _build(fault_plan=plan)
    sids = _admit_on(system, cfg, hosts, n_new)
    done = _drive(system, sids, n_new)

    # session conservation: served, or failed with a machine-readable reason
    for sid, sess in done.items():
        assert sess.state in ("done", "failed")
        if sess.state == "failed":
            assert sess.fail_reason is not None
    # this topology always has a surviving chain: everyone serves
    assert all(s.state == "done" for s in done.values())

    # fault-free-twin token exactness, affected sessions included (replay
    # rebuilds bit-identical caches; greedy decoding is route-independent)
    for ts, fs in zip(twin_sids, sids):
        assert list(done[fs].tokens) == list(twin[ts].tokens)

    # unaffected sessions (hosts 0 and 5) keep the EXACT twin clock;
    # crash victims and the straggler's session pay strictly more
    by_host = dict(zip(hosts, sids))
    twin_by_host = dict(zip(hosts, twin_sids))
    for j in (0, 5):
        assert done[by_host[j]].virtual_time == \
            twin[twin_by_host[j]].virtual_time
        assert done[by_host[j]].recovery_time == 0.0
    for j in (1, 2, 3):
        sess = done[by_host[j]]
        assert sess.n_detections >= 1 and sess.n_replays >= 1
        assert sess.detect_time > 0 and sess.backoff_time > 0
        assert sess.replay_time > 0
        assert sess.virtual_time > twin[twin_by_host[j]].virtual_time
        # the crashed host is out of the spliced route
        assert j not in sess.route.servers
    assert done[by_host[4]].virtual_time > \
        twin[twin_by_host[4]].virtual_time  # straggled rounds cost more

    # aggregate clock strictly greater than the fault-free twin's
    assert sum(s.virtual_time for s in done.values()) > \
        sum(s.virtual_time for s in twin.values())

    # rejoin happened and left suspicion behind (flap avoidance)
    assert system.round_stats["rejoins"] >= 1
    assert system.servers[2].alive and not system.servers[2].crashed
    assert set(system.suspected_servers()) >= {1, 3}
    assert system.round_stats["detections"] >= 3
    assert system.round_stats["replays"] >= 3
    assert system.round_stats["detect_s"] > 0
    assert system.round_stats["backoff_s"] > 0
    assert system.round_stats["replay_s"] > 0

    # nothing leaked
    for used, _cap in system.slot_usage().values():
        assert used == 0


# ---------------------------------------------------------------------------
# Randomized chaos (hypothesis; bounded under HYPOTHESIS_PROFILE=ci)
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_randomized_fault_plans_conserve_sessions(seed):
    """Any bounded random fault plan: streams stay bit-equal to the twin,
    every session ends served or failed-with-reason, untouched sessions
    keep the exact fault-free clock, and no slots leak."""
    cfg, prob, twin_sys = _build(n_servers=4, l_out=6, max_new=6)
    hosts = [0, 1, 2]
    n_new = 6
    twin_sids = _admit_on(twin_sys, cfg, hosts, n_new, seed=1)
    twin = _drive(twin_sys, twin_sids, n_new)

    plan = FaultPlan.random(4, seed, horizon=0.8, n_crashes=1,
                            n_transients=1, n_stragglers=1,
                            protect=(0,))
    _, _, system = _build(n_servers=4, l_out=6, max_new=6, fault_plan=plan)
    sids = _admit_on(system, cfg, hosts, n_new, seed=1)
    done = _drive(system, sids, n_new)

    affected = set(plan.affected_servers)
    for (j, ts, fs) in zip(hosts, twin_sids, sids):
        sess = done[fs]
        assert sess.state in ("done", "failed")
        if sess.state == "failed":
            assert sess.fail_reason is not None
            continue
        assert list(sess.tokens) == list(twin[ts].tokens)
        if j not in affected:
            assert sess.virtual_time == twin[ts].virtual_time
            assert sess.recovery_time == 0.0
        else:
            assert sess.virtual_time >= twin[ts].virtual_time
    for used, _cap in system.slot_usage().values():
        assert used == 0


# ---------------------------------------------------------------------------
# Typed failures: validation errors and capacity-starved failover deferral
# ---------------------------------------------------------------------------


def test_kill_server_unknown_or_dead_raises():
    cfg, _, system = _build(n_servers=3)
    with pytest.raises(ValueError, match="alive servers"):
        system.kill_server(99)
    system.kill_server(2)
    with pytest.raises(ValueError, match="alive servers"):
        system.kill_server(2)  # already dead
    with pytest.raises(ValueError):
        system.inject_crash(99)
    with pytest.raises(ValueError):
        system.rejoin_server(99)


def test_failover_without_capacity_defers_then_completes():
    """Kill the only host of session A while the sole failover target is
    full: the NoCapacityError path parks A (deferral, not failure), and A
    resumes + splices once a blocker retires — tokens bit-exact."""
    n_new = 6
    hosts = [0, 1]  # A on server 0; B leaves server 1 with 1 free slot
    cfg, prob, ref_sys = _build(n_servers=2, mem=130.0, R=1, l_out=6,
                                max_new=6, max_sessions=6)
    # cap per server: floor((130 - 50*2)/10) = 3 slots; a session books
    # k = 2 block-slots, so B (2/3) leaves no room for A's failover (2)
    ref_sids = _admit_on(ref_sys, cfg, hosts, n_new, seed=5)
    ref = _drive(ref_sys, ref_sids, n_new)

    _, _, system = _build(n_servers=2, mem=130.0, R=1, l_out=6, max_new=6,
                          max_sessions=6)
    sids = _admit_on(system, cfg, hosts, n_new, seed=5)
    system.decode_round()  # one normal round for everyone
    system.kill_server(0)
    # drive: A defers on NoCapacityError (server 1 lacks 2 free slots),
    # B completes and retires, then A resumes onto server 1 and finishes
    done = _drive(system, sids, n_new)
    assert done[sids[0]].state == "done"
    assert done[sids[0]].fail_reason is None
    assert done[sids[0]].n_defer_resumes >= 1
    assert done[sids[0]].n_preemptions >= 1  # parked via the resume queue
    assert done[sids[0]].route.servers == (1,)
    for (rs, fs) in zip(ref_sids, sids):
        assert list(done[fs].tokens) == list(ref[rs].tokens)
    for used, _cap in system.slot_usage().values():
        assert used == 0


def test_dispatch_error_fails_admission_once():
    """An admission-time dispatch fault consumes itself: the first admit
    touching the server fails, the retry goes through."""
    plan = FaultPlan((FaultEvent(0.0, "dispatch_error", 0),))
    cfg, _, system = _build(n_servers=2, fault_plan=plan)
    system.apply_faults(0.0)
    rng = np.random.RandomState(0)
    sid = system.create_session(rng.randint(2, cfg.vocab_size, 4), 0,
                                _single_hop_route(system, 0), 4)
    assert system.try_admit_sessions([sid]) == []
    assert system.round_stats["dispatch_errors"] == 1
    assert system.try_admit_sessions([sid]) == [sid]  # fault consumed


# ---------------------------------------------------------------------------
# FaultPlan / detector unit properties
# ---------------------------------------------------------------------------


def test_fault_plan_validation_and_determinism():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0.0, "meteor", 0)
    with pytest.raises(ValueError, match="factor"):
        FaultEvent(0.0, "straggler_start", 0, factor=0.5)
    p1 = FaultPlan.random(8, 42, n_crashes=2, n_transients=1,
                          n_stragglers=1, n_dispatch_errors=1)
    p2 = FaultPlan.random(8, 42, n_crashes=2, n_transients=1,
                          n_stragglers=1, n_dispatch_errors=1)
    assert p1.events == p2.events  # seed-deterministic
    assert [e.time for e in p1.events] == sorted(e.time for e in p1.events)
    assert p1.count("crash") == 3  # transients crash too
    # cursor-based delivery never re-delivers
    due1, cur = p1.due(0, p1.events[1].time)
    due2, cur = p1.due(cur, np.inf)
    assert [id(e) for e in due1 + due2] == [id(e) for e in p1.events]
    # protected servers are never victims
    p3 = fault_schedule(4, 7, n_crashes=2, n_stragglers=1, protect=(0,))
    assert 0 not in p3.affected_servers


def test_detector_pricing_matches_backoff_shape():
    det = FailureDetector(timeout_factor=2.0, backoff_base=1.0,
                          backoff_cap=4.0, max_probes=4)
    assert det.probe_delays() == [1.0, 2.0, 4.0, 4.0]  # doubling, capped
    assert det.backoff_time() == 11.0
    assert det.detect_time(0.5) == (1 + 4) * 2.0 * 0.5
    with pytest.raises(ValueError):
        FailureDetector(timeout_factor=1.0)


def test_suspicion_penalizes_route_cost_columns():
    llm = LLMSpec("toy", 4, block_bytes=50.0, cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, 900.0, 0.01, 0.002, 0.0005) for j in range(3)]
    rtt = np.full((1, 3), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, 6))
    from repro.core.placement import cg_bp
    placement, _ = cg_bp(prob, 2)
    base = RouteCostCache(prob, placement).cost(0)
    sus = RouteCostCache(prob, placement, suspicion={1: 0.5}).cost(0)
    np.testing.assert_allclose(sus[:, 1], base[:, 1] + 0.5)
    np.testing.assert_array_equal(sus[:, [0, 2]], base[:, [0, 2]])


def test_recovery_replay_cost_terms():
    llm = LLMSpec("toy", 4, block_bytes=50.0, cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, 900.0, 0.01 * (j + 1), 0.002, 0.0005)
               for j in range(2)]
    rtt_tok = np.full((1, 2), 0.02)
    prob = Problem(llm, servers, 1, rtt_tok, rtt_tok * 3,
                   workload=Workload(4, 6))
    got = recovery_replay_cost(prob, 0, [(1, 0, 4)], n_tokens=3)
    w = prob.llm.tau_weight(0, 4)
    want = (prob.rtt_prefill[0, 1]
            + w * prob.servers[1].tau_prefill(4)
            + 3 * w * prob.servers[1].tau)
    assert got == pytest.approx(want)
    # straggler multiplier scales compute, not the RTT
    slow = recovery_replay_cost(prob, 0, [(1, 0, 4)], n_tokens=3,
                                slowdown_of=lambda j: 2.0)
    want_slow = (prob.rtt_prefill[0, 1]
                 + 2.0 * (w * prob.servers[1].tau_prefill(4)
                          + 3 * w * prob.servers[1].tau))
    assert slow == pytest.approx(want_slow)


# ---------------------------------------------------------------------------
# Analytic reference: simulate_faults conservation + monotone recovery
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_simulate_faults_conserves_requests(seed):
    llm = LLMSpec("toy", 4, block_bytes=50.0, cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, 900.0, 0.01 * (j + 1), 0.002, 0.0005)
               for j in range(6)]
    rtt = np.full((1, 6), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, 16))
    reqs = poisson_requests(25, rate=2.0, seed=seed)
    plan = fault_schedule(6, seed, horizon=8.0, n_crashes=1, n_transients=1,
                          n_stragglers=1, n_dispatch_errors=1, protect=(0,))
    res = simulate_faults(prob, reqs, plan, R=4)
    assert res.n_served + res.n_failed == res.n_requests
    assert all(k in ("no_route", "dispatch_error", "server_lost_mid_prefill")
               for k in res.fail_reasons)
    assert res.recovery_time >= 0.0
    # deterministic: same inputs, same outcome
    res2 = simulate_faults(prob, reqs, plan, R=4)
    assert (res2.n_served, res2.recovery_time) == \
        (res.n_served, res.recovery_time)
    # the fault-free twin never pays recovery and serves at least as many
    base = simulate_faults(prob, reqs, FaultPlan(), R=4)
    assert base.recovery_time == 0.0
    assert base.n_served >= res.n_served
