"""Simulator invariants: determinism, memory-cap safety, the paper's
headline claims (proposed beats PETALS; first-token dominated), and the
fast-vs-reference exactness contract of the array-native event engine."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (LLMSpec, Problem, ServerSpec, ServerState,
                        ServerStateArrays, Workload, capacity, cg_bp,
                        edge_waiting_times, petals_route)
from repro.sim import (ALGORITHMS, SimConfig, clustered_scenario,
                       run_comparison, simulate, simulate_churn)
from repro.sim.simulator import _Timeline
from repro.sim.topologies import TOPOLOGY_SPECS, make_topology
from repro.sim.workload import (ChurnEvent, Request, RequestBatch,
                                bursty_requests, churn_schedule,
                                diurnal_rate, diurnal_requests,
                                poisson_requests)

SETTINGS = settings(max_examples=20, deadline=None)


def test_deterministic():
    prob, _ = clustered_scenario()
    a = simulate(prob, SimConfig(algorithm="proposed", n_requests=30,
                                 rate=0.3, seed=7))
    b = simulate(prob, SimConfig(algorithm="proposed", n_requests=30,
                                 rate=0.3, seed=7))
    assert a.per_token_all == b.per_token_all
    assert a.first_token == b.first_token


@pytest.mark.parametrize("alg", ["petals", "proposed", "optimized_number"])
def test_memory_never_exceeded(alg):
    prob, _ = clustered_scenario()
    res = simulate(prob, SimConfig(algorithm=alg, n_requests=40, rate=0.5,
                                   seed=1))
    # rebuild the timeline and assert usage <= capacity at all event times
    tl = _Timeline(prob, res.placement)
    for r in res.requests:
        if r.get("drop"):
            continue
    # per-request commitments were already capacity-checked by construction;
    # re-verify via the recorded rows: waits are finite and nonneg
    for r in res.requests:
        if not r.get("drop"):
            assert r["wait"] >= -1e-9
            assert np.isfinite(r["total"])


def test_proposed_beats_petals_clustered():
    prob, _ = clustered_scenario()
    petals = simulate(prob, SimConfig(algorithm="petals", n_requests=80,
                                      rate=0.5, seed=0))
    prop = simulate(prob, SimConfig(algorithm="proposed", n_requests=80,
                                    rate=0.5, seed=0))
    assert prop.per_token_all < petals.per_token_all
    # paper §4.2: the improvement is dominated by the first token
    assert prop.first_token < 0.5 * petals.first_token


def test_first_token_gap_order_of_magnitude():
    prob, _ = clustered_scenario()
    petals = simulate(prob, SimConfig(algorithm="petals", n_requests=100,
                                      rate=0.5, seed=2))
    prop = simulate(prob, SimConfig(algorithm="proposed", n_requests=100,
                                    rate=0.5, seed=2))
    assert petals.first_token / max(prop.first_token, 1e-9) > 5.0


def test_topologies_match_specs():
    for name, spec in TOPOLOGY_SPECS.items():
        topo = make_topology(name)
        assert topo.n == spec["n"]
        assert len(topo.edges) == spec["links"]
        delays = np.array([e[2] for e in topo.edges]) * 1e3
        lo, hi = spec["delay_ms"]
        assert delays.min() >= lo - 1e-6 and delays.max() <= hi + 1e-6
        assert np.isfinite(topo.rtt).all(), "topology must be connected"

# ----------------------------------------------------------------------
# fast-vs-reference exactness: the array-native event engine must be a
# bit-exact twin of the per-request reference loop — same routes, same
# starts, same drops, same metrics, on every algorithm and trace shape
# ----------------------------------------------------------------------

def _sim_problem(n_clients=1):
    """The bench cross-validation topology (2 fast + 3 slow servers),
    optionally with extra clients at slightly different RTTs."""
    llm = LLMSpec("simx", 8, block_bytes=50.0, cache_bytes_per_token=0.5)
    servers = [
        ServerSpec(0, 500.0, 0.004, tau_prefill_base=0.002,
                   tau_prefill_per_token=0.0005),
        ServerSpec(1, 500.0, 0.004, tau_prefill_base=0.002,
                   tau_prefill_per_token=0.0005),
        ServerSpec(2, 260.0, 0.020, tau_prefill_base=0.004,
                   tau_prefill_per_token=0.001),
        ServerSpec(3, 260.0, 0.020, tau_prefill_base=0.004,
                   tau_prefill_per_token=0.001),
        ServerSpec(4, 260.0, 0.020, tau_prefill_base=0.004,
                   tau_prefill_per_token=0.001),
    ]
    base = np.array([0.01, 0.01, 0.03, 0.03, 0.03])
    rtt = np.stack([base * (1.0 + 0.2 * c) for c in range(n_clients)])
    return Problem(llm, servers, n_clients, rtt, 3 * rtt,
                   workload=Workload(8, 12))


def _clustered(n_clients=1):
    """Table-2 clustered deployment, optionally widened to several
    clients at scaled RTTs (every algorithm finds real routes here)."""
    prob, _ = clustered_scenario()
    if n_clients == 1:
        return prob
    rtt_t = np.concatenate([prob.rtt_token * (1.0 + 0.2 * c)
                            for c in range(n_clients)])
    rtt_p = np.concatenate([prob.rtt_prefill * (1.0 + 0.2 * c)
                            for c in range(n_clients)])
    return Problem(prob.llm, prob.servers, n_clients, rtt_t, rtt_p,
                   prob.workload)


def _trace(kind):
    if kind == "poisson":
        return _clustered(), poisson_requests(40, 0.5, seed=1)
    if kind == "bursty":
        return _clustered(), bursty_requests(n_bursts=10, burst_size=4,
                                             spacing=10.0)
    if kind == "multi_client":
        return (_clustered(n_clients=3),
                poisson_requests(40, 0.5, seed=2, n_clients=3))
    assert kind == "diurnal"
    return _clustered(), diurnal_requests(60, 0.1, 1.5, period=60.0,
                                          seed=3)


def _run_mode(prob, alg, requests, mode, **kw):
    return simulate(prob, SimConfig(algorithm=alg, n_requests=len(requests),
                                    rate=1.0, seed=0, sim_mode=mode, **kw),
                    requests=requests)


METRICS = ("drop_rate", "wait", "first_token", "per_token_rest",
           "per_token_all")


@pytest.mark.parametrize("alg", ALGORITHMS)
@pytest.mark.parametrize("kind", ["poisson", "bursty", "multi_client",
                                  "diurnal"])
def test_fast_matches_reference(kind, alg):
    prob, requests = _trace(kind)
    ref = _run_mode(prob, alg, requests, "reference")
    fast = _run_mode(prob, alg, requests, "fast")
    assert ref.sim_mode == "reference" and fast.sim_mode == "fast"
    assert ref.drop_rate < 1.0  # the cell actually serves traffic
    # exact per-request row equality: route hops, waits, every timing
    assert ref.requests == fast.requests
    for f in METRICS:
        assert getattr(ref, f) == getattr(fast, f), f


@pytest.mark.parametrize("alg", ["proposed", "optimized_number"])
def test_fast_matches_reference_contended(alg):
    """The bench cross-validation topology under load: waits are nonzero,
    so the slow exact path (incremental eq. (20) state) is what must
    agree, not just the memoized zero-wait decision."""
    prob = _sim_problem()
    requests = poisson_requests(40, 2.0, seed=1)
    ref = _run_mode(prob, alg, requests, "reference", R=8)
    fast = _run_mode(prob, alg, requests, "fast", R=8)
    assert ref.drop_rate < 1.0
    assert ref.requests == fast.requests
    for f in METRICS:
        assert getattr(ref, f) == getattr(fast, f), f


def test_fast_matches_reference_all_dropped():
    """Route-infeasible placements must drop identically in both modes
    (the memoized base decision caches the drop too)."""
    prob = _sim_problem()
    requests = poisson_requests(10, 2.0, seed=1)
    ref = _run_mode(prob, "petals", requests, "reference", R=8)
    fast = _run_mode(prob, "petals", requests, "fast", R=8)
    assert ref.drop_rate == fast.drop_rate == 1.0
    assert ref.requests == fast.requests


def test_fast_exercises_both_paths():
    """The contended trace must hit the memoized zero-wait path AND the
    exact slow path — otherwise the parity matrix proves less than it
    claims."""
    prob = _sim_problem()
    requests = poisson_requests(40, 2.0, seed=1)
    fast = _run_mode(prob, "proposed", requests, "fast", R=8)
    st_ = fast.fast_stats
    assert st_ is not None
    assert st_["fast_routes"] > 0 and st_["slow_routes"] > 0, st_
    assert st_["fast_routes"] + st_["slow_routes"] + st_["drops"] \
        == len(requests)


def test_fast_collect_rows_off_matches_metrics():
    prob = _sim_problem()
    requests = poisson_requests(40, 2.0, seed=1)
    ref = _run_mode(prob, "proposed", requests, "reference", R=8)
    fast = simulate(prob, SimConfig(algorithm="proposed",
                                    n_requests=len(requests), rate=1.0,
                                    seed=0, R=8, sim_mode="fast",
                                    collect_rows=False),
                    requests=requests)
    assert fast.requests == []  # rows skipped, metrics array-backed
    for f in METRICS:
        assert getattr(ref, f) == getattr(fast, f), f


def test_fast_falls_back_on_unsorted_trace():
    """Nondecreasing arrivals are the frontier-pruning precondition; an
    unsorted trace must transparently run the reference loop."""
    prob, _ = _trace("poisson")
    reqs = [Request(0, 0, 5.0), Request(1, 0, 1.0), Request(2, 0, 3.0)]
    res = _run_mode(prob, "proposed", reqs, "fast")
    assert res.sim_mode == "reference"
    assert res.requests == _run_mode(prob, "proposed", reqs,
                                     "reference").requests


def test_simulate_rejects_unknown_mode():
    prob, requests = _trace("poisson")
    with pytest.raises(ValueError):
        simulate(prob, SimConfig(algorithm="proposed", n_requests=5,
                                 rate=1.0, seed=0, R=8, sim_mode="turbo"),
                 requests=requests[:5])


# ----------------------------------------------------------------------
# incremental eq. (20) state: array twins and frontier pruning
# ----------------------------------------------------------------------

@SETTINGS
@given(st.integers(0, 10_000))
def test_edge_waiting_dict_vs_arrays(seed):
    """edge_waiting_times must produce bit-identical matrices from the
    classic dict-of-ServerState and the SoA ServerStateArrays."""
    rng = np.random.default_rng(seed)
    prob = _sim_problem()
    pl, info = cg_bp(prob, 8)
    assert info.feasible
    states = {}
    for j in range(prob.n_servers):
        if rng.random() < 0.7:
            m = int(rng.integers(1, 5))
            states[j] = ServerState(
                remaining=[float(x) for x in rng.exponential(1.0, m)],
                blocks=[int(b) for b in rng.integers(1, 9, m)])
    w_dict = edge_waiting_times(prob, pl, states)
    arrays = ServerStateArrays.from_states(states, prob.n_servers)
    w_arr = edge_waiting_times(prob, pl, arrays)
    np.testing.assert_array_equal(w_dict, w_arr)
    # and the round-trip preserves the states exactly
    back = arrays.to_states()
    assert set(back) == set(states)
    for j in states:
        assert back[j].remaining == [max(r, 0.0)
                                     for r in states[j].remaining]
        assert back[j].blocks == list(states[j].blocks)


@SETTINGS
@given(st.integers(0, 10_000))
def test_timeline_pruned_matches_unpruned(seed):
    """Frontier pruning + buffered commits must be probe-invisible: a
    timeline with the frontier advanced (and compaction forced) answers
    every probe at t >= frontier exactly like an untouched twin."""
    rng = np.random.default_rng(seed)
    prob = _sim_problem()
    pl, info = cg_bp(prob, 8)
    route = petals_route(prob, pl, 0)
    assert route is not None
    tl = _Timeline(prob, pl)
    twin = _Timeline(prob, pl)
    t = 0.0
    for _ in range(60):
        t += float(rng.exponential(0.3))
        dur = float(0.1 + rng.exponential(1.0))
        tl.frontier = t  # the fast loop's per-arrival advance
        tl.commit(route, t, dur)
        twin.commit(route, t, dur)
    for j in range(prob.n_servers):
        tl._flush(j)  # force compaction opportunities
    probes = sorted(float(t * rng.uniform(0.0, 1.2)) for _ in range(8))
    for u in probes:
        if u < tl.frontier:
            continue
        for j in route.servers:
            assert tl.usage_max(j, u, u + 0.5) == twin.usage_max(
                j, u, u + 0.5)
        assert tl.earliest_start(route, u, 0.5) == twin.earliest_start(
            route, u, 0.5)
        s_a, s_b = tl.states_at(u), twin.states_at(u)
        assert set(s_a) == set(s_b)
        for j in s_a:
            assert sorted(zip(s_a[j].remaining, s_a[j].blocks)) \
                == sorted(zip(s_b[j].remaining, s_b[j].blocks))
        arr = tl.states_arrays_at(u).to_states()
        assert set(arr) == set(s_a)
        for j in arr:
            assert arr[j].remaining == s_a[j].remaining
            assert arr[j].blocks == s_a[j].blocks


# ----------------------------------------------------------------------
# array-backed traces and churn schedules
# ----------------------------------------------------------------------

@SETTINGS
@given(st.integers(0, 10_000))
def test_request_batch_round_trip(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 50))
    reqs = poisson_requests(n, rate=2.0, seed=seed, n_clients=3)
    batch = RequestBatch.from_requests(reqs)
    assert len(batch) == n
    assert batch.to_requests() == reqs  # exact floats, exact ids


def test_diurnal_requests_shape():
    batch = diurnal_requests(500, 1.0, 10.0, period=60.0, n_clients=4,
                             seed=0)
    assert len(batch) == 500
    assert np.all(np.diff(batch.arrival) >= 0.0)
    assert batch.client.min() >= 0 and batch.client.max() < 4
    # valley rate ~base at t0, peak half a period later
    assert diurnal_rate(0.0, 1.0, 10.0, 60.0) == pytest.approx(1.0)
    assert diurnal_rate(30.0, 1.0, 10.0, 60.0) == pytest.approx(10.0)
    with pytest.raises(ValueError):
        diurnal_requests(10, 5.0, 1.0)  # base > peak


def test_churn_schedule_invariants():
    events = churn_schedule(20, n_storms=5, storm_size=3, first=10.0,
                            spacing=5.0, seed=2, protect=(0, 1))
    assert len(events) == 5
    down = ()
    for i, ev in enumerate(events):
        assert ev.time == pytest.approx(10.0 + 5.0 * i)
        assert len(ev.leave) == 3
        assert not set(ev.leave) & {0, 1}  # protected servers never leave
        assert ev.join == down  # previous victims revived first
        down = ev.leave
    with pytest.raises(ValueError):
        churn_schedule(4, n_storms=1, storm_size=4, protect=(0,))


def test_simulate_churn_smoke():
    prob = _sim_problem(n_clients=2)
    reqs = poisson_requests(60, rate=2.0, seed=5, n_clients=2)
    sched = churn_schedule(prob.n_servers, n_storms=2, storm_size=1,
                           first=8.0, spacing=8.0, seed=0, protect=(0, 1))
    res = simulate_churn(prob, reqs, sched, R=8)
    assert res.n_requests == 60
    assert res.n_replacements >= 1  # storms actually re-placed
    assert res.alive_min >= prob.n_servers - 1
    assert 0.0 <= res.drop_rate <= 1.0


# ----------------------------------------------------------------------
# run_comparison: std-dev columns and multi-client threading
# ----------------------------------------------------------------------

def test_run_comparison_std_and_clients():
    prob = _clustered(n_clients=3)
    rows = run_comparison(prob, algorithms=("petals", "proposed"),
                          n_requests=20, rate=0.5, seeds=(0, 1, 2),
                          n_clients=3)
    assert set(rows) == {"petals", "proposed"}
    for row in rows.values():
        for name in ("per_token_all", "first_token", "wait", "drop_rate"):
            assert name in row and name + "_std" in row
            assert row[name + "_std"] >= 0.0
    # multi-client traffic really reached the simulator: a fresh
    # single-client run differs from the n_clients=3 one
    solo = run_comparison(prob, algorithms=("proposed",), n_requests=20,
                          rate=0.5, seeds=(0, 1, 2))
    assert solo["proposed"]["per_token_all"] \
        != rows["proposed"]["per_token_all"]
