"""Simulator invariants: determinism, memory-cap safety, and the paper's
headline claims (proposed beats PETALS; first-token dominated)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import capacity
from repro.sim import SimConfig, clustered_scenario, simulate
from repro.sim.simulator import _Timeline
from repro.sim.topologies import TOPOLOGY_SPECS, make_topology


def test_deterministic():
    prob, _ = clustered_scenario()
    a = simulate(prob, SimConfig(algorithm="proposed", n_requests=30,
                                 rate=0.3, seed=7))
    b = simulate(prob, SimConfig(algorithm="proposed", n_requests=30,
                                 rate=0.3, seed=7))
    assert a.per_token_all == b.per_token_all
    assert a.first_token == b.first_token


@pytest.mark.parametrize("alg", ["petals", "proposed", "optimized_number"])
def test_memory_never_exceeded(alg):
    prob, _ = clustered_scenario()
    res = simulate(prob, SimConfig(algorithm=alg, n_requests=40, rate=0.5,
                                   seed=1))
    # rebuild the timeline and assert usage <= capacity at all event times
    tl = _Timeline(prob, res.placement)
    for r in res.requests:
        if r.get("drop"):
            continue
    # per-request commitments were already capacity-checked by construction;
    # re-verify via the recorded rows: waits are finite and nonneg
    for r in res.requests:
        if not r.get("drop"):
            assert r["wait"] >= -1e-9
            assert np.isfinite(r["total"])


def test_proposed_beats_petals_clustered():
    prob, _ = clustered_scenario()
    petals = simulate(prob, SimConfig(algorithm="petals", n_requests=80,
                                      rate=0.5, seed=0))
    prop = simulate(prob, SimConfig(algorithm="proposed", n_requests=80,
                                    rate=0.5, seed=0))
    assert prop.per_token_all < petals.per_token_all
    # paper §4.2: the improvement is dominated by the first token
    assert prop.first_token < 0.5 * petals.first_token


def test_first_token_gap_order_of_magnitude():
    prob, _ = clustered_scenario()
    petals = simulate(prob, SimConfig(algorithm="petals", n_requests=100,
                                      rate=0.5, seed=2))
    prop = simulate(prob, SimConfig(algorithm="proposed", n_requests=100,
                                    rate=0.5, seed=2))
    assert petals.first_token / max(prop.first_token, 1e-9) > 5.0


def test_topologies_match_specs():
    for name, spec in TOPOLOGY_SPECS.items():
        topo = make_topology(name)
        assert topo.n == spec["n"]
        assert len(topo.edges) == spec["links"]
        delays = np.array([e[2] for e in topo.edges]) * 1e3
        lo, hi = spec["delay_ms"]
        assert delays.min() >= lo - 1e-6 and delays.max() <= hi + 1e-6
        assert np.isfinite(topo.rtt).all(), "topology must be connected"
