"""Device-group servers: sharded pooled serving == the mesh=None twin.

The tentpole contract (docs/serving.md "Device-group servers"): threading a
``jax.sharding.Mesh`` through the pooled serving steps must not change WHAT
is computed — only where.  Three tiers of evidence:

* trivial 1-device mesh: the constraint path is BIT-exact against mesh=None
  (tokens, virtual clock, logits) — runs everywhere, no forced devices;
* real 8-device mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
  the ``sharded-parity`` CI lane): token streams and the virtual clock are
  EXACTLY equal across decoder / MLA / MoE-EP x fused / serial x slab /
  paged; logits agree to float-eps (sharded contracting-dim matmuls reorder
  reductions);
* a subprocess acceptance test that forces 8 host devices itself, so tier-1
  proves the multi-device contract even when collected on one device.

Also here: τ calibration from the sharded step's per-device cost analysis
(``calibrate_taus`` -> ``with_server_taus``) and the pure-EP shard_map MoE
under a real multi-device mesh.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                        shortest_path_route)
from repro.launch.mesh import compat_make_mesh
from repro.models import init_params
from repro.serving import GeoServingSystem

pytestmark = pytest.mark.sharded

N_DEV = len(jax.devices())
needs8 = pytest.mark.skipif(
    N_DEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8"
    " (the sharded-parity CI lane)")

# sharded matmuls split contracting dims -> per-device partial sums reduce
# in a different order than the single-device GEMM; same float32 scale of
# slack as the fused-tail tolerance in test_round_fusion.py
LOGIT_TOL = dict(atol=5e-6, rtol=1e-4)

# arch x mesh shape: deepseek = MLA latent caches, TP over "model";
# llama4-scout = small-E MoE, experts sharded over "data" (EP);
# llama3 = plain GQA decoder.
ARCH_MESH = [
    ("llama3_2_1b", (2, 4)),
    ("deepseek_v2_236b", (2, 4)),
    ("llama4_scout_17b_a16e", (4, 2)),
]

_PARAMS_CACHE = {}


def _params_for(cfg):
    if cfg.name not in _PARAMS_CACHE:
        _PARAMS_CACHE[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)[0]
    return _PARAMS_CACHE[cfg.name]


def _problem(cfg, n_servers=2, l_out=4):
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=1000.0, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    return Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, l_out))


def _build(arch, mesh, *, decode_mode="fused", cache_layout="slab",
           page_size=None, max_new=4):
    cfg = get_reduced_config(arch)
    system = GeoServingSystem(cfg, _params_for(cfg), _problem(cfg, 2, max_new),
                              algorithm="proposed", R=2,
                              max_new_tokens=max_new, max_sessions=4,
                              decode_mode=decode_mode,
                              cache_layout=cache_layout, page_size=page_size,
                              mesh=mesh)
    return cfg, system


def _jobs_for(cfg, lengths, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(2, cfg.vocab_size, n) for n in lengths]


def _serve(system, jobs, n_new=4):
    """Admit, prefill, decode to completion.  Returns (tokens, virtual
    times, per-round logits histories) per session."""
    sids = []
    for prompt in jobs:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(prompt, 0, route, n_new))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    hist = {s: [np.asarray(system.sessions[s].last_logits)] for s in sids}
    while True:
        todo = [s for s in sids if system.sessions[s].n_generated < n_new]
        if not todo:
            break
        system.decode_round(todo)
        for s in todo:
            hist[s].append(np.asarray(system.sessions[s].last_logits))
    toks = [list(system.sessions[s].tokens) for s in sids]
    vts = [float(system.sessions[s].virtual_time) for s in sids]
    for s in sids:
        system.retire_session(s)
    return toks, vts, [hist[s] for s in sids]


# ---------------------------------------------------------------------------
# Trivial mesh: bit-exact twin, no forced devices needed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3_2_1b", "deepseek_v2_236b"])
def test_trivial_mesh_is_bit_exact(arch):
    """A 1-device mesh exercises the whole sharded code path (device_put'd
    params/pools, constrained steps, frozen rules in the jit keys) with
    no actual partitioning — everything, logits included, must be
    BIT-identical to mesh=None."""
    cfg, ref = _build(arch, None)
    jobs = _jobs_for(cfg, (4, 6))
    want = _serve(ref, jobs)

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg, system = _build(arch, mesh)
    got = _serve(system, jobs)
    assert got[0] == want[0], f"{arch}: tokens diverge under trivial mesh"
    assert got[1] == want[1], f"{arch}: virtual clock diverges"
    for hg, hw in zip(got[2], want[2]):
        for a, b in zip(hg, hw):
            np.testing.assert_array_equal(a, b)  # bit-for-bit


def test_mesh_rules_roundtrip_and_override():
    """``mesh_rules`` is accepted as a dict or a frozen tuple and lands on
    every server; the derived default comes from ``serving_rules``."""
    from repro.launch.sharding import freeze_rules, serving_rules, thaw_rules

    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg, system = _build("llama3_2_1b", mesh)
    srv = next(iter(system.servers.values()))
    derived = serving_rules(cfg, mesh, srv.pool.n_rows, srv.pool.max_len)
    assert srv.mesh_rules == derived
    assert thaw_rules(freeze_rules(derived)) == derived
    assert freeze_rules(None) is None and thaw_rules(None) == {}

    override = dict(derived, batch=None)
    cfg2, system2 = _build("llama3_2_1b", mesh)
    system2b = GeoServingSystem(cfg2, _params_for(cfg2), _problem(cfg2),
                                R=2, max_new_tokens=4, max_sessions=4,
                                mesh=mesh, mesh_rules=override)
    srv2 = next(iter(system2b.servers.values()))
    assert srv2.mesh_rules["batch"] is None


# ---------------------------------------------------------------------------
# τ calibration from the (sharded) step's cost analysis
# ---------------------------------------------------------------------------


def test_calibrated_taus_feed_perf_model():
    """AOT cost -> roofline -> per-server τ: finite, positive, folded into a
    COPY of the problem (the live engine keeps its spec'd τ — the parity
    contract says a mesh must not change the virtual clock)."""
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg, system = _build("llama3_2_1b", mesh)
    cost = next(iter(system.servers.values())).decode_step_cost()
    assert cost.flops > 0 and cost.bytes_accessed > 0
    taus = system.calibrate_taus()
    assert set(taus) == set(system.servers)
    assert all(np.isfinite(t) and t > 0 for t in taus.values())
    cal = system.calibrated_problem()
    np.testing.assert_allclose(cal.tau(),
                               [taus[s.sid] for s in cal.servers])
    # the live problem is untouched
    assert system.problem.tau().tolist() == [0.01, 0.02]


def test_calibration_without_mesh():
    """mesh=None servers calibrate too (n_chips=1): the same entry point
    covers plain single-device serving."""
    cfg, system = _build("llama3_2_1b", None)
    taus = system.calibrate_taus()
    assert all(np.isfinite(t) and t > 0 for t in taus.values())


# ---------------------------------------------------------------------------
# Real 8-device mesh: the parity matrix (sharded-parity CI lane)
# ---------------------------------------------------------------------------


@needs8
@pytest.mark.parametrize("layout,page_size", [("slab", None), ("paged", 2)])
@pytest.mark.parametrize("mode", ["fused", "serial"])
@pytest.mark.parametrize("arch,mesh_shape", ARCH_MESH)
def test_sharded_matches_single_device(arch, mesh_shape, mode, layout,
                                       page_size):
    """The acceptance matrix: decoder / MLA / MoE-EP x fused / serial x
    slab / paged on a real (data, model) mesh — tokens and virtual clock
    EXACTLY equal to the mesh=None twin, logits to float-eps."""
    cfg, ref = _build(arch, None, decode_mode=mode, cache_layout=layout,
                      page_size=page_size)
    jobs = _jobs_for(cfg, (4, 6, 5))
    want = _serve(ref, jobs)

    mesh = compat_make_mesh(mesh_shape, ("data", "model"))
    cfg, system = _build(arch, mesh, decode_mode=mode, cache_layout=layout,
                         page_size=page_size)
    got = _serve(system, jobs)
    assert got[0] == want[0], f"{arch}/{mode}/{layout}: tokens diverge"
    assert got[1] == want[1], f"{arch}/{mode}/{layout}: vclock diverges"
    for hg, hw in zip(got[2], want[2]):
        for a, b in zip(hg, hw):
            np.testing.assert_allclose(a, b, **LOGIT_TOL)


@needs8
def test_sharded_solo_matches_grouped():
    """Under a mesh, solo and grouped sessions still share ONE pooled
    program — bit-for-bit identical tokens and logits."""
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    cfg, system = _build("deepseek_v2_236b", mesh)
    jobs = _jobs_for(cfg, (4, 6, 5))
    toks_g, _, hist_g = _serve(system, jobs)
    toks_s, hist_s = [], []
    for job in jobs:
        t, _, h = _serve(system, [job])
        toks_s += t
        hist_s += h
    assert toks_s == toks_g
    for hs, hg in zip(hist_s, hist_g):
        for a, b in zip(hs, hg):
            np.testing.assert_array_equal(a, b)  # bit-for-bit


@needs8
def test_sharded_step_params_and_pools_actually_shard():
    """On an 8-device mesh at least one param leaf and one cache leaf must
    be non-trivially partitioned (the point of a device group), and the
    calibrated τ reflects per-device costs."""
    mesh = compat_make_mesh((2, 4), ("data", "model"))
    cfg, system = _build("deepseek_v2_236b", mesh)
    srv = next(iter(system.servers.values()))

    def any_sharded(tree):
        return any(
            not leaf.sharding.is_fully_replicated
            for leaf in jax.tree.leaves(tree))

    assert any_sharded(srv.run_params), "no param leaf is partitioned"
    assert any_sharded(srv.pool.tree), "no cache leaf is partitioned"
    taus = system.calibrate_taus()
    assert all(np.isfinite(t) and t > 0 for t in taus.values())


@needs8
def test_ep_shard_map_on_real_mesh():
    """Pure-EP shard_map dispatch == global sort-dispatch on a REAL
    multi-device mesh (test_moe_ep.py proves it on 1 device; here the
    all_to_alls actually move tokens between devices)."""
    from repro.models import moe as moe_mod
    from repro.models.layers import NULL_SH, ShardingCtx

    cfg = get_reduced_config("deepseek_v2_236b").replace(capacity_factor=8.0)
    E = cfg.n_experts
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model), jnp.float32) * 0.3
    ref, aux_ref = moe_mod.apply_moe(params, cfg, NULL_SH, x)

    mesh = compat_make_mesh((2, 2), ("data", "model"))
    sh = ShardingCtx(mesh, {"batch": "data", "seq_act": None})
    padded = dict(params)
    for k in ("wg", "wu", "wo"):
        w = params[k]
        pad = jnp.zeros((2 * E - E,) + w.shape[1:], w.dtype)
        padded[k] = jnp.concatenate([w, pad], axis=0)
    got, aux = moe_mod._apply_moe_ep(padded, cfg, sh, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)
    assert float(aux["moe_drop_frac"]) < 1e-6


# ---------------------------------------------------------------------------
# Heterogeneous device groups: per-server meshes (the tentpole)
# ---------------------------------------------------------------------------

HETERO_SHAPES = {0: None, 1: (1, 2), 2: (2, 2)}  # solo, 2-dev TP, 4-dev


def _build_hetero(arch, groups, *, decode_mode="fused", cache_layout="slab",
                  page_size=None, max_new=4):
    """3-server deployment at R=3 (every server hosts every block, so every
    group's sharded step actually runs) with per-server device groups."""
    cfg = get_reduced_config(arch)
    system = GeoServingSystem(cfg, _params_for(cfg),
                              _problem(cfg, 3, max_new), algorithm="proposed",
                              R=3, max_new_tokens=max_new, max_sessions=4,
                              decode_mode=decode_mode,
                              cache_layout=cache_layout, page_size=page_size,
                              device_groups=groups)
    assert len(system.servers) == 3  # R=3: every server hosts every block
    return cfg, system


def test_all_solo_device_groups_are_byte_identical():
    """device_groups with every entry None (or missing) IS the unsharded
    engine: same jit twin from the factory cache, bit-identical serving."""
    cfg, ref = _build_hetero("llama3_2_1b", None)
    jobs = _jobs_for(cfg, (4, 6))
    want = _serve(ref, jobs)

    cfg, system = _build_hetero("llama3_2_1b", {0: None, 2: None})
    for srv in system.servers.values():
        assert srv.mesh is None and srv.n_chips == 1
    got = _serve(system, jobs)
    assert got[0] == want[0] and got[1] == want[1]
    for hg, hw in zip(got[2], want[2]):
        for a, b in zip(hg, hw):
            np.testing.assert_array_equal(a, b)  # bit-for-bit


@needs8
@pytest.mark.parametrize("layout,page_size", [("slab", None), ("paged", 2)])
@pytest.mark.parametrize("mode", ["fused", "serial"])
def test_hetero_groups_match_all_solo_twin(mode, layout, page_size):
    """The hetero acceptance matrix: mixed {solo, 2-device, 4-device}
    groups on one host — token streams and the virtual clock EXACTLY equal
    to the all-solo twin across fused/serial x slab/paged."""
    from repro.launch.mesh import group_meshes

    cfg, ref = _build_hetero("llama3_2_1b", None, decode_mode=mode,
                             cache_layout=layout, page_size=page_size)
    jobs = _jobs_for(cfg, (4, 6, 5))
    want = _serve(ref, jobs)

    groups = group_meshes(HETERO_SHAPES)
    cfg, system = _build_hetero("llama3_2_1b", groups, decode_mode=mode,
                                cache_layout=layout, page_size=page_size)
    assert [system.servers[j].n_chips for j in sorted(system.servers)] \
        == [1, 2, 4]
    got = _serve(system, jobs)
    assert got[0] == want[0], f"hetero/{mode}/{layout}: tokens diverge"
    assert got[1] == want[1], f"hetero/{mode}/{layout}: vclock diverges"
    for hg, hw in zip(got[2], want[2]):
        for a, b in zip(hg, hw):
            np.testing.assert_allclose(a, b, **LOGIT_TOL)


@needs8
def test_hetero_groups_disjoint_devices_and_own_rules():
    """Each server's params/pool live on ITS OWN device slice; per-group
    rule derivation is independent (frozen_serving_rules cache keys on the
    group's mesh)."""
    from repro.launch.mesh import group_meshes
    from repro.launch.sharding import serving_rules

    groups = group_meshes(HETERO_SHAPES)
    cfg, system = _build_hetero("llama3_2_1b", groups)
    seen = set()
    for j, srv in system.servers.items():
        devs = set(srv.group.devices)
        assert not (devs & seen), f"server {j} shares devices"
        seen |= devs
        if srv.mesh is not None:
            assert srv.mesh_rules == serving_rules(
                cfg, srv.mesh, srv.pool.n_rows, srv.pool.max_len)
            for leaf in jax.tree.leaves(srv.run_params):
                assert set(leaf.sharding.device_set) == devs


@needs8
def test_hetero_calibrated_taus_are_non_constant():
    """The acceptance criterion: on a heterogeneous deployment (identical
    spec'd servers, different device groups) calibrate_taus() yields a
    NON-constant vector — bigger groups get smaller per-device roofline
    bounds — and calibrated_problem() carries it while the live problem
    keeps its spec'd τ."""
    from repro.launch.mesh import group_meshes

    groups = group_meshes(HETERO_SHAPES)
    cfg, system = _build_hetero("llama3_2_1b", groups)
    taus = system.calibrate_taus()
    assert set(taus) == {0, 1, 2}
    assert all(np.isfinite(t) and t > 0 for t in taus.values())
    assert len({round(t, 15) for t in taus.values()}) > 1, taus
    # more devices -> per-device step cost can only shrink
    assert taus[2] <= taus[0] * (1 + 1e-9), taus
    cal = system.calibrated_problem()
    np.testing.assert_allclose(cal.tau(), [taus[0], taus[1], taus[2]])
    assert system.problem.tau().tolist() == [0.01, 0.02, 0.03]


def test_device_groups_and_global_mesh_are_exclusive():
    cfg = get_reduced_config("llama3_2_1b")
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="not both"):
        GeoServingSystem(cfg, _params_for(cfg), _problem(cfg), R=2,
                         max_new_tokens=4, max_sessions=4, mesh=mesh,
                         device_groups={0: mesh})


# ---------------------------------------------------------------------------
# Padded MoE EP through the pooled decode step (satellite of PR 9)
# ---------------------------------------------------------------------------


def _pad_model_experts(params, E, E_alloc):
    """Zero-pad the stacked per-layer expert weights (L, E, ...) ->
    (L, E_alloc, ...): the global path slices ``[:E]`` so the pad is inert
    on the solo twin, while a mesh makes the pooled decode step take the
    pure-EP all-to-all (kv_cache._ep_row_grid)."""
    out = jax.tree.map(lambda x: x, params)  # fresh containers, shared leaves
    ffn = out["segments"]["blocks"]["ffn"]
    for k in ("wg", "wu", "wo"):
        w = ffn[k]
        pad = jnp.zeros((w.shape[0], E_alloc - E) + w.shape[2:], w.dtype)
        ffn[k] = jnp.concatenate([w, pad], axis=1)
    return out


@needs8
def test_padded_ep_through_pooled_decode_step():
    """ROADMAP closure: the padded `_apply_moe_ep` all-to-all path runs
    THROUGH a pooled decode step on a real (2,2) mesh — not just
    standalone.  The decoder body regroups the pool's rows into a
    (n_data, rows/n_data) grid for the position-free FFN half; tokens and
    the virtual clock stay EXACTLY equal to the solo twin on the same
    padded params (which the global path slices back to E)."""
    import repro.serving.kv_cache as KV
    from repro.launch.sharding import freeze_rules

    cfg = get_reduced_config("llama4_scout_17b_a16e")
    E = cfg.n_experts
    params = _pad_model_experts(_params_for(cfg), E, 2 * E)

    def build(mesh):
        return GeoServingSystem(cfg, params, _problem(cfg, 2, 4),
                                algorithm="proposed", R=2, max_new_tokens=4,
                                max_sessions=4, mesh=mesh)

    ref = build(None)
    jobs = _jobs_for(cfg, (4, 6, 5))
    want = _serve(ref, jobs)

    mesh = compat_make_mesh((2, 2), ("data", "model"))
    system = build(mesh)
    srv = next(iter(system.servers.values()))
    frozen = freeze_rules(srv.mesh_rules)
    grid = KV._ep_row_grid(cfg, mesh, frozen, srv.run_params[0],
                           srv.pool.n_rows)
    assert grid == (2, srv.pool.n_rows // 2), \
        "pooled decode step did not engage the EP row grid"
    got = _serve(system, jobs)
    assert got[0] == want[0], "EP-through-decode: tokens diverge"
    assert got[1] == want[1], "EP-through-decode: vclock diverges"
    for hg, hw in zip(got[2], want[2]):
        for a, b in zip(hg, hw):
            np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


@needs8
def test_unpadded_moe_keeps_reference_decode_trace():
    """Reduced (unpadded) MoE configs must NOT take the EP decode branch:
    the gate keys on padded expert weights, so existing sharded parity
    stays byte-identical."""
    import repro.serving.kv_cache as KV
    from repro.launch.sharding import freeze_rules

    cfg = get_reduced_config("llama4_scout_17b_a16e")
    mesh = compat_make_mesh((2, 2), ("data", "model"))
    system = GeoServingSystem(cfg, _params_for(cfg), _problem(cfg, 2, 4),
                              R=2, max_new_tokens=4, max_sessions=4,
                              mesh=mesh)
    srv = next(iter(system.servers.values()))
    frozen = freeze_rules(srv.mesh_rules)
    assert KV._ep_row_grid(cfg, mesh, frozen, srv.run_params[0],
                           srv.pool.n_rows) is None


# ---------------------------------------------------------------------------
# Subprocess acceptance: force 8 devices regardless of the parent process
# ---------------------------------------------------------------------------

_ACCEPT_SCRIPT = r"""
import jax, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                        shortest_path_route)
from repro.launch.mesh import compat_make_mesh
from repro.models import init_params
from repro.serving import GeoServingSystem


def run(arch, mesh_shape):
    cfg = get_reduced_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, 100.0, 1.0)
    servers = [ServerSpec(j, 1000.0, 0.01 * (j + 1), 0.002, 0.0005)
               for j in range(2)]
    rtt = np.full((1, 2), 0.02)
    prob = Problem(llm, servers, 1, rtt, 3 * rtt, workload=Workload(4, 4))
    out = {}
    for tag, mesh in (("ref", None),
                      ("sharded", compat_make_mesh(mesh_shape,
                                                   ("data", "model")))):
        system = GeoServingSystem(cfg, params, prob, R=2, max_new_tokens=4,
                                  max_sessions=4, mesh=mesh)
        rng = np.random.RandomState(0)
        sids = []
        for n in (4, 6):
            route, _ = shortest_path_route(prob, system.alive_placement(), 0)
            sids.append(system.create_session(
                rng.randint(2, cfg.vocab_size, n), 0, route, 4))
        assert system.try_admit_sessions(sids) == sids
        system.drain_prefill()
        while any(system.sessions[s].n_generated < 4 for s in sids):
            system.decode_round()
        out[tag] = ([list(system.sessions[s].tokens) for s in sids],
                    [float(system.sessions[s].virtual_time) for s in sids])
    assert out["sharded"][0] == out["ref"][0], (arch, "tokens")
    assert out["sharded"][1] == out["ref"][1], (arch, "vclock")


run("deepseek_v2_236b", (2, 4))   # MLA, TP over model
run("llama4_scout_17b_a16e", (4, 2))  # MoE, EP over data
print("SHARDED_PARITY_OK")
"""


@pytest.mark.slow
def test_forced_8_device_parity_subprocess(tmp_path):
    """The acceptance criterion, self-contained: a fresh interpreter forces
    8 host devices via XLA_FLAGS, then checks sharded-vs-twin token and
    virtual-clock equality for the TP (deepseek MLA) and EP (llama4-scout)
    configs.  Runs in tier-1 even though the parent has 1 device."""
    script = tmp_path / "accept.py"
    script.write_text(_ACCEPT_SCRIPT)
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(repo / "src")
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SHARDED_PARITY_OK" in proc.stdout
