"""Decode parity: prefill(S) + decode(S) == full forward at position S.

The strongest correctness property of the serving path: exercises caches,
rope positions, masks, ring states, and the MLA absorbed decode.  MoE archs
use a no-drop capacity factor (token dropping is capacity-dependent and
intentionally makes train-time prefixes differ — documented semantics).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import NULL_SH, decode_step, init_params, prefill
from repro.models.layers import lm_head
from repro.models.model import forward_full


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = get_reduced_config(arch)
    if cfg.is_moe:
        cfg = cfg.replace(capacity_factor=8.0)  # no-drop for parity
    params, _ = init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 33
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(2, cfg.vocab_size, (B, S + 1)), jnp.int32)
    if cfg.is_enc_dec:
        frames = jnp.asarray(rng.randn(B, 24, cfg.frame_dim), jnp.float32)
        batch_full = {"frames": frames, "tokens": toks}
        batch_pre = {"frames": frames, "tokens": toks[:, :S]}
    else:
        batch_full = {"tokens": toks}
        batch_pre = {"tokens": toks[:, :S]}
    h, _, _ = forward_full(params, cfg, NULL_SH, batch_full)
    ref = lm_head(params["embed"], cfg, NULL_SH, h[:, -1:])[:, 0]
    _, caches = prefill(params, cfg, NULL_SH, batch_pre, cache_len=S + 8)
    got, _ = decode_step(params, cfg, NULL_SH, caches, toks[:, S], S)
    ref32 = np.asarray(ref, np.float32)
    got32 = np.asarray(got, np.float32)
    rel = np.max(np.abs(ref32 - got32)) / (np.max(np.abs(ref32)) + 1e-9)
    assert rel < 5e-4, f"{arch}: decode parity rel err {rel}"
