"""End-to-end behaviour: the full pipeline (placement → routing → engine →
metrics) reproduces the paper's qualitative claims, and the dry-run
machinery lowers a production cell in a fresh 512-device process."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import BLOOM_PETALS, LLMSpec
from repro.sim import SimConfig, clustered_scenario, simulate


def test_bloom_petals_spec_matches_paper():
    # BLOOM-176B: 70 blocks; NF4 block ~1.4 GB; cache 2*d_model*len*2B
    assert BLOOM_PETALS.n_blocks == 70
    assert 1.2e9 < BLOOM_PETALS.block_bytes < 1.7e9
    s_c = BLOOM_PETALS.cache_bytes(148)
    assert 7e6 < s_c < 10e6  # ≈ 8.5 MB for l_in=20, l_out=128


def test_llmspec_from_model_configs():
    from repro.configs import ARCH_IDS, get_config

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        spec = LLMSpec.from_model_config(cfg)
        assert spec.n_blocks == cfg.n_layers
        assert spec.block_bytes > 0
        # per-session cache: MLA << GQA; SSM state is length-free
        if cfg.attn_kind == "mla":
            gqa_like = 2 * cfg.n_kv_heads * cfg.head_dim * 2.0
            assert spec.cache_bytes_per_token < 0.1 * gqa_like
        if cfg.family == "ssm":
            assert spec.cache_bytes_per_token == 0.0
            assert spec.cache_bytes_const > 0


def test_end_to_end_paper_claim():
    """Headline claim: substantially smaller inference times vs PETALS."""
    prob, _ = clustered_scenario()
    petals = simulate(prob, SimConfig("petals", n_requests=100, rate=0.5,
                                      seed=0))
    prop = simulate(prob, SimConfig("proposed", n_requests=100, rate=0.5,
                                    seed=0))
    improvement = 1 - prop.per_token_all / petals.per_token_all
    assert improvement > 0.4, f"only {improvement:.0%} improvement"


@pytest.mark.slow
def test_dryrun_subprocess_cell():
    """Lower+compile one production cell in a fresh process (512 fake
    devices, multi-pod mesh) — the minimal dry-run gate."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out_dir = "/tmp/dryrun_pytest"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch",
           "llama3_2_1b", "--shape", "decode_32k", "--mesh", "multi",
           "--out", out_dir, "--force", "--no-corrections"]
    res = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    path = os.path.join(out_dir, "llama3_2_1b__decode_32k__multi.json")
    with open(path) as f:
        art = json.load(f)
    assert art["n_chips"] == 512
    assert art["roofline"]["dominant"] in ("compute", "memory", "collective")
