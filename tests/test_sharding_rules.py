"""Property tests for the serving sharding rules (launch/sharding.py).

``guarded_spec`` is the single choke point every serving PartitionSpec goes
through, so its invariants carry the whole device-group contract:

* every mesh axis a produced spec assigns to a dim DIVIDES that dim,
* a mesh axis is never used twice within one spec,
* non-divisible dims fall back to replication (never an invalid spec),
* cache spec trees are structurally identical to the cache trees they
  shard, for all four StateSpec families (decoder / recurrent / hybrid /
  enc-dec).

Runs under real hypothesis (bounded by the conftest "ci" profile) or the
conftest fallback shim — strategies are limited to the shim's subset.
"""
import types

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_reduced_config
from repro.launch.sharding import (cache_axes_for, cache_tree_axes,
                                   freeze_rules, guarded_spec,
                                   pool_tree_shardings, serving_rules,
                                   thaw_rules)

SETTINGS = settings(max_examples=20, deadline=None)

# one arch per StateSpec family
FAMILIES = ["llama3_2_1b", "rwkv6_7b", "zamba2_7b", "seamless_m4t_large_v2"]


def _mesh(data, model):
    """Mesh stand-in: the rules/spec machinery only reads ``axis_names`` and
    ``devices.shape``, so property tests can sweep mesh extents without
    forcing host devices."""
    return types.SimpleNamespace(axis_names=("data", "model"),
                                 devices=np.zeros((data, model), np.int8))


def _check_spec(spec, shape, sizes):
    """The guarded_spec invariants for one leaf."""
    used = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        extent = int(np.prod([sizes[a] for a in axes]))
        assert dim % extent == 0, (spec, shape, sizes)
        used += list(axes)
    assert len(used) == len(set(used)), f"mesh axis reused: {spec}"


# ---------------------------------------------------------------------------
# guarded_spec invariants
# ---------------------------------------------------------------------------


@SETTINGS
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64),
       st.sampled_from([1, 2, 4, 8]), st.sampled_from([1, 2, 4]))
def test_guarded_spec_divides_and_never_reuses(d0, d1, d2, model, data):
    mesh = _mesh(data, model)
    rules = {"a": "model", "b": ("data", "model"), "c": "data"}
    spec = guarded_spec(("a", "b", "c"), (d0, d1, d2), rules, mesh)
    _check_spec(spec, (d0, d1, d2), {"data": data, "model": model})


@SETTINGS
@given(st.sampled_from([3, 5, 7, 11, 13]), st.sampled_from([2, 4, 8]))
def test_guarded_spec_replicates_nondivisible(dim, model):
    """Prime dims not divisible by the mesh extent must REPLICATE, not
    error — the engine picks pool row counts freely."""
    mesh = _mesh(2, model)
    spec = guarded_spec(("x",), (dim,), {"x": "model"}, mesh)
    assert tuple(spec) == (None,)


@SETTINGS
@given(st.sampled_from([1, 2, 4, 8]), st.sampled_from([1, 2, 4]))
def test_guarded_spec_tuple_axes_partial_use(model, data):
    """When one axis of a ("data", "model") pair is already claimed by an
    earlier dim, the survivor alone must still divide — and the produced
    spec must contain ONLY unused axes."""
    mesh = _mesh(data, model)
    rules = {"m": "model", "dm": ("data", "model")}
    # dim0 takes "model"; dim1 may then only use "data"
    spec = guarded_spec(("m", "dm"), (8 * model, 8 * data), rules, mesh)
    _check_spec(spec, (8 * model, 8 * data), {"data": data, "model": model})
    if model > 1:
        assert spec[0] == "model"
        assert spec[1] in (None, "data", ("data",))


@SETTINGS
@given(st.sampled_from([None, "model", "data", ("data", "model")]),
       st.integers(1, 32))
def test_guarded_spec_unknown_logical_replicates(axis, dim):
    """Logical names absent from the rules (or mapped to None) replicate."""
    mesh = _mesh(2, 4)
    rules = {} if axis is None else {"known": axis}
    spec = guarded_spec(("missing",), (dim,), rules, mesh)
    assert tuple(spec) == (None,)


# ---------------------------------------------------------------------------
# freeze / thaw round-trip
# ---------------------------------------------------------------------------


@SETTINGS
@given(st.sampled_from(["model", "data", None]),
       st.sampled_from(["model", None]), st.booleans())
def test_freeze_rules_canonical_and_roundtrips(v1, v2, flip):
    a = {"batch": v1, "mlp": v2}
    b = {"mlp": v2, "batch": v1}  # same mapping, different insertion order
    if flip:
        a, b = b, a
    assert freeze_rules(a) == freeze_rules(b)
    assert thaw_rules(freeze_rules(a)) == a
    assert hash(freeze_rules(a)) == hash(freeze_rules(b))


# ---------------------------------------------------------------------------
# Cache trees: axes and spec trees for all four StateSpec families
# ---------------------------------------------------------------------------

_POOLS = {}


def _pool(arch, layout="slab"):
    from repro.serving.kv_cache import CachePool, state_specs

    key = (arch, layout)
    if key not in _POOLS:
        cfg = get_reduced_config(arch)
        kinds = tuple(s.kind for s in state_specs(cfg))
        enc = 6 if cfg.is_enc_dec else 0
        _POOLS[key] = (cfg, CachePool(cfg, kinds, 4, 8, 4, enc_len=enc,
                                      layout=layout,
                                      page_size=2 if layout == "paged"
                                      else 0))
    return _POOLS[key]


@pytest.mark.parametrize("layout", ["slab", "paged"])
@pytest.mark.parametrize("arch", FAMILIES)
def test_cache_axes_tree_matches_cache_tree(arch, layout):
    """cache_tree_axes mirrors the cache tree leaf-for-leaf, and every axes
    tuple has exactly one logical name per array dim."""
    cfg, pool = _pool(arch, layout)
    axes = cache_tree_axes(pool.tree)
    # an axes leaf is a tuple of logical names / None — the pool tree's
    # outer tuple-of-run-dicts is a container, not a leaf
    is_ax = lambda x: (isinstance(x, tuple)
                       and all(a is None or isinstance(a, str) for a in x))
    assert (jax.tree.structure(axes, is_leaf=is_ax)
            == jax.tree.structure(pool.tree))
    for ax, leaf in zip(jax.tree.leaves(axes, is_leaf=is_ax),
                        jax.tree.leaves(pool.tree)):
        assert len(ax) == leaf.ndim


@pytest.mark.parametrize("arch", FAMILIES)
def test_cache_leaf_specs_divide_for_all_families(arch):
    """For every cache leaf of every family, across a sweep of mesh
    extents, the produced spec obeys the divisibility + no-reuse
    invariants.  (This is the property that makes engine-chosen pool
    shapes safe under any mesh.)"""
    cfg, pool = _pool(arch)
    for data, model in [(1, 2), (2, 2), (2, 4), (1, 8), (4, 2)]:
        mesh = _mesh(data, model)
        rules = serving_rules(cfg, mesh, n_rows=4, max_len=8)
        scratch = dict(rules)  # cache_axes_for may add kv_time_noverlap

        def one(path, leaf):
            name = next((p.key for p in reversed(path)
                         if hasattr(p, "key")), None)
            axes = cache_axes_for(name, leaf.ndim, scratch)
            spec = guarded_spec(axes, leaf.shape, scratch, mesh)
            _check_spec(spec, leaf.shape,
                        {"data": data, "model": model})
            return None

        jax.tree_util.tree_map_with_path(one, pool.tree)


@pytest.mark.parametrize("layout", ["slab", "paged"])
@pytest.mark.parametrize("arch", FAMILIES)
def test_pool_tree_shardings_structure(arch, layout):
    """pool_tree_shardings yields a NamedSharding per leaf with the exact
    tree structure of the pool (slab AND paged layouts)."""
    from repro.launch.mesh import compat_make_mesh

    cfg, pool = _pool(arch, layout)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    rules = serving_rules(cfg, mesh, n_rows=4, max_len=8)
    sh = pool_tree_shardings(mesh, rules, pool.tree)
    assert jax.tree.structure(sh) == jax.tree.structure(pool.tree)
    for s, leaf in zip(jax.tree.leaves(sh), jax.tree.leaves(pool.tree)):
        assert isinstance(s, NamedSharding)
        assert len(tuple(s.spec)) <= leaf.ndim


# ---------------------------------------------------------------------------
# Mixed mesh extents: per-group rule derivation (heterogeneous device groups)
# ---------------------------------------------------------------------------

GROUP_EXTENTS = [(1, 1), (1, 2), (2, 1), (2, 2), (2, 4), (4, 2), (1, 8)]


class _HashableMesh:
    """Like ``_mesh`` but hashable by identity (no ``__eq__``), so it can
    key the ``frozen_serving_rules`` lru_cache like a real Mesh does."""

    def __init__(self, data, model):
        self.axis_names = ("data", "model")
        self.devices = np.zeros((data, model), np.int8)


@SETTINGS
@given(st.sampled_from(FAMILIES), st.sampled_from([1, 2, 3, 4, 6, 8]),
       st.sampled_from([8, 16, 32]))
def test_rules_hold_independently_per_group(arch, n_rows, max_len):
    """One heterogeneous deployment, many groups: rules derived for
    DIFFERENT mesh extents must each satisfy the divisibility / no-reuse /
    replication invariants against THEIR OWN mesh — a 2-device group's
    rules never leak into a 4-device group's specs (the per-server
    DeviceGroup contract)."""
    cfg, pool = _pool(arch)
    for data, model in GROUP_EXTENTS:
        mesh = _mesh(data, model)
        rules = serving_rules(cfg, mesh, n_rows=n_rows, max_len=max_len)
        # batch maps to the data axis only when THIS group's extent divides
        if rules["batch"] is not None:
            assert n_rows % data == 0, (arch, n_rows, data)
        scratch = dict(rules)

        def one(path, leaf, mesh=mesh, scratch=scratch, sizes={"data": data,
                                                               "model": model}):
            name = next((p.key for p in reversed(path)
                         if hasattr(p, "key")), None)
            axes = cache_axes_for(name, leaf.ndim, scratch)
            spec = guarded_spec(axes, leaf.shape, scratch, mesh)
            _check_spec(spec, leaf.shape, sizes)
            return None

        jax.tree_util.tree_map_with_path(one, pool.tree)


def test_frozen_serving_rules_cache_keys_per_group():
    """``frozen_serving_rules`` memoizes per (cfg, mesh, rows, len): the
    same group hits the cache (identical object), different groups get
    independent derivations that thaw back to ``serving_rules``."""
    from repro.launch.sharding import frozen_serving_rules

    cfg = get_reduced_config("llama3_2_1b")
    m1, m2 = _HashableMesh(1, 2), _HashableMesh(2, 2)
    f1 = frozen_serving_rules(cfg, m1, 4, 8)
    assert frozen_serving_rules(cfg, m1, 4, 8) is f1  # cache hit
    f2 = frozen_serving_rules(cfg, m2, 4, 8)
    assert thaw_rules(f1) == serving_rules(cfg, m1, 4, 8)
    assert thaw_rules(f2) == serving_rules(cfg, m2, 4, 8)
    # per-group keying: a different n_rows is a different cache entry
    assert frozen_serving_rules(cfg, m1, 3, 8) is not f1


def test_device_group_descriptor():
    """DeviceGroup: solo twin (mesh=None) owns no devices and derives no
    rules; a mesh group derives (and freezes) its own rules; dict overrides
    are frozen at construction; as_device_group normalizes."""
    from repro.launch.sharding import (DeviceGroup, as_device_group,
                                       frozen_serving_rules)

    solo = as_device_group(None)
    assert solo.mesh is None and solo.n_chips == 1 and solo.devices == ()
    cfg = get_reduced_config("llama3_2_1b")
    assert solo.frozen_rules_for(cfg, 4, 8) is None

    mesh = _HashableMesh(2, 2)
    g = as_device_group(mesh)
    assert g.mesh is mesh and g.n_chips == 4 and len(g.devices) == 4
    assert g.frozen_rules_for(cfg, 4, 8) == frozen_serving_rules(
        cfg, mesh, 4, 8)
    assert as_device_group(g) is g  # idempotent

    override = DeviceGroup(mesh=mesh,
                           rules={"batch": None, "mlp": "model"})
    assert isinstance(override.rules, tuple)  # frozen at construction
    assert override.frozen_rules_for(cfg, 4, 8) == override.rules
    assert thaw_rules(override.rules)["batch"] is None


def test_serving_rules_disable_sequence_sharding():
    """Pooled steps vmap one token per row — serving rules must never
    sequence-shard activations, whatever make_rules would pick."""
    for arch in FAMILIES + ["deepseek_v2_236b", "llama4_scout_17b_a16e"]:
        cfg = get_reduced_config(arch)
        rules = serving_rules(cfg, _mesh(2, 4), n_rows=8, max_len=32)
        assert rules["seq_act"] is None
        assert rules["attn_seq_q"] is None
