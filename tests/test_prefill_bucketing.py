"""Batched prefill with prompt-length bucketing: bit-exact parity of the
bucketed/chunked pooled path against the legacy serial prefill, chunk
interleaving with decode rounds, and group deferral under the slot budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                        shortest_path_route)
from repro.models import NULL_SH, decode_step, init_params, prefill
from repro.serving import (ContinuousBatchingScheduler, GeoServingSystem,
                           bucket_for, default_prefill_buckets)
from repro.sim.workload import bursty_requests, prompts_for_lengths


def _build(arch="llama3_2_1b", n_servers=4, R=2, mem=900.0, max_sessions=8,
           l_out=8, max_new=8, prefill_mode="batched", prefill_buckets=None,
           l_in=4):
    cfg = get_reduced_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=50.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=mem, tau=0.01 * (j + 1),
                          tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3,
                   workload=Workload(l_in, l_out))
    system = GeoServingSystem(cfg, params, prob, algorithm="proposed", R=R,
                              max_new_tokens=max_new,
                              max_sessions=max_sessions,
                              prefill_mode=prefill_mode,
                              prefill_buckets=prefill_buckets)
    return cfg, params, prob, system


def _run_group(system, prompts, n_new, coalesce: bool):
    """Create all sessions, admit them (in one batch when ``coalesce``),
    decode to completion.  Returns per-session (tokens, [logits/token])."""
    sids = []
    for toks in prompts:
        route, _ = shortest_path_route(system.problem,
                                       system.alive_placement(), 0)
        sids.append(system.create_session(toks, 0, route, n_new))
    if coalesce:
        admitted = system.try_admit_sessions(sids)
        assert admitted == sids, "every session must fit"
        system.drain_prefill()
    else:
        for sid in sids:
            assert system.try_admit_session(sid)
    hist = {sid: [np.asarray(system.sessions[sid].last_logits)]
            for sid in sids}
    while True:
        todo = [s for s in sids if system.sessions[s].n_generated < n_new]
        if not todo:
            break
        system.decode_round(todo)
        for sid in todo:
            hist[sid].append(np.asarray(system.sessions[sid].last_logits))
    out = [list(system.sessions[sid].tokens) for sid in sids]
    for sid in sids:
        system.retire_session(sid)
    return out, [hist[s] for s in sids]


def _monolithic_ref(cfg, params, prompt, n_new):
    logits, caches = prefill(params, cfg, NULL_SH,
                             {"tokens": jnp.asarray(prompt)[None]},
                             cache_len=len(prompt) + n_new + 4)
    seq = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, caches = decode_step(params, cfg, NULL_SH, caches,
                                 jnp.asarray([seq[-1]]), pos)
        seq.append(int(jnp.argmax(lg[0])))
        pos += 1
    return seq


def test_default_buckets_and_lookup():
    assert default_prefill_buckets(44) == (8, 16, 32, 44)
    assert default_prefill_buckets(8) == (8,)
    assert bucket_for((8, 16), 3) == 8
    assert bucket_for((8, 16), 8) == 8
    assert bucket_for((8, 16), 9) == 16
    assert bucket_for((8, 16), 17) is None  # overflow -> chunked


def test_single_session_bucket_bitexact():
    """A group of ONE padded session (prompt 5 -> bucket 8) must match the
    legacy serial (exact-length) prefill bit-for-bit."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(2, 64, 5)]
    _, _, _, sys_serial = _build(prefill_mode="serial", l_in=5)
    toks_s, logits_s = _run_group(sys_serial, prompts, 6, coalesce=False)
    _, _, _, sys_batched = _build(prefill_mode="batched", l_in=5)
    toks_b, logits_b = _run_group(sys_batched, prompts, 6, coalesce=True)
    assert toks_s == toks_b
    for a, b in zip(logits_s[0], logits_b[0]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("arch", ["llama3_2_1b", "rwkv6_7b"])
def test_mixed_length_group_parity(arch):
    """Mixed-length co-admitted sessions (two buckets for decoder; exact-
    length groups for rwkv) must reproduce the serial path bit-for-bit."""
    rng = np.random.RandomState(1)
    lengths = [3, 5, 5, 9, 12]
    prompts = [rng.randint(2, 64, n) for n in lengths]
    n_new = 5
    cfg, params, _, sys_serial = _build(arch, prefill_mode="serial", l_in=6)
    toks_s, logits_s = _run_group(sys_serial, prompts, n_new, coalesce=False)
    _, _, _, sys_batched = _build(arch, prefill_mode="batched", l_in=6)
    toks_b, logits_b = _run_group(sys_batched, prompts, n_new, coalesce=True)
    assert toks_s == toks_b
    for ls, lb in zip(logits_s, logits_b):
        assert len(ls) == len(lb) == n_new
        for a, b in zip(ls, lb):
            np.testing.assert_array_equal(a, b)
    # and the serial reference itself equals the monolithic stack
    for p, got in zip(prompts, toks_s):
        assert got[len(p):] == _monolithic_ref(cfg, params, p, n_new)


def test_chunked_long_prompt_parity():
    """A prompt longer than the largest bucket is prefilled in chunks that
    attend over the already-cached prefix.  The chunked path must be
    bit-for-bit identical whether the session is admitted alone or in a
    batch (the fixed-shape pooled program makes this structural), and must
    generate the exact serial/monolithic token stream."""
    rng = np.random.RandomState(2)
    prompts = [rng.randint(2, 64, 19)]  # chunks [0:8) [8:16) [16:19)->pad 8
    n_new = 6
    cfg, params, _, sys_seq = _build(prefill_mode="batched",
                                     prefill_buckets=(4, 8), l_in=19)
    assert bucket_for(sys_seq.prefill_buckets, 19) is None
    toks_q, logits_q = _run_group(sys_seq, prompts, n_new, coalesce=False)
    _, _, _, sys_batched = _build(prefill_mode="batched",
                                  prefill_buckets=(4, 8), l_in=19)
    toks_b, logits_b = _run_group(sys_batched, prompts, n_new, coalesce=True)
    assert toks_q == toks_b
    for a, b in zip(logits_q[0], logits_b[0]):
        np.testing.assert_array_equal(a, b)  # bit-for-bit
    # token stream equals the serial exact-length path and the monolithic
    # stack (padding jitters logits at float-eps scale, never the argmax)
    _, _, _, sys_serial = _build(prefill_mode="serial", l_in=19)
    toks_s, _ = _run_group(sys_serial, prompts, n_new, coalesce=False)
    assert toks_b == toks_s
    assert toks_b[0][19:] == _monolithic_ref(cfg, params, prompts[0], n_new)


def test_chunked_mixed_with_short_group():
    """Chunked long prompts co-admitted WITH short bucketed prompts: every
    session bit-exact vs its own solo admission, and token-exact vs the
    serial engine."""
    rng = np.random.RandomState(3)
    prompts = [rng.randint(2, 64, 19), rng.randint(2, 64, 4),
               rng.randint(2, 64, 17)]
    n_new = 5
    _, _, _, sys_seq = _build(prefill_mode="batched", prefill_buckets=(4, 8),
                              l_in=8)
    toks_q, logits_q = _run_group(sys_seq, prompts, n_new, coalesce=False)
    _, _, _, sys_batched = _build(prefill_mode="batched",
                                  prefill_buckets=(4, 8), l_in=8)
    toks_b, logits_b = _run_group(sys_batched, prompts, n_new, coalesce=True)
    assert toks_q == toks_b
    for ls, lb in zip(logits_q, logits_b):
        for a, b in zip(ls, lb):
            np.testing.assert_array_equal(a, b)  # bit-for-bit
    _, _, _, sys_serial = _build(prefill_mode="serial", l_in=8)
    toks_s, _ = _run_group(sys_serial, prompts, n_new, coalesce=False)
    assert toks_b == toks_s


def test_chunk_rounds_interleave_with_decode():
    """While a long prompt prefills chunk by chunk, a resident active
    session must be able to decode between chunk rounds (no head-of-line
    blocking) — and the late-prefilling session still matches serial."""
    rng = np.random.RandomState(4)
    short, long = rng.randint(2, 64, 4), rng.randint(2, 64, 19)
    n_new = 6
    _, _, _, system = _build(prefill_mode="batched", prefill_buckets=(4, 8),
                             l_in=8)
    route, _ = shortest_path_route(system.problem, system.alive_placement(), 0)
    sid_a = system.create_session(short, 0, route, n_new)
    assert system.try_admit_session(sid_a)
    route, _ = shortest_path_route(system.problem, system.alive_placement(), 0)
    sid_b = system.create_session(long, 0, route, n_new)
    assert system.try_admit_sessions([sid_b]) == [sid_b]
    decoded_during_prefill = 0
    rounds = 0
    while system.has_pending_prefill():
        system.prefill_round()
        rounds += 1
        if system.has_pending_prefill():
            before = system.sessions[sid_a].n_generated
            system.decode_round()
            decoded_during_prefill += (system.sessions[sid_a].n_generated
                                       - before)
    assert rounds == 3  # chunks [0:8) [8:16) [16:19)
    assert decoded_during_prefill >= 2, \
        "resident session must advance between chunk rounds"
    while any(system.sessions[s].n_generated < n_new for s in (sid_a, sid_b)):
        system.decode_round()
    # bit-exact check of the chunk-interleaved session vs the serial engine
    _, _, _, sys_serial = _build(prefill_mode="serial", l_in=8)
    toks_s, _ = _run_group(sys_serial, [short, long], n_new, coalesce=False)
    assert list(system.sessions[sid_a].tokens) == toks_s[0]
    assert list(system.sessions[sid_b].tokens) == toks_s[1]


def test_group_deferral_when_budget_exhausted():
    """A co-admitted batch larger than the slot budget: the fitting prefix
    is admitted as a group, the overflow claims nothing and is deferred by
    the scheduler — no overbooking, everyone eventually served."""
    # one server hosting both blocks, 8 block-slots, k=2 per session ->
    # at most 4 resident sessions
    cfg, params, prob, system = _build(n_servers=1, R=1, mem=180.0,
                                       max_sessions=8, l_out=6, max_new=6)
    # engine level: direct batch admission admits only what fits
    rng = np.random.RandomState(5)
    sids = []
    for _ in range(6):
        route, _ = shortest_path_route(prob, system.alive_placement(), 0)
        sids.append(system.create_session(rng.randint(2, 64, 4), 0, route, 6))
    admitted = system.try_admit_sessions(sids)
    system.drain_prefill()
    assert 0 < len(admitted) < len(sids), (admitted, sids)
    for used, cap in system.slot_usage().values():
        assert used <= cap
    for sid in admitted:
        system.retire_session(sid)
    for sid in set(sids) - set(admitted):
        assert system.sessions[sid].state == "admitted"  # claimed nothing
        system.sessions.pop(sid)

    # scheduler level: a same-timestamp burst under the same tight budget
    _, _, _, system2 = _build(n_servers=1, R=1, mem=180.0, max_sessions=8,
                              l_out=6, max_new=6)
    sched = ContinuousBatchingScheduler(system2, R=1)
    for i in range(6):
        sched.submit(i, rng.randint(2, cfg.vocab_size, 4), 0.0, n_new=6)
    served = sched.run()
    assert len(served) == 6 and not any(r.dropped for r in served)
    # WS-RR spreads committed starts, so the overflow either waits (the
    # controller predicted the contention) or defers (it did not)
    assert any(r.wait > 0 for r in served) or \
        any(r.n_deferrals > 0 for r in served)
    for used, cap in system2.slot_usage().values():
        assert used == 0


def test_bucketed_failover_replay_exact():
    """Failover replay must reproduce bucket-group-prefilled caches: kill a
    server after co-admitted (padded) sessions started decoding."""
    cfg, params, prob, system = _build(n_servers=4, R=2, l_in=6)
    rng = np.random.RandomState(6)
    prompts = [rng.randint(2, cfg.vocab_size, 5),
               rng.randint(2, cfg.vocab_size, 7)]
    n_new = 6
    refs = [_monolithic_ref(cfg, params, p, n_new) for p in prompts]
    sids = []
    for p in prompts:
        route, _ = shortest_path_route(prob, system.alive_placement(), 0)
        sids.append(system.create_session(p, 0, route, n_new))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    system.decode_round(sids)
    victim = system.sessions[sids[0]].route.servers[0]
    system.kill_server(victim)
    while any(system.sessions[s].n_generated < n_new for s in sids):
        system.decode_round(
            [s for s in sids if system.sessions[s].n_generated < n_new])
    for sid, p, ref in zip(sids, prompts, refs):
        sess = system.sessions[sid]
        assert victim not in sess.route.servers
        assert sess.tokens[len(p):] == ref
        system.retire_session(sid)


def test_chunked_failover_replay_exact():
    """Failover of a session whose prompt was CHUNK-prefilled: the replay
    must follow the session's chunk plan through the same pooled programs
    (legacy exact-length replay would rebuild subtly different caches)."""
    cfg, params, prob, system = _build(n_servers=4, R=2, l_in=8,
                                       prefill_buckets=(4, 8))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(2, cfg.vocab_size, 19),
               rng.randint(2, cfg.vocab_size, 17)]
    n_new = 6
    refs = [_monolithic_ref(cfg, params, p, n_new) for p in prompts]
    sids = []
    for p in prompts:
        route, _ = shortest_path_route(prob, system.alive_placement(), 0)
        sids.append(system.create_session(p, 0, route, n_new))
    assert system.try_admit_sessions(sids) == sids
    system.drain_prefill()
    system.decode_round(sids)
    victim = system.sessions[sids[0]].route.servers[0]
    system.kill_server(victim)
    while any(system.sessions[s].n_generated < n_new for s in sids):
        system.decode_round(
            [s for s in sids if system.sessions[s].n_generated < n_new])
    for sid, p, ref in zip(sids, prompts, refs):
        sess = system.sessions[sid]
        assert victim not in sess.route.servers
        assert sess.tokens[len(p):] == ref
        system.retire_session(sid)


def test_bursty_trace_mixed_lengths_end_to_end():
    """Bursty arrivals with mixed prompt lengths through the full
    scheduler: same tokens as the serial engine, zero drops."""
    lengths = (3, 5, 9, 12)
    reqs = bursty_requests(n_bursts=2, burst_size=4, spacing=5.0)
    results = {}
    for mode in ("serial", "batched"):
        cfg, params, prob, system = _build(mem=2000.0, max_sessions=10,
                                           l_out=6, max_new=6, l_in=8,
                                           prefill_mode=mode)
        sched = ContinuousBatchingScheduler(system, R=8)
        prompts = prompts_for_lengths(reqs, lengths, cfg.vocab_size, seed=9)
        for req, toks in zip(reqs, prompts):
            sched.submit(req.rid, toks, req.arrival, n_new=6)
        served = sched.run()
        assert len(served) == 8 and not any(r.dropped for r in served)
        results[mode] = ([list(r.tokens) for r in served],
                         [(r.start, r.first_token, r.per_token) for r in
                          served])
    assert results["serial"][0] == results["batched"][0], "same tokens"
    for a, b in zip(results["serial"][1], results["batched"][1]):
        np.testing.assert_allclose(a, b, rtol=1e-12), \
            "virtual clock must not depend on prefill batching"


def test_scheduler_coalesces_same_time_starts():
    """A same-timestamp burst must reach the engine as ONE admission batch
    (the bucket group), not as one-session batches: arrivals process before
    same-time starts, so every zero-wait start is in the heap when the
    first pops."""
    cfg, params, prob, system = _build(mem=2000.0, max_sessions=8, l_out=6,
                                       max_new=6)
    batches = []
    orig = system.try_admit_sessions

    def spy(sids, now=0.0):
        batches.append(list(sids))
        return orig(sids, now=now)

    system.try_admit_sessions = spy
    sched = ContinuousBatchingScheduler(system, R=8)
    rng = np.random.RandomState(11)
    for rid in range(4):
        sched.submit(rid, rng.randint(2, cfg.vocab_size, 4), 0.0, n_new=6)
    served = sched.run()
    assert len(served) == 4 and not any(r.dropped for r in served)
    assert any(len(b) == 4 for b in batches), \
        f"burst must admit as one batch, got {batches}"


@pytest.mark.parametrize("R", [4, 8])
def test_engine_vs_simulator_bursty_tolerance(R):
    """Same bursty trace through the simulator and the real engine: mean
    per-token and first-token times agree within 10%."""
    from benchmarks.engine_validation import cross_validate

    eng, simm, err = cross_validate(R, n_requests=8, trace="bursty")
    assert err["per_token_all"] < 0.10, (eng, simm)
    assert err["first_token"] < 0.10, (eng, simm)


def test_crash_during_prefill_group():
    """Silent crash of one group's route server between chunk rounds:
    that group's in-flight members fail with a machine-readable reason
    and billed timeout detection, while the OTHER group (distinct route)
    prefill-completes and decodes bit-exact vs a fault-free run."""
    from repro.core.perf_model import Route

    def _setup():
        cfg, params, prob, system = _build(prefill_buckets=(4,), l_in=12,
                                           max_new=5, l_out=5)
        rng = np.random.RandomState(5)
        prompts = [rng.randint(2, cfg.vocab_size, 12) for _ in range(4)]
        sids = []
        for i, toks in enumerate(prompts):
            j = 1 if i < 2 else 2  # group A -> server 1, group B -> server 2
            a, m = int(system.placement.a[j]), int(system.placement.m[j])
            assert a == 0 and m == prob.L, "toy placement must replicate"
            sids.append(system.create_session(
                toks, 0, Route(servers=(j,), blocks=(m,)), 5))
        assert system.try_admit_sessions(sids) == sids
        assert len(system._prefill_groups) == 2  # distinct routes
        return system, sids

    # fault-free twin: group B's oracle streams
    ref, ref_sids = _setup()
    ref.drain_prefill()
    while any(ref.sessions[s].n_generated < 5 for s in ref_sids):
        ref.decode_round()
    ref_b = [list(ref.sessions[s].tokens) for s in ref_sids[2:]]

    system, sids = _setup()
    system.prefill_round()  # one chunk round: both groups mid-prompt
    system.inject_crash(1)  # silent: next dispatch discovers it
    system.drain_prefill()
    while any(system.sessions[s].state == "active"
              and system.sessions[s].n_generated < 5 for s in sids):
        system.decode_round()

    for sid in sids[:2]:  # group A: failed mid-prefill, detection billed
        sess = system.sessions[sid]
        assert sess.state == "failed"
        assert sess.fail_reason == "server_lost_mid_prefill"
        assert sess.n_detections >= 1 and sess.detect_time > 0.0
    # group B: untouched, bit-exact streams
    assert [list(system.sessions[s].tokens) for s in sids[2:]] == ref_b
    assert all(system.sessions[s].recovery_time == 0.0 for s in sids[2:])
    assert not system.servers[1].alive and 1 in system.suspected_servers()
    # failed members released their claims: no leaked slots on server 1
    for sid in sids:
        system.retire_session(sid)
    assert all(u == 0 for u, _ in system.slot_usage().values())
