"""Geo serving engine: block-partition equivalence, exact failover recovery,
elastic scale-out, and straggler avoidance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import LLMSpec, Problem, ServerSpec, Workload
from repro.models import NULL_SH, decode_step, init_params, prefill
from repro.serving import GeoServingSystem, generate


def _setup(arch="llama3_2_1b", n_servers=4, R=2):
    cfg = get_reduced_config(arch)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    llm = LLMSpec("toy", cfg.n_layers, block_bytes=100.0,
                  cache_bytes_per_token=1.0)
    servers = [ServerSpec(j, mem_bytes=500.0, tau=0.01 * (j + 1))
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.02)
    prob = Problem(llm, servers, 1, rtt, rtt * 3, workload=Workload(4, 8))
    system = GeoServingSystem(cfg, params, prob, algorithm="proposed", R=R)
    return cfg, params, prob, system


def _reference_tokens(cfg, params, toks, n_new):
    logits, caches = prefill(params, cfg, NULL_SH,
                             {"tokens": jnp.asarray(toks)[None]},
                             cache_len=len(toks) + n_new + 4)
    seq = [int(jnp.argmax(logits[0]))]
    pos = len(toks)
    for _ in range(n_new - 1):
        lg, caches = decode_step(params, cfg, NULL_SH, caches,
                                 jnp.asarray([seq[-1]]), pos)
        seq.append(int(jnp.argmax(lg[0])))
        pos += 1
    return seq


@pytest.mark.parametrize("arch", ["llama3_2_1b", "rwkv6_7b"])
def test_engine_matches_monolithic(arch):
    cfg, params, prob, system = _setup(arch)
    rng = np.random.RandomState(0)
    toks = rng.randint(2, cfg.vocab_size, 7)
    out, vt = generate(system, toks, 5)
    ref = _reference_tokens(cfg, params, toks, 5)
    assert list(out[len(toks): len(toks) + 5]) == ref
    assert vt > 0


def test_failover_recovery_exact():
    cfg, params, prob, system = _setup()
    rng = np.random.RandomState(0)
    toks = rng.randint(2, cfg.vocab_size, 7)
    ref = _reference_tokens(cfg, params, toks, 5)
    sid, logits = system.submit(toks)
    seq = [int(jnp.argmax(logits[0]))]
    lg = system.decode(sid, seq[-1])
    seq.append(int(jnp.argmax(lg[0])))
    victim = system.sessions[sid].route.servers[0]
    system.kill_server(victim)
    for _ in range(3):
        lg = system.decode(sid, seq[-1])
        seq.append(int(jnp.argmax(lg[0])))
    assert seq == ref, "post-failover generation must be identical"
    assert victim not in system.sessions[sid].route.servers


def test_new_sessions_avoid_dead_servers():
    cfg, params, prob, system = _setup()
    rng = np.random.RandomState(1)
    toks = rng.randint(2, cfg.vocab_size, 5)
    system.kill_server(0)
    sid, _ = system.submit(toks)
    assert 0 not in system.sessions[sid].route.servers


def test_elastic_join():
    cfg, params, prob, system = _setup(n_servers=2)
    spec = ServerSpec(99, mem_bytes=500.0, tau=0.001)  # much faster server
    system.join_server(spec, rtt_token_col=[0.02], rtt_prefill_col=[0.06])
    assert system.problem.n_servers == 3
    rng = np.random.RandomState(2)
    toks = rng.randint(2, cfg.vocab_size, 5)
    sid, _ = system.submit(toks)
    # the fast new server should host blocks and attract routing
    assert 2 in system.sessions[sid].route.servers


def test_straggler_avoidance():
    cfg, params, prob, system = _setup(n_servers=4)
    rng = np.random.RandomState(3)
    toks = rng.randint(2, cfg.vocab_size, 5)
    sid0, _ = system.submit(toks)
    fast_route = system.sessions[sid0].route.servers
    system.finish(sid0)
    # make the previously chosen first server 100x slower
    system.set_slowdown(int(fast_route[0]), 100.0)
    sid1, _ = system.submit(toks)
    assert system.sessions[sid1].route.servers[0] != fast_route[0]
