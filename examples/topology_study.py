"""Scattered-deployment study (paper Figs 6/8 in miniature): sweep #servers
and request rate over a Topology-Zoo-style network and print CSV.

Run:  PYTHONPATH=src python examples/topology_study.py
"""
from repro.sim import run_comparison

import sys
sys.path.insert(0, ".")
from benchmarks.common import scattered_problem  # noqa: E402


def main():
    print("sweep,value,petals_s,proposed_s,improvement")
    for C in (10, 14, 19):
        prob = scattered_problem("bellcanada", C=C)
        out = run_comparison(prob, ("petals", "proposed"), n_requests=50,
                             rate=0.5, seeds=(0, 1))
        imp = 1 - out["proposed"]["per_token_all"] / out["petals"]["per_token_all"]
        print(f"servers,{C},{out['petals']['per_token_all']:.2f},"
              f"{out['proposed']['per_token_all']:.2f},{imp:.0%}")
    for rate in (0.1, 0.3, 0.6):
        prob = scattered_problem("abovenet")
        out = run_comparison(prob, ("petals", "proposed"), n_requests=50,
                             rate=rate, seeds=(0, 1))
        imp = 1 - out["proposed"]["per_token_all"] / out["petals"]["per_token_all"]
        print(f"rate,{rate},{out['petals']['per_token_all']:.2f},"
              f"{out['proposed']['per_token_all']:.2f},{imp:.0%}")


if __name__ == "__main__":
    main()
