"""Quickstart: the paper's BPRR algorithms on a toy geo-distributed cluster.

Builds the paper's clustered scenario (Table 2: 2 A100-class + 7 MIG-class
servers serving BLOOM-176B), runs PETALS' heuristics vs the proposed
CG-BP + WS-RR, and prints the placements, routes, bounds, and simulated
inference times.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (auto_R, cg_bp, cg_upper_bound, lower_bound,
                        petals_bp, petals_route, route_per_token_time,
                        shortest_path_route)
from repro.sim import SimConfig, clustered_scenario, simulate


def main():
    problem, clusters = clustered_scenario(client_cluster=0)
    print(f"model: {problem.llm.name}  L={problem.L} blocks  "
          f"s_m={problem.s_m/2**30:.2f} GB  s_c={problem.s_c/2**20:.1f} MB")

    R = auto_R(problem, arrival_rate=0.5, expected_session_s=150.0)
    print(f"\ndesign concurrency |R| = {R} (mean+std rule, Cor. 3.6)")

    pl_pet = petals_bp(problem)
    pl_cg, info = cg_bp(problem, R)
    print(f"PETALS placement  m_j = {pl_pet.m}")
    print(f"CG-BP  placement  m_j = {pl_cg.m}  (order {info.order})")

    route_pet = petals_route(problem, pl_pet, 0)
    route_cg, _ = shortest_path_route(problem, pl_cg, 0)
    print(f"\nPETALS route: servers {route_pet.servers} "
          f"blocks {route_pet.blocks} "
          f"-> {route_per_token_time(problem, route_pet, 0):.3f} s/token")
    print(f"CG-BPRR route: servers {route_cg.servers} "
          f"blocks {route_cg.blocks} "
          f"-> {route_per_token_time(problem, route_cg, 0):.3f} s/token")
    print(f"bound (17): {cg_upper_bound(problem, R):.3f} s/token;  "
          f"lower bound (35): {lower_bound(problem):.3f} s/token")

    print("\nsimulating 100 requests at 0.5 req/s ...")
    for alg in ("petals", "proposed"):
        res = simulate(problem, SimConfig(algorithm=alg, n_requests=100,
                                          rate=0.5, seed=0))
        print(f"  {alg:9s}: per-token(all) {res.per_token_all:6.2f} s   "
              f"first-token {res.first_token:7.1f} s   "
              f"rest {res.per_token_rest:5.2f} s")


if __name__ == "__main__":
    main()
