"""Train a small model end-to-end on synthetic data with checkpoint/resume.

Demonstrates the training substrate behind the train_4k dry-run cells:
AdamW, remat, the data pipeline, and crash-safe checkpointing.

Run:  PYTHONPATH=src python examples/train_small.py [--steps N]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.data import make_batches
from repro.models import NULL_SH
from repro.training import (TrainHParams, checkpoint, init_train_state,
                            make_optimizer_for, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--ckpt", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch).replace(n_layers=4, d_model=128,
                                                d_ff=512, n_heads=8,
                                                n_kv_heads=4, head_dim=16)
    hp = TrainHParams(learning_rate=3e-3, grad_accum=1, remat=True)
    opt = make_optimizer_for(cfg, hp)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step_fn = jax.jit(make_train_step(cfg, NULL_SH, opt, hp))

    start = checkpoint.latest_step(args.ckpt) or 0
    if start:
        state, start = checkpoint.restore(args.ckpt, state)
        print(f"resumed from step {start}")
    batches = make_batches(cfg, batch_size=8, seq_len=128, seed=0,
                           start_step=start)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % 10 == 0:
            dt = time.time() - t0
            print(f"step {i+1:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({dt/10:.2f}s/step)")
            t0 = time.time()
        if (i + 1) % 25 == 0:
            checkpoint.save(args.ckpt, i + 1, state)
            print(f"  checkpointed at step {i+1}")
    print("done")


if __name__ == "__main__":
    main()
