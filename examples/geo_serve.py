"""End-to-end driver: serve concurrent requests through the geo-distributed
engine (real JAX block-level computation, PETALS-style client-centric
protocol) with continuous batching — online BPRR admission via WS-RR,
interleaved sessions sharing per-server cache pools, a mid-run server
failure + exact recovery, and cross-validation of the simulator's predicted
per-token times against the engine's virtual clock.

Run:  PYTHONPATH=src python examples/geo_serve.py
"""
import numpy as np
import jax

from repro.configs import get_reduced_config
from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                        route_per_token_time, shortest_path_route)
from repro.models import init_params
from repro.serving import ContinuousBatchingScheduler, GeoServingSystem
from repro.sim.workload import poisson_requests


def main():
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=8)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)

    # heterogeneous virtual cluster: 2 fast, 3 slow servers
    llm = LLMSpec("llama3.2-reduced", cfg.n_layers, block_bytes=50.0,
                  cache_bytes_per_token=0.5)
    servers = [ServerSpec(0, 500.0, 0.004), ServerSpec(1, 500.0, 0.004),
               ServerSpec(2, 220.0, 0.020), ServerSpec(3, 220.0, 0.020),
               ServerSpec(4, 220.0, 0.020)]
    rtt = np.array([[0.01, 0.01, 0.03, 0.03, 0.03]])
    problem = Problem(llm, servers, 1, rtt, 3 * rtt,
                      workload=Workload(8, 16))

    system = GeoServingSystem(cfg, params, problem, algorithm="proposed",
                              R=4, max_new_tokens=16, max_sessions=8)
    print("placement a:", system.placement.a, " m:", system.placement.m)
    sched = ContinuousBatchingScheduler(system, R=4)

    rng = np.random.RandomState(0)
    print("\nserving 8 requests (Poisson arrivals, continuous batching) ...")
    for req in poisson_requests(8, rate=2.0, seed=1):
        toks = rng.randint(2, cfg.vocab_size, 8)
        sched.submit(req.rid, toks, req.arrival, n_new=12)
    served = sched.run()
    for out in served:
        print(f"  req {out.rid}: arrival {out.arrival:6.2f}s  "
              f"start {out.start:6.2f}s  wait {out.wait*1e3:5.1f}ms  "
              f"per-token {out.per_token*1e3:6.1f}ms  "
              f"tokens {out.tokens[8:14]}...")
    print(f"  peak concurrency: {sched.max_concurrency} interleaved sessions")

    # cross-validate: engine virtual time vs the analytic model (eq. 1).
    # per_token_rest is the decode-phase per-token time — queueing wait and
    # prefill amortisation are excluded, so the ratio isolates eq. (4).
    route, _ = shortest_path_route(problem, system.placement, 0)
    predicted = route_per_token_time(problem, route, 0)
    measured = np.mean([s.per_token_rest for s in served])
    print(f"\nmodel eq.(1) per-token {predicted*1e3:.1f} ms vs engine "
          f"virtual clock {measured*1e3:.1f} ms "
          f"(ratio {measured/predicted:.2f})")

    # failure mid-generation with TWO live sessions: exact recovery from
    # client-side caches while a co-resident session keeps decoding
    print("\nfailure drill: killing the first server under two live "
          "sessions ...")
    toks_a = rng.randint(2, cfg.vocab_size, 8)
    toks_b = rng.randint(2, cfg.vocab_size, 8)
    sid_a, logits_a = system.submit(toks_a)
    sid_b, logits_b = system.submit(toks_b)
    seq_a = [int(np.argmax(np.asarray(logits_a[0])))]
    seq_b = [int(np.argmax(np.asarray(logits_b[0])))]
    for step in range(8):
        if step == 2:
            victim = system.sessions[sid_a].route.servers[0]
            system.kill_server(victim)
            print(f"  killed server {victim} at step {step}")
        lg = system.decode(sid_a, seq_a[-1])
        seq_a.append(int(np.argmax(np.asarray(lg[0]))))
        lg = system.decode(sid_b, seq_b[-1])
        seq_b.append(int(np.argmax(np.asarray(lg[0]))))
    print(f"  new route A: {system.sessions[sid_a].route.servers}  "
          f"generated: {seq_a}")
    print(f"  route B:     {system.sessions[sid_b].route.servers}  "
          f"generated: {seq_b}")
    system.finish(sid_a)
    system.finish(sid_b)
    print("done — generation continued seamlessly after failover.")


if __name__ == "__main__":
    main()
