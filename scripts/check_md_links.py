#!/usr/bin/env python
"""Markdown link check (stdlib only, used by the CI docs job).

Verifies that every relative `[text](target)` link in the given markdown
files/directories points at an existing file or directory.  External
links (http/https/mailto) are skipped; `#anchor` suffixes are stripped
(anchor existence is not checked).

Usage:  python scripts/check_md_links.py README.md docs
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")  # links AND images
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def collect(paths):
    for p in map(Path, paths):
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        else:
            yield p


def main(argv) -> int:
    bad = []
    n_links = 0
    for md in collect(argv or ["README.md", "docs"]):
        text = md.read_text(encoding="utf-8")
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            target = target.split("#", 1)[0]
            if not target:  # pure in-page anchor
                continue
            n_links += 1
            if not (md.parent / target).exists():
                line = text.count("\n", 0, m.start()) + 1
                bad.append(f"{md}:{line}: broken link -> {m.group(1)}")
    for b in bad:
        print(b, file=sys.stderr)
    print(f"checked {n_links} relative links, {len(bad)} broken")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
