"""Table 6 (+ Figs 15–20): algorithm decision time per request — both
PETALS' heuristics and the proposed two-time-scale algorithm are fast enough
to be negligible against inference time."""
from __future__ import annotations

from repro.sim import SimConfig, clustered_scenario, simulate

from benchmarks.common import emit, scattered_problem, timed

PAPER_TABLE6 = {"clustered": (0.0186, 0.0216), "abovenet": (0.0190, 0.0333),
                "bellcanada": (0.0291, 0.0287), "gts_ce": (0.0350, 0.0320)}


def run(full: bool = False):
    scenarios = [("clustered", clustered_scenario()[0])]
    topos = ("abovenet", "bellcanada", "gts_ce") if full \
        else ("abovenet", "bellcanada")
    for t in topos:
        scenarios.append((t, scattered_problem(t)))
    for name, prob in scenarios:
        times = {}
        for alg in ("petals", "proposed", "optimized_rr"):
            res, us = timed(simulate, prob, SimConfig(
                algorithm=alg, n_requests=40 if not full else 100,
                rate=0.5, seed=0))
            times[alg] = res.decision_time_s
        ref = PAPER_TABLE6.get(name)
        ref_s = f"paper={ref[0]:.4f}/{ref[1]:.4f}" if ref else ""
        emit(f"table6.{name}", times["proposed"] * 1e6,
             f"petals={times['petals']*1e3:.2f}ms "
             f"proposed={times['proposed']*1e3:.2f}ms "
             f"optimized_rr={times['optimized_rr']*1e3:.2f}ms {ref_s}")


if __name__ == "__main__":
    run()
