"""Optimality-gap study (context for Thm 3.2 / Thm 3.5): CG-BPRR vs the
exact MILP (13) on random small instances, plus bound (17) tightness."""
from __future__ import annotations

import numpy as np

from repro.core import (LLMSpec, Problem, ServerSpec, Workload, cg_bp,
                        cg_upper_bound, lower_bound,
                        route_per_token_time, shortest_path_route)
from repro.core.milp import solve_bprr_milp

from benchmarks.common import emit, timed


def random_instance(rng, L=4, n=3, n_req=3):
    llm = LLMSpec("toy", L, block_bytes=4.0, cache_bytes_per_token=0.5)
    servers = [ServerSpec(j, mem_bytes=float(4.0 * L + 8 * rng.random()),
                          tau=float(0.05 + 0.3 * rng.random()))
               for j in range(n)]
    C = 2
    rtt = 0.02 + 0.3 * rng.random((C, n))
    prob = Problem(llm, servers, C, rtt, rtt * 4, workload=Workload(2, 2))
    reqs = [int(rng.integers(0, C)) for _ in range(n_req)]
    return prob, reqs


def run(full: bool = False):
    rng = np.random.default_rng(7)
    n_inst = 8 if full else 4
    gaps = []
    for i in range(n_inst):
        prob, reqs = random_instance(rng)
        (res,), us = timed(lambda: (solve_bprr_milp(prob, reqs),))
        pl, info = cg_bp(prob, len(reqs))
        if not info.feasible or res.placement is None:
            continue
        cg_total = 0.0
        for c in reqs:
            rt, _ = shortest_path_route(prob, pl, c)
            if rt is None:
                cg_total = np.inf
                break
            cg_total += route_per_token_time(prob, rt, c)
        gap = cg_total / res.objective if res.objective > 0 else np.inf
        ub = cg_upper_bound(prob, len(reqs)) * len(reqs)
        lb = lower_bound(prob) * len(reqs)
        gaps.append(gap)
        emit(f"optgap.inst{i}", us,
             f"milp={res.objective:.3f} cg={cg_total:.3f} gap={gap:.3f} "
             f"bound17={ub:.3f} bound35={lb:.3f}")
    if gaps:
        emit("optgap.summary", 0.0,
             f"mean_gap={np.mean(gaps):.3f} max_gap={np.max(gaps):.3f} "
             f"n={len(gaps)}")


if __name__ == "__main__":
    run()
