"""Optimality-gap study (context for Thm 3.2 / Thm 3.5): CG-BPRR vs the
exact MILP (13) on random small instances, plus bound (17) tightness —
and the ONLINE scale sweep: the per-arrival MILP (21) vs the polynomial
eq. (20) DP (ws_rr) on growing fleets."""
from __future__ import annotations

import numpy as np

from repro.core import (LLMSpec, Problem, RouteCostCache, ServerSpec,
                        ServerState, Workload, cg_bp, cg_upper_bound,
                        edge_waiting_times, lower_bound,
                        route_per_token_time, shortest_path_route, ws_rr)
from repro.core.milp import solve_bprr_milp, solve_online_routing
from repro.core.routing import edge_cost_matrix

from benchmarks.common import emit, timed


def random_instance(rng, L=4, n=3, n_req=3):
    llm = LLMSpec("toy", L, block_bytes=4.0, cache_bytes_per_token=0.5)
    servers = [ServerSpec(j, mem_bytes=float(4.0 * L + 8 * rng.random()),
                          tau=float(0.05 + 0.3 * rng.random()))
               for j in range(n)]
    C = 2
    rtt = 0.02 + 0.3 * rng.random((C, n))
    prob = Problem(llm, servers, C, rtt, rtt * 4, workload=Workload(2, 2))
    reqs = [int(rng.integers(0, C)) for _ in range(n_req)]
    return prob, reqs


def online_instance(rng, n: int):
    """Random fleet of ``n`` servers for the online sweep: enough memory
    to host a handful of blocks each, spread taus/RTTs so routes are
    non-trivial."""
    L = 8
    llm = LLMSpec("sweep", L, block_bytes=8.0, cache_bytes_per_token=0.5)
    servers = [ServerSpec(j, mem_bytes=float(8.0 * L + 60 * rng.random()),
                          tau=float(0.01 + 0.05 * rng.random()))
               for j in range(n)]
    C = 4
    rtt = 0.01 + 0.1 * rng.random((C, n))
    return Problem(llm, servers, C, rtt, 3 * rtt, workload=Workload(4, 8))


def _objective21(problem, cm, waiting, route) -> float:
    """Realized eq. (21) objective of a committed route: max hop wait +
    l_max * sum of eq. (4) edge costs (the online MILP's own metric, so
    both solvers are scored on the same scale)."""
    n = problem.n_servers
    lmax = float(problem.workload.l_out)
    prev, w, c = n, 0.0, 0.0
    for j in route.servers:
        w = max(w, float(waiting[prev, j]))
        c += float(cm[prev, j])
        prev = j
    return w + lmax * c


def online_scale_sweep(sizes=(8, 16, 32, 48), n_arrivals: int = 12,
                       seed: int = 11):
    """Per-arrival online MILP (21) (HiGHS) vs the polynomial eq. (20)
    DP (``ws_rr``) on growing fleets.  Emits one ``optgap.online.n{N}``
    row per size with the realized-cost ratio under the MILP's own
    objective and the wall-time ratio.  Sizes stop below ~50 servers:
    the MILP's dense edge-variable matrix grows as O(n^2) rows and
    becomes memory-bound well before the DP (O(n^2) total) does."""
    rng = np.random.default_rng(seed)
    out = {}
    for n in sizes:
        prob = online_instance(rng, n)
        pl, info = cg_bp(prob, 8)
        if not info.feasible:
            continue
        cache = RouteCostCache(prob, pl)
        # a few random in-flight sessions so eq. (20) waits are non-zero
        states = {}
        for j in rng.choice(n, size=max(2, n // 4), replace=False):
            k = int(min(pl.m[int(j)], 2))
            if k <= 0:
                continue
            states[int(j)] = ServerState(
                remaining=[float(1.0 + 5.0 * rng.random())], blocks=[k])
        waiting = edge_waiting_times(prob, pl, states, cache=cache)
        ratios, milp_us, dp_us = [], 0.0, 0.0
        solved = 0
        for r in range(n_arrivals):
            c = r % prob.n_clients
            cm = edge_cost_matrix(prob, pl, c)
            (rt_m, _), us_m = timed(solve_online_routing, prob, pl, c,
                                    waiting)
            (rt_d, _, _), us_d = timed(ws_rr, prob, pl, c, states,
                                       cache=cache)
            if rt_m is None or rt_d is None:
                continue
            solved += 1
            milp_us += us_m
            dp_us += us_d
            obj_m = _objective21(prob, cm, waiting, rt_m)
            obj_d = _objective21(prob, cm, waiting, rt_d)
            ratios.append(obj_d / obj_m if obj_m > 0 else 1.0)
        if not solved:
            continue
        row = {"n_servers": n, "n_arrivals": solved,
               "cost_ratio_mean": float(np.mean(ratios)),
               "cost_ratio_max": float(np.max(ratios)),
               "milp_us_per_arrival": milp_us / solved,
               "dp_us_per_arrival": dp_us / solved,
               "milp_over_dp_time": milp_us / max(dp_us, 1e-9)}
        out[n] = row
        emit(f"optgap.online.n{n}", milp_us + dp_us,
             f"cost dp/milp={row['cost_ratio_mean']:.3f} "
             f"(max {row['cost_ratio_max']:.3f}) | "
             f"milp={row['milp_us_per_arrival']:.0f}us/arrival "
             f"dp={row['dp_us_per_arrival']:.0f}us/arrival "
             f"({row['milp_over_dp_time']:.0f}x)")
    return out


def run(full: bool = False):
    rng = np.random.default_rng(7)
    n_inst = 8 if full else 4
    gaps = []
    for i in range(n_inst):
        prob, reqs = random_instance(rng)
        (res,), us = timed(lambda: (solve_bprr_milp(prob, reqs),))
        pl, info = cg_bp(prob, len(reqs))
        if not info.feasible or res.placement is None:
            continue
        cg_total = 0.0
        for c in reqs:
            rt, _ = shortest_path_route(prob, pl, c)
            if rt is None:
                cg_total = np.inf
                break
            cg_total += route_per_token_time(prob, rt, c)
        gap = cg_total / res.objective if res.objective > 0 else np.inf
        ub = cg_upper_bound(prob, len(reqs)) * len(reqs)
        lb = lower_bound(prob) * len(reqs)
        gaps.append(gap)
        emit(f"optgap.inst{i}", us,
             f"milp={res.objective:.3f} cg={cg_total:.3f} gap={gap:.3f} "
             f"bound17={ub:.3f} bound35={lb:.3f}")
    if gaps:
        emit("optgap.summary", 0.0,
             f"mean_gap={np.mean(gaps):.3f} max_gap={np.max(gaps):.3f} "
             f"n={len(gaps)}")
    online_scale_sweep(sizes=(8, 16, 32, 48) if full else (8, 16, 32))


if __name__ == "__main__":
    run()
