"""Perf-model validation (paper Figs 2/3/11/12 analogue): the engine's
block-level execution confirms the linear dependence of per-token time on
#processed blocks, independence from concurrent sessions within memory, and
the memory model (2)/(5) — cross-validating the simulator."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed


def run(full: bool = False):
    import jax

    from repro.configs import get_reduced_config
    from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                            route_per_token_time, server_memory_use,
                            shortest_path_route)
    from repro.models import init_params
    from repro.serving import GeoServingSystem, generate

    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=8)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    tau = 0.01

    # Fig 2b analogue: virtual per-token time vs #blocks on one server.
    times = {}
    for m_blocks in (2, 4, 8):
        llm = LLMSpec("t", cfg.n_layers, 10.0, 0.5)
        # one big server forced to host everything + tiny helpers
        servers = [ServerSpec(0, 10.0 * m_blocks + 50, tau)]
        if m_blocks < cfg.n_layers:
            servers += [ServerSpec(1, 10.0 * (cfg.n_layers - m_blocks) + 50,
                                   tau)]
        rtt = np.full((1, len(servers)), 0.005)
        prob = Problem(llm, servers, 1, rtt, rtt, workload=Workload(4, 8))
        system = GeoServingSystem(cfg, params, prob, algorithm="proposed",
                                  R=1, max_new_tokens=8)
        toks = np.arange(4) + 2
        (out, vt), us = timed(generate, system, toks, 6)
        times[m_blocks] = vt / 7  # per forward
        emit(f"perfmodel.blocks{m_blocks}", us,
             f"virtual_per_token={vt/7*1e3:.2f}ms")
    # linearity check: time(8 blocks)/time(2 blocks) tracks the block ratio
    # modulo the constant RTT term
    t2, t8 = times[2], times[8]
    rtt_const = 0.005
    slope2 = (t2 - 2 * rtt_const)
    slope8 = (t8 - rtt_const)
    emit("perfmodel.linearity", 0.0,
         f"per-block slope (2-block route)={slope2/2*1e3:.2f}ms "
         f"(8-block)={slope8/8*1e3:.2f}ms (model tau={tau*1e3:.1f}ms)")


if __name__ == "__main__":
    run()
