"""Perf-model + simulator cross-validation against the REAL engine.

Three parts (paper Figs 2/3/11/12 analogue + §4 concurrency dynamics):

1. block-linearity: the engine's block-level execution confirms the linear
   dependence of per-token time on #processed blocks (eq. (1)).
2. concurrency cross-validation: the SAME Poisson trace is played through
   the discrete-event simulator and through the continuous-batching engine
   (real JAX forward passes, WS-RR admission, shared cache pools) at design
   concurrency R ∈ {1, 4, 8}; we report the relative error of mean
   per-token and first-token times between the two paths.  Agreement within
   a few percent validates that the simulator's waiting/memory dynamics
   (eq. (5)/(20)) match what the engine actually does under interleaved
   sessions.
3. hybrid-topology cross-validation: the same trace served by a zamba2-style
   hybrid stack (mamba + shared-attention blocks) with per-FAMILY block
   compute weights (``LLMSpec.block_tau``) — the engine's family-polymorphic
   state pools against the simulator's weighted eq. (1) accounting.

Also emits a machine-readable ``BENCH_engine.json`` (tokens/s and
cross-validation error per scenario) so CI can track the perf trajectory.

Run:  PYTHONPATH=src:. python benchmarks/engine_validation.py [--full]
      [--smoke] [--json PATH]
"""
from __future__ import annotations

import json
import os
import numpy as np

from benchmarks.common import emit, timed

# per-family relative block compute weights of the hybrid scenario: a
# shared-attention block (mamba mixer + width-2d attention+MLP) costs ~2.5x
# a plain mamba mixer; weights average ~1 so totals stay comparable to the
# uniform scenario
HYBRID_TAU = {"mamba": 0.7, "mamba_shared": 1.9}

# collected by run(): scenario name -> metrics dict (written as JSON)
_RESULTS = {}


def _record(name: str, **metrics):
    _RESULTS[name] = {k: (float(v) if isinstance(v, (int, float, np.floating))
                          else v) for k, v in metrics.items()}


def _xval_config(arch: str, L: int):
    """Reduced engine config with exactly L BPRR blocks for one arch."""
    from repro.configs import get_reduced_config

    cfg = get_reduced_config(arch)
    if cfg.n_layers != L:
        cfg = cfg.replace(n_layers=L)
    return cfg


def _concurrency_problem(block_tau=None):
    from repro.core import LLMSpec, Problem, ServerSpec, Workload

    llm = LLMSpec("xval", 8, block_bytes=50.0, cache_bytes_per_token=0.5,
                  block_tau=block_tau)
    servers = [
        ServerSpec(0, 500.0, 0.004, tau_prefill_base=0.002,
                   tau_prefill_per_token=0.0005),
        ServerSpec(1, 500.0, 0.004, tau_prefill_base=0.002,
                   tau_prefill_per_token=0.0005),
        ServerSpec(2, 260.0, 0.020, tau_prefill_base=0.004,
                   tau_prefill_per_token=0.001),
        ServerSpec(3, 260.0, 0.020, tau_prefill_base=0.004,
                   tau_prefill_per_token=0.001),
        ServerSpec(4, 260.0, 0.020, tau_prefill_base=0.004,
                   tau_prefill_per_token=0.001),
    ]
    rtt = np.array([[0.01, 0.01, 0.03, 0.03, 0.03]])
    return Problem(llm, servers, 1, rtt, 3 * rtt, workload=Workload(8, 12))


def _planet_problem(n_servers: int = 8, n_clients: int = 4,
                    mem: float = 3200.0):
    """Well-provisioned planet-scale topology for the 1M-request diurnal
    study: two server classes (fast/slow alternating), each client nearest
    a distinct server pair, ~200-280 eq. (15) cache slots per server.  At
    R=8 CG-BP gives every client a dedicated full-stack server, so the
    diurnal valley runs entirely in the zero-wait regime (the fast engine's
    W == W0 condition) and the midday rush spills onto the slow exact
    path — both branches of the vectorized event loop get exercised."""
    from repro.core import LLMSpec, Problem, ServerSpec, Workload

    llm = LLMSpec("planet", 8, block_bytes=50.0, cache_bytes_per_token=0.5)
    servers = [
        ServerSpec(j, mem if j % 2 == 0 else mem * 0.75,
                   0.004 if j % 2 == 0 else 0.006,
                   tau_prefill_base=0.002, tau_prefill_per_token=0.0005)
        for j in range(n_servers)
    ]
    rtt = np.full((n_clients, n_servers), 0.02)
    for c in range(n_clients):
        rtt[c, (2 * c) % n_servers] = 0.005
        rtt[c, (2 * c + 1) % n_servers] = 0.005
    return Problem(llm, servers, n_clients, rtt, 3 * rtt,
                   workload=Workload(8, 12))


def _fleet_problem(n_servers: int = 120, n_clients: int = 4, seed: int = 0):
    """Large elastic fleet for the churn study: heterogeneous memory and
    compute in a 3x4 class grid, dense random client RTTs — big enough
    that CG-BP re-placement (OnlineBPRR.replace_servers) is the dominant
    cost a storm has to amortize."""
    from repro.core import LLMSpec, Problem, ServerSpec, Workload

    llm = LLMSpec("fleet", 8, block_bytes=50.0, cache_bytes_per_token=0.5)
    rng = np.random.default_rng(seed)
    servers = [
        ServerSpec(j, float(400.0 + 100.0 * (j % 3)),
                   float(0.004 + 0.004 * (j % 4)),
                   tau_prefill_base=0.002, tau_prefill_per_token=0.0005)
        for j in range(n_servers)
    ]
    rtt = 0.005 + 0.045 * rng.random((n_clients, n_servers))
    return Problem(llm, servers, n_clients, rtt, 3 * rtt,
                   workload=Workload(8, 12))


def cross_validate(R: int, n_requests: int = 10, rate: float = 1.0,
                   seed: int = 0, trace: str = "poisson",
                   arch: str = "llama3_2_1b"):
    """Returns (engine metrics, sim metrics, relative errors) for one R.

    ``trace``: "poisson" (the paper's proxy-client arrivals) or "bursty"
    (4-request same-timestamp bursts — the coalescable-prefill workload:
    the engine admits each burst as one bucket group).  ``arch`` picks the
    served stack; "zamba2_7b" runs the hybrid topology with per-family
    block compute weights (``HYBRID_TAU``)."""
    import jax

    from repro.models import init_params, stack_block_kinds
    from repro.serving import ContinuousBatchingScheduler, GeoServingSystem
    from repro.sim import SimConfig, simulate
    from repro.sim.workload import (bursty_requests, poisson_requests,
                                    prompts_for)

    cfg = _xval_config(arch, 8)
    block_tau = None
    if cfg.family == "hybrid":
        block_tau = tuple(HYBRID_TAU[k] for k in stack_block_kinds(cfg))
    problem = _concurrency_problem(block_tau=block_tau)
    lw = problem.workload
    if trace == "bursty":
        requests = bursty_requests(n_bursts=max(1, n_requests // 4),
                                   burst_size=4, spacing=2.0)
    else:
        requests = poisson_requests(n_requests, rate, seed=seed)

    # --- simulator path ---------------------------------------------------
    sim = simulate(problem, SimConfig("proposed", n_requests=len(requests),
                                      rate=rate, seed=seed, R=R),
                   requests=requests)

    # --- engine path (same trace, same R) ---------------------------------
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    system = GeoServingSystem(cfg, params, problem, algorithm="proposed",
                              R=R, max_new_tokens=lw.l_out,
                              max_sessions=max(8, R))
    sched = ContinuousBatchingScheduler(system, R=R, arrival_rate=rate)
    prompts = prompts_for(requests, lw.l_in, cfg.vocab_size, seed=seed)
    for req, toks in zip(requests, prompts):
        sched.submit(req.rid, toks, req.arrival, n_new=lw.l_out,
                     client=req.client)
    served = [r for r in sched.run() if not r.dropped]

    eng = {
        "per_token_all": float(np.mean([r.per_token for r in served])),
        "first_token": float(np.mean([r.first_token for r in served])),
        "wait": float(np.mean([r.wait for r in served])),
        "max_concurrency": sched.max_concurrency,
    }
    simm = {
        "per_token_all": sim.per_token_all,
        "first_token": sim.first_token,
        "wait": sim.wait,
    }
    err = {k: abs(eng[k] - simm[k]) / max(simm[k], 1e-12)
           for k in ("per_token_all", "first_token")}
    return eng, simm, err


def prefill_throughput(R: int = 4, burst: int = 8, n_new: int = 4,
                       seed: int = 0):
    """Wall-clock prefill throughput of one same-timestamp burst, serial
    vs bucketed-batched admission.  Returns {mode: tokens/s} measured on a
    second (jit-warm) run."""
    import time

    import jax

    from repro.configs import get_reduced_config
    from repro.core import shortest_path_route
    from repro.models import init_params
    from repro.serving import GeoServingSystem

    from repro.core import LLMSpec, Problem, ServerSpec, Workload

    # amply-provisioned two-hop topology: the whole burst must be resident
    llm = LLMSpec("tput", 8, block_bytes=50.0, cache_bytes_per_token=0.5)
    servers = [ServerSpec(0, 2000.0, 0.004, tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005),
               ServerSpec(1, 2000.0, 0.004, tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)]
    rtt = np.array([[0.01, 0.01]])
    problem = Problem(llm, servers, 1, rtt, 3 * rtt,
                      workload=Workload(12, 12))
    lw = problem.workload
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=problem.L)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(2, cfg.vocab_size, size=lw.l_in)
               for _ in range(burst)]

    out = {}
    for mode in ("serial", "batched"):
        system = GeoServingSystem(cfg, params, problem, algorithm="proposed",
                                  R=R, max_new_tokens=n_new,
                                  max_sessions=max(8, burst),
                                  prefill_mode=mode)

        def once():
            sids = []
            for toks in prompts:
                route, _ = shortest_path_route(system.problem,
                                               system.alive_placement(), 0)
                sids.append(system.create_session(toks, 0, route, n_new))
            t0 = time.perf_counter()
            admitted = system.try_admit_sessions(sids)
            system.drain_prefill()
            dt = time.perf_counter() - t0
            assert len(admitted) == burst, "burst must fit for the measure"
            for sid in sids:
                system.retire_session(sid)
            return dt

        once()  # jit warm-up
        dt = min(once() for _ in range(3))
        out[mode] = burst * lw.l_in / dt
    return out


def decode_throughput(n_servers: int = 2, n_sessions: int = 8,
                      n_rounds: int = 4, warm: int = 2, seed: int = 0):
    """Wall-clock decode throughput of one resident cohort: the
    device-resident fused rounds (``decode_mode="fused"``) against the
    per-session serial reference (``decode_mode="serial"``, one session per
    round — the ``prefill_mode="serial"``-style baseline).

    Benchmark hygiene: both paths run ``warm`` rounds first (trace +
    compile excluded) and each fused round ends on its token readback
    (the round's one host sync), so the timed window measures steady
    state.  Topology: ``n_servers`` servers hosting one equal share of the
    blocks each, sized so the WHOLE cohort is resident (every session
    routes through every server).  Returns tokens/s per mode + speedup +
    fused dispatches/round."""
    import time

    import jax

    from repro.configs import get_reduced_config
    from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                            shortest_path_route)
    from repro.models import init_params
    from repro.serving import GeoServingSystem

    L = max(8, n_servers)
    bps = L // n_servers  # blocks per server
    lw = Workload(4, warm + n_rounds + 2)
    llm = LLMSpec("dtput", L, block_bytes=500.0, cache_bytes_per_token=0.5)
    # memory: exactly `bps` blocks fit (one more would not), plus cache
    # slots for the whole cohort — forces an n_servers-hop route
    s_c = 0.5 * (lw.l_in + lw.l_out)
    mem = 500.0 * bps + s_c * (n_sessions * bps + 1)
    assert mem < 500.0 * (bps + 1), "cohort slots must fit under one block"
    servers = [ServerSpec(j, mem, 0.004, tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)
               for j in range(n_servers)]
    rtt = np.full((1, n_servers), 0.01)
    problem = Problem(llm, servers, 1, rtt, 3 * rtt, workload=lw)
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=L)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(2, cfg.vocab_size, size=lw.l_in)
               for _ in range(n_sessions)]

    out = {}
    toks = {}
    for mode in ("serial", "fused"):
        system = GeoServingSystem(cfg, params, problem,
                                  algorithm="proposed", R=n_sessions,
                                  max_new_tokens=lw.l_out,
                                  max_sessions=n_sessions, decode_mode=mode)
        sids = []
        for p in prompts:
            route, _ = shortest_path_route(problem,
                                           system.alive_placement(), 0)
            sids.append(system.create_session(p, 0, route, lw.l_out))
        admitted = system.try_admit_sessions(sids)
        assert len(admitted) == n_sessions, "cohort must be fully resident"
        system.drain_prefill()
        hops = len(system.sessions[sids[0]].route.servers)
        assert hops == n_servers, f"expected {n_servers}-hop route: {hops}"

        def sweep():
            if mode == "fused":
                system.decode_round(sids)
            else:  # per-session reference: one session per round
                for sid in sids:
                    system.decode_round([sid])

        for _ in range(warm):
            sweep()
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            sweep()
        dt = time.perf_counter() - t0
        out[mode] = n_sessions * n_rounds / dt
        toks[mode] = [list(system.sessions[s].tokens) for s in sids]
        if mode == "fused":
            st = system.round_stats
            out["fused_dispatches_per_round"] = (
                (st["embed_dispatches"] + st["tail_dispatches"]
                 + st["hop_dispatches"]) / max(1, st["rounds"]))
    assert toks["fused"] == toks["serial"], \
        "fused and serial reference must emit identical token streams"
    return {"serial_tok_s": out["serial"], "fused_tok_s": out["fused"],
            "speedup": out["fused"] / out["serial"],
            "fused_dispatches_per_round": out["fused_dispatches_per_round"],
            "n_servers": n_servers, "n_sessions": n_sessions}


def shard_decode_throughput(n_sessions: int = 8, n_rounds: int = 4,
                            warm: int = 2, mesh_shape=(1, 1)):
    """Decode throughput of DEVICE-GROUP servers (mesh-sharded pooled
    steps, docs/serving.md "Device-group servers") against the mesh=None
    twin — same cohort, same rounds, token parity asserted at measure
    time.  Defaults to a 1-device mesh so the row runs on any host (the
    sharded-parity CI lane re-proves the multi-device matrix); also
    records the step-cost-calibrated τ (``launch.costs.tau_from_step_cost``)
    that ``GeoServingSystem.calibrated_problem`` folds back into eq. (1)."""
    import time

    import jax

    from repro.configs import get_reduced_config
    from repro.core import (LLMSpec, Problem, ServerSpec, Workload,
                            shortest_path_route)
    from repro.launch.mesh import compat_make_mesh
    from repro.models import init_params
    from repro.serving import GeoServingSystem

    L = 8
    lw = Workload(4, warm + n_rounds + 2)
    llm = LLMSpec("shard", L, block_bytes=50.0, cache_bytes_per_token=0.5)
    servers = [ServerSpec(j, 2000.0, 0.004, tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005) for j in range(2)]
    rtt = np.full((1, 2), 0.01)
    problem = Problem(llm, servers, 1, rtt, 3 * rtt, workload=lw)
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=L)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=lw.l_in)
               for _ in range(n_sessions)]

    mesh = compat_make_mesh(mesh_shape, ("data", "model"))
    out, toks, tau_cal, group_chips = {}, {}, float("nan"), []
    for tag, m in (("twin", None), ("sharded", mesh)):
        system = GeoServingSystem(cfg, params, problem,
                                  algorithm="proposed", R=n_sessions,
                                  max_new_tokens=lw.l_out,
                                  max_sessions=n_sessions, mesh=m)
        sids = []
        for p in prompts:
            route, _ = shortest_path_route(problem,
                                           system.alive_placement(), 0)
            sids.append(system.create_session(p, 0, route, lw.l_out))
        assert len(system.try_admit_sessions(sids)) == n_sessions
        system.drain_prefill()
        for _ in range(warm):
            system.decode_round(sids)
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            system.decode_round(sids)
        dt = time.perf_counter() - t0
        out[tag] = n_sessions * n_rounds / dt
        toks[tag] = [list(system.sessions[s].tokens) for s in sids]
        if tag == "sharded":
            tau_cal = float(min(system.calibrate_taus().values()))
            group_chips = [system.servers[j].n_chips
                           for j in sorted(system.servers)]
    assert toks["sharded"] == toks["twin"], \
        "device-group decode must emit the twin's token stream"
    return {"sharded_tok_s": out["sharded"], "twin_tok_s": out["twin"],
            "ratio": out["sharded"] / out["twin"], "token_parity": 1,
            "tau_calibrated_s": tau_cal,
            "mesh_devices": int(np.prod(mesh_shape)),
            "group_chips": group_chips}


def hetero_validation(n_sessions: int = 6, n_rounds: int = 4,
                      warm: int = 2):
    """Heterogeneous device-group fleet {solo, (1,2) mesh, (2,2) mesh}
    served against the all-solo twin — token AND virtual-clock parity
    asserted at measure time — plus the calibrated-vs-uniform τ placement
    gap (``optgap.hetero``).  Needs 8 host devices, so ``run()`` invokes
    this through the ``--hetero-child`` subprocess (a fresh interpreter
    with ``--xla_force_host_platform_device_count=8``).

    Returns two rows:

    * ``hetero.decode.tput`` — hetero vs twin tokens/s, per-group chip
      counts, the per-server calibrated τ vector and its max/min spread
      (> 1 proves ``calibrate_taus`` is genuinely per-group).
    * ``optgap.hetero`` — CG-BP placements computed under the calibrated
      (normalised to the spec'd τ scale) and under a uniform τ vector on
      the SAME topology; memory caps each server at 6 of 8 blocks so the
      split is placement-sensitive, and the client's RTT favours the SLOW
      solo server so only the calibrated vector pulls blocks onto the big
      mesh groups.  Asserts the placements differ and that the calibrated
      placement costs no more when both are priced under calibrated τ.
    """
    import time

    import jax

    from repro.configs import get_reduced_config
    from repro.core import (LLMSpec, Problem, ServerSpec, Workload, cg_bp,
                            shortest_path_route, with_server_taus)
    from repro.launch.mesh import group_meshes
    from repro.models import init_params
    from repro.serving import GeoServingSystem

    assert len(jax.devices()) >= 8, \
        "hetero_validation needs 8 host devices (run via --hetero-child)"
    L = 8
    lw = Workload(4, warm + n_rounds + 2)
    llm = LLMSpec("hetero", L, block_bytes=50.0, cache_bytes_per_token=0.5)
    servers = [ServerSpec(j, 2000.0, 0.01 * (j + 1), tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005) for j in range(3)]
    rtt = np.full((1, 3), 0.01)
    problem = Problem(llm, servers, 1, rtt, 3 * rtt, workload=lw)
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=L)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=lw.l_in)
               for _ in range(n_sessions)]

    shapes = {0: None, 1: (1, 2), 2: (2, 2)}
    out, toks, vts, taus, chips = {}, {}, {}, {}, []
    for tag, groups in (("twin", None), ("hetero", group_meshes(shapes))):
        system = GeoServingSystem(cfg, params, problem,
                                  algorithm="proposed", R=3,
                                  max_new_tokens=lw.l_out,
                                  max_sessions=n_sessions,
                                  device_groups=groups)
        sids = []
        for p in prompts:
            route, _ = shortest_path_route(problem,
                                           system.alive_placement(), 0)
            sids.append(system.create_session(p, 0, route, lw.l_out))
        assert len(system.try_admit_sessions(sids)) == n_sessions
        system.drain_prefill()
        for _ in range(warm):
            system.decode_round(sids)
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            system.decode_round(sids)
        dt = time.perf_counter() - t0
        out[tag] = n_sessions * n_rounds / dt
        toks[tag] = [list(system.sessions[s].tokens) for s in sids]
        vts[tag] = [float(system.sessions[s].virtual_time) for s in sids]
        if tag == "hetero":
            taus = system.calibrate_taus()
            chips = [system.servers[j].n_chips
                     for j in sorted(system.servers)]
    assert toks["hetero"] == toks["twin"], \
        "hetero device groups must emit the all-solo twin's token stream"
    assert vts["hetero"] == vts["twin"], \
        "hetero device groups must keep the twin's virtual clocks"
    assert chips == [1, 2, 4], chips
    tau_vec = [taus[j] for j in sorted(taus)]
    tau_spread = max(tau_vec) / min(tau_vec)
    het_row = {"hetero_tok_s": out["hetero"], "twin_tok_s": out["twin"],
               "ratio": out["hetero"] / out["twin"], "token_parity": 1,
               "n_groups": len(chips), "group_chips": chips,
               "taus_s": tau_vec, "tau_spread": tau_spread}

    # --- optgap.hetero: the same 3-group fleet with placement-TIGHT
    # memories (5/3/6 of the 8 blocks) and client RTT favouring the slow
    # solo server.  The calibrated vector is normalised to the spec'd τ
    # scale (mean 0.01 s) so heterogeneity — not the raw-roofline-vs-RTT
    # unit gap — is the only difference from the uniform baseline.
    # CG-BP's m_j is memory-only; τ moves the SPAN assignment, so the gap
    # shows up in (a, m) and in the route cost: under uniform τ the big
    # (2,2) group lands on the tail span and every route must open on the
    # slow solo server; calibrated τ pulls it to the head span.
    tau_ref = 0.01
    mean_tau = sum(tau_vec) / len(tau_vec)
    scaled = {j: tau_ref * taus[j] / mean_tau for j in taus}
    opt_lw = Workload(4, 8)
    mems = (290.0, 180.0, 350.0)
    tight = [ServerSpec(j, mems[j], tau_ref, tau_prefill_base=0.002,
                        tau_prefill_per_token=0.0005) for j in range(3)]
    rtt_skew = np.array([[0.002, 0.004, 0.006]])
    base = Problem(llm, tight, 1, rtt_skew, 3 * rtt_skew, workload=opt_lw)
    cal_prob = with_server_taus(base, scaled)
    pl_cal, info_cal = cg_bp(cal_prob, 1)
    pl_uni, info_uni = cg_bp(base, 1)
    assert info_cal.feasible and info_uni.feasible
    _, cost_cal = shortest_path_route(cal_prob, pl_cal, 0)
    _, cost_uni = shortest_path_route(cal_prob, pl_uni, 0)
    differs = int(not (np.array_equal(pl_cal.m, pl_uni.m)
                       and np.array_equal(pl_cal.a, pl_uni.a)))
    assert differs, (list(pl_cal.a), list(pl_uni.a))
    assert cost_cal <= cost_uni * (1 + 1e-9), (cost_cal, cost_uni)
    og_row = {"cost_calibrated_s": float(cost_cal),
              "cost_uniform_s": float(cost_uni),
              "optgap_frac": float((cost_uni - cost_cal) / cost_uni),
              "placement_differs": differs,
              "m_calibrated": [int(v) for v in pl_cal.m],
              "a_calibrated": [int(v) for v in pl_cal.a],
              "m_uniform": [int(v) for v in pl_uni.m],
              "a_uniform": [int(v) for v in pl_uni.a],
              "tau_scaled_s": [scaled[j] for j in sorted(scaled)]}
    return {"hetero.decode.tput": het_row, "optgap.hetero": og_row}


def _hetero_rows(smoke: bool = False):
    """Parent-side driver for :func:`hetero_validation`: spawn a fresh
    interpreter with 8 forced host CPU devices (this process's jax device
    count is frozen at first import) and parse the child's JSON rows."""
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, os.path.abspath(__file__), "--hetero-child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                          text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"--hetero-child failed:\n{proc.stderr}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def _one_server_problem(slab_cap: int, l_out: int = 60):
    """One server hosting the whole 8-block stack with cache memory for
    EXACTLY ``slab_cap`` worst-case sessions — the fixed-width co-residency
    cap the paged layout is measured against."""
    from repro.core import LLMSpec, Problem, ServerSpec, Workload

    L, block_bytes = 8, 50.0
    lw = Workload(4, l_out)
    llm = LLMSpec("paged", L, block_bytes, cache_bytes_per_token=0.5)
    s_c = 0.5 * lw.total_tokens
    mem = block_bytes * L + s_c * slab_cap * L
    servers = [ServerSpec(0, mem, 0.004, tau_prefill_base=0.002,
                          tau_prefill_per_token=0.0005)]
    rtt = np.array([[0.01]])
    return Problem(llm, servers, 1, rtt, 3 * rtt, workload=lw)


def _paged_cohort(problem, cfg, params, layout, n_sessions, n_new,
                  page_size=None, R=None):
    from repro.core import shortest_path_route
    from repro.serving import GeoServingSystem

    # R is the DESIGN concurrency CG-BP reserves worst-case memory for;
    # the paged layout oversubscribes past it at the pool level
    system = GeoServingSystem(
        cfg, params, problem, algorithm="proposed", R=R or n_sessions,
        max_new_tokens=problem.workload.l_out, max_sessions=n_sessions,
        decode_mode="fused", cache_layout=layout, page_size=page_size)
    rng = np.random.default_rng(0)
    sids = []
    for _ in range(n_sessions):
        route, _ = shortest_path_route(problem, system.alive_placement(), 0)
        sids.append(system.create_session(
            rng.integers(2, cfg.vocab_size, size=problem.workload.l_in),
            0, route, n_new))
    admitted = system.try_admit_sessions(sids)
    return system, sids, admitted


def paged_decode_throughput(n_sessions: int = 128, slab_cap: int = 32,
                            n_new: int = 4):
    """The paged co-residency headline (``decode.tput.R128``): sessions
    book prompt pages and grow on demand, so the SAME topology whose
    worst-case eq. (5) budget caps the slab layout at ``slab_cap``
    co-resident sessions holds the whole ``n_sessions`` cohort — measured
    admissions on both layouts plus the fused decode tokens/s of the full
    paged cohort (jit-warm is the prefill drain; rounds are timed)."""
    import time

    import jax

    from repro.configs import get_reduced_config
    from repro.models import init_params

    problem = _one_server_problem(slab_cap)
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=problem.L)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)

    slab_sys, _, slab_admitted = _paged_cohort(
        problem, cfg, params, "slab", n_sessions, n_new, R=slab_cap)
    paged_sys, sids, paged_admitted = _paged_cohort(
        problem, cfg, params, "paged", n_sessions, n_new, page_size=2,
        R=slab_cap)
    assert len(paged_admitted) == n_sessions, \
        "paged admission must hold the whole cohort"
    paged_sys.drain_prefill()
    t0 = time.perf_counter()
    rounds = 0
    while any(paged_sys.sessions[s].n_generated < n_new for s in sids):
        paged_sys.decode_round()
        rounds += 1
    dt = time.perf_counter() - t0
    return {"paged_tok_s": n_sessions * n_new / dt,
            "slab_coresident": len(slab_admitted),
            "paged_coresident": len(paged_admitted),
            "coresidency_ratio": len(paged_admitted)
            / max(1, len(slab_admitted)),
            "rounds": rounds,
            "preemptions": paged_sys.round_stats["preemptions"]}


def oversubscription_scenario(n_sessions: int = 10, slab_cap: int = 2,
                              n_new: int = 30):
    """The preemption acceptance scenario (``oversub``): a cohort whose
    combined worst case overbooks the slab budget — slab admission REFUSES
    part of it, paged admission takes everything and serves it to
    completion by swapping sessions under page pressure (>= 1 preemption +
    resume), bit-exact per the tests; here we record the counts."""
    import jax

    from repro.configs import get_reduced_config
    from repro.models import init_params

    problem = _one_server_problem(slab_cap, l_out=n_new)
    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=problem.L)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)

    _, _, slab_admitted = _paged_cohort(
        problem, cfg, params, "slab", n_sessions, n_new, R=slab_cap)
    assert len(slab_admitted) < n_sessions, \
        "scenario must overbook the slab budget"
    paged_sys, sids, paged_admitted = _paged_cohort(
        problem, cfg, params, "paged", n_sessions, n_new, page_size=2,
        R=slab_cap)
    assert len(paged_admitted) == n_sessions
    paged_sys.drain_prefill()
    rounds = 0
    while any(paged_sys.sessions[s].n_generated < n_new for s in sids):
        paged_sys.decode_round()
        rounds += 1
        assert rounds < 20000, "oversubscribed cohort failed to converge"
    completed = sum(paged_sys.sessions[s].n_generated >= n_new
                    for s in sids)
    return {"n_sessions": n_sessions,
            "slab_admitted": len(slab_admitted),
            "paged_admitted": len(paged_admitted),
            "completed": completed, "rounds": rounds,
            "preemptions": paged_sys.round_stats["preemptions"],
            "resumes": paged_sys.round_stats["resumes"]}


def _assert_sim_parity(ref, fast):
    """The bit-exact twin contract: identical per-request rows (route,
    start, wait, every timing field) and identical aggregate metrics.
    ``decision_time_s`` is wall-clock and deliberately NOT part of it."""
    assert ref.requests == fast.requests, "fast/reference rows diverge"
    for f in ("drop_rate", "wait", "first_token", "per_token_rest",
              "per_token_all"):
        assert getattr(ref, f) == getattr(fast, f), (f, ref, fast)


def sim_throughput(n_requests: int = 2000, rate: float = 5.0, seed: int = 0):
    """Requests/s of the CPU-only discrete-event simulator on one long
    Poisson trace, measured for BOTH execution modes on the SAME trace:
    the per-request reference loop and the array-native fast engine
    (retirement-heap usage counters + memoized zero-wait decisions).
    Exact row parity is asserted before either number is recorded."""
    import time

    from repro.sim import SimConfig, simulate
    from repro.sim.workload import poisson_requests

    problem = _concurrency_problem()
    requests = poisson_requests(n_requests, rate, seed=seed)
    results, wall = {}, {}
    for mode in ("reference", "fast"):
        t0 = time.perf_counter()
        results[mode] = simulate(
            problem, SimConfig("proposed", n_requests=n_requests, rate=rate,
                               seed=seed, R=8, sim_mode=mode),
            requests=requests)
        wall[mode] = time.perf_counter() - t0
    _assert_sim_parity(results["reference"], results["fast"])
    st = results["fast"].fast_stats or {}
    return {"requests_per_s": n_requests / wall["reference"],
            "requests_per_s_reference": n_requests / wall["reference"],
            "requests_per_s_fast": n_requests / wall["fast"],
            "speedup": wall["reference"] / wall["fast"],
            "n_requests": n_requests, "wall_s": wall["reference"],
            "wall_s_fast": wall["fast"],
            "drop_rate": results["reference"].drop_rate,
            "fast_frac": st.get("fast_routes", 0) / max(1, n_requests),
            "parity": 1, "sim_mode": "both"}


def sim_throughput_1m(n_requests: int = 1_000_000, base_rate: float = 40.0,
                      peak_rate: float = 200.0, period: float = 7200.0,
                      seed: int = 0):
    """The planet-scale headline: a 1M-request diurnal trace (thinned
    nonhomogeneous Poisson, ~1.3 day-cycles) through the fast engine with
    array-backed metrics (``collect_rows=False``).  A 2000-request prefix
    is first replayed through BOTH modes with full rows as the exactness
    spot-check; trace generation is timed separately from the event loop."""
    import time

    from repro.sim import SimConfig, simulate
    from repro.sim.workload import diurnal_requests

    problem = _planet_problem()

    def _cfg(mode, n, collect):
        return SimConfig("proposed", n_requests=n, rate=1.0, seed=seed,
                         R=8, sim_mode=mode, collect_rows=collect)

    # both-modes parity spot-check on a prefix trace
    n_spot = min(2000, n_requests)
    spot = diurnal_requests(n_spot, base_rate, peak_rate, period=period,
                            n_clients=problem.n_clients, seed=seed)
    _assert_sim_parity(
        simulate(problem, _cfg("reference", n_spot, True), requests=spot),
        simulate(problem, _cfg("fast", n_spot, True), requests=spot))

    t0 = time.perf_counter()
    batch = diurnal_requests(n_requests, base_rate, peak_rate, period=period,
                             n_clients=problem.n_clients, seed=seed)
    gen_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = simulate(problem, _cfg("fast", n_requests, False), requests=batch)
    wall = time.perf_counter() - t0
    st = res.fast_stats or {}
    return {"requests_per_s": n_requests / wall, "n_requests": n_requests,
            "wall_s": wall, "trace_gen_s": gen_s,
            "trace_span_s": float(batch.arrival[-1]),
            "drop_rate": res.drop_rate, "wait": res.wait,
            "fast_frac": st.get("fast_routes", 0) / max(1, n_requests),
            "compactions": st.get("compactions", 0),
            "parity_spot_check": 1, "sim_mode": "fast"}


def sim_churn_study(n_servers: int = 120, n_requests: int = 2000,
                    rate: float = 20.0, n_storms: int = 6,
                    storm_size: int = 10, seed: int = 3):
    """Elastic-fleet churn: a 120-server fleet serving a Poisson trace
    while timed storms knock out / revive ``storm_size`` servers at a
    time.  Each storm triggers ``OnlineBPRR.replace_servers`` — a full
    CG-BP re-placement plus ``RouteCostCache`` invalidation — and the
    study reports how routing survives it (drops, waits, fleet size)."""
    import time

    from repro.sim import simulate_churn
    from repro.sim.workload import churn_schedule, poisson_requests

    problem = _fleet_problem(n_servers=n_servers)
    requests = poisson_requests(n_requests, rate=rate, seed=seed,
                                n_clients=problem.n_clients)
    span = n_requests / rate
    spacing = span / (n_storms + 1)
    schedule = churn_schedule(n_servers, n_storms=n_storms,
                              storm_size=storm_size, first=spacing,
                              spacing=spacing, seed=1)
    t0 = time.perf_counter()
    res = simulate_churn(problem, requests, schedule, R=16)
    wall = time.perf_counter() - t0
    return {"n_servers": n_servers, "n_requests": n_requests,
            "n_storms": res.n_storms, "n_replacements": res.n_replacements,
            "drop_rate": res.drop_rate, "wait": res.wait,
            "per_token_all": res.per_token_all, "alive_min": res.alive_min,
            "requests_per_s": n_requests / wall, "wall_s": wall}


def chaos_recovery_study(n_sessions: int = 6, n_new: int = 12,
                         kill_round: int = 3, victim: int = 5):
    """Crash-recovery latency + goodput, engine-vs-simulator
    cross-validated.

    An 8-server toy fleet serves ``n_sessions`` single-hop sessions; a
    :class:`FaultPlan` crashes ``victim``'s server silently after
    ``kill_round`` decode rounds.  The ENGINE discovers the loss by
    missed deadline, bills detection + backoff + failover replay on the
    virtual clock, and finishes every stream.  The SIMULATOR side prices
    the same recovery analytically from the shared components —
    ``FailureDetector.detect_time``/``backoff_time``, the
    ``subchain_route`` splice, and ``recovery_replay_cost`` with the
    known replay token count — and the two totals must agree to float
    precision (``recovery_parity``).  Goodput is tokens over the fleet
    makespan, reported against the fault-free twin; the default victim
    is the slowest host (the makespan holder), so the billed recovery
    visibly dents fleet goodput."""
    import jax

    from repro.configs import get_reduced_config
    from repro.core import LLMSpec, Problem, Route, ServerSpec, Workload
    from repro.models import init_params
    from repro.serving import (FailureDetector, FaultEvent, FaultPlan,
                               GeoServingSystem)
    from repro.serving.faults import recovery_replay_cost
    from repro.sim import subchain_route

    cfg = get_reduced_config("llama3_2_1b")
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    n_servers, l_in = 8, 4
    detector = FailureDetector(timeout_factor=3.0, backoff_base=0.01,
                               backoff_cap=0.04)

    def build(plan=None):
        llm = LLMSpec("toy", cfg.n_layers, block_bytes=50.0,
                      cache_bytes_per_token=1.0)
        servers = [ServerSpec(j, mem_bytes=900.0, tau=0.01 * (j + 1),
                              tau_prefill_base=0.002,
                              tau_prefill_per_token=0.0005)
                   for j in range(n_servers)]
        rtt = np.full((1, n_servers), 0.02)
        prob = Problem(llm, servers, 1, rtt, rtt * 3,
                       workload=Workload(l_in, n_new))
        system = GeoServingSystem(cfg, params, prob, R=4,
                                  max_new_tokens=n_new,
                                  max_sessions=n_sessions + 2,
                                  fault_plan=plan, detector=detector)
        rng = np.random.RandomState(0)
        sids = []
        for j in range(n_sessions):
            a, m = int(system.placement.a[j]), int(system.placement.m[j])
            assert a == 0 and m == prob.L, "toy placement must replicate"
            sids.append(system.create_session(
                rng.randint(2, cfg.vocab_size, l_in), 0,
                Route(servers=(j,), blocks=(m,)), n_new))
        assert system.try_admit_sessions(sids) == sids
        system.drain_prefill()
        return prob, system, sids

    def drive(system, sids):
        done = {}
        while len(done) < len(sids):
            for sid in sids:
                sess = system.sessions.get(sid)
                if sid not in done and (sess.state == "failed"
                                        or sess.n_generated >= n_new):
                    done[sid] = system.retire_session(sid)
            if len(done) < len(sids):
                system.decode_round()
        return done

    # fault-free twin: baseline makespan/goodput + the crash's clock time
    prob, twin_sys, twin_sids = build()
    clocks = {s: twin_sys.sessions[s].virtual_time for s in twin_sids}
    ptok = {s: twin_sys.sessions[s].per_token_time for s in twin_sids}
    twin = drive(twin_sys, twin_sids)
    # deliver the crash just before the min member clock crosses into
    # round kill_round+1, so exactly kill_round decoded tokens replay
    t_kill = min(clocks[s] + kill_round * ptok[s] for s in twin_sids) - 1e-9
    makespan0 = max(s.start + s.virtual_time for s in twin.values())
    goodput0 = n_sessions * n_new / makespan0

    plan = FaultPlan([FaultEvent(time=t_kill, kind="crash", server=victim)])
    prob, system, sids = build(plan)
    done = drive(system, sids)
    vic = done[sids[victim]]
    makespan1 = max(s.start + s.virtual_time for s in done.values())
    goodput1 = n_sessions * n_new / makespan1

    # simulator-side analytic prediction from the SHARED pricing pieces
    expected_hop = float(prob.rtt_token[0, victim]
                         + prob.llm.tau_weight(0, prob.L)
                         * prob.servers[victim].tau)
    spliced = subchain_route(prob, twin_sys.placement, {victim},
                             0, prob.L, 0)
    repl, e = [], 0
    for j, k in zip(spliced.servers, spliced.blocks):
        repl.append((j, e, e + k))
        e += k
    predicted = (detector.detect_time(expected_hop)
                 + detector.backoff_time()
                 + recovery_replay_cost(prob, 0, repl, kill_round,
                                        l_in=l_in))
    err = abs(vic.recovery_time - predicted) / predicted
    assert vic.route.servers == spliced.servers, (vic.route, spliced)
    served = sum(1 for s in done.values() if s.state != "failed")
    return {"n_sessions": n_sessions, "served": served,
            "n_detections": int(vic.n_detections),
            "n_replays": int(vic.n_replays),
            "recovery_s": float(vic.recovery_time),
            "detect_s": float(vic.detect_time),
            "backoff_s": float(vic.backoff_time),
            "replay_s": float(vic.replay_time),
            "predicted_recovery_s": float(predicted),
            "recovery_err": float(err),
            "recovery_parity": int(err < 1e-6),
            "goodput_tok_s": float(goodput1),
            "goodput_fault_free_tok_s": float(goodput0),
            "goodput_frac": float(goodput1 / goodput0)}


def sim_scale_smoke(n_requests: int = 50_000, budget_s: float = 60.0):
    """Bounded CI scale check (the ``--sim-scale`` job): a 50k-request
    diurnal trace through the fast engine must finish under the wall
    budget on a cold CI runner.  Raises on budget overrun or drops."""
    import time

    from repro.sim import SimConfig, simulate
    from repro.sim.workload import diurnal_requests

    problem = _planet_problem()
    batch = diurnal_requests(n_requests, 40.0, 200.0, period=7200.0,
                             n_clients=problem.n_clients, seed=0)
    t0 = time.perf_counter()
    res = simulate(problem,
                   SimConfig("proposed", n_requests=n_requests, rate=1.0,
                             seed=0, R=8, sim_mode="fast",
                             collect_rows=False),
                   requests=batch)
    wall = time.perf_counter() - t0
    assert res.sim_mode == "fast", res.sim_mode
    assert wall < budget_s, \
        f"{n_requests} requests took {wall:.1f}s (budget {budget_s:.0f}s)"
    return {"n_requests": n_requests, "wall_s": wall,
            "requests_per_s": n_requests / wall, "budget_s": budget_s,
            "drop_rate": res.drop_rate}


def _emit_xval(name: str, eng, simm, err, us):
    emit(name, us,
         f"per_token eng={eng['per_token_all']*1e3:.2f}ms "
         f"sim={simm['per_token_all']*1e3:.2f}ms "
         f"err={err['per_token_all']:.1%} | "
         f"first_token eng={eng['first_token']*1e3:.1f}ms "
         f"sim={simm['first_token']*1e3:.1f}ms "
         f"err={err['first_token']:.1%} | "
         f"max_conc={eng['max_concurrency']}")
    _record(name, per_token_eng=eng["per_token_all"],
            per_token_sim=simm["per_token_all"],
            first_token_eng=eng["first_token"],
            first_token_sim=simm["first_token"],
            err_per_token=err["per_token_all"],
            err_first_token=err["first_token"],
            max_concurrency=eng["max_concurrency"])


def run(full: bool = False, smoke: bool = False):
    """``smoke``: reduced trace sizes + the essential scenario per class —
    the CI job that keeps the perf trajectory populated."""
    import jax

    from repro.configs import get_reduced_config
    from repro.core import LLMSpec, Problem, ServerSpec, Workload
    from repro.models import init_params
    from repro.serving import GeoServingSystem, generate

    cfg = get_reduced_config("llama3_2_1b").replace(n_layers=8)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    tau = 0.01

    # Fig 2b analogue: virtual per-token time vs #blocks on one server.
    times = {}
    for m_blocks in (2, 4, 8):
        llm = LLMSpec("t", cfg.n_layers, 10.0, 0.5)
        # one big server forced to host everything + tiny helpers
        servers = [ServerSpec(0, 10.0 * m_blocks + 50, tau)]
        if m_blocks < cfg.n_layers:
            servers += [ServerSpec(1, 10.0 * (cfg.n_layers - m_blocks) + 50,
                                   tau)]
        rtt = np.full((1, len(servers)), 0.005)
        prob = Problem(llm, servers, 1, rtt, rtt, workload=Workload(4, 8))
        system = GeoServingSystem(cfg, params, prob, algorithm="proposed",
                                  R=1, max_new_tokens=8)
        toks = np.arange(4) + 2
        (out, vt), us = timed(generate, system, toks, 6)
        times[m_blocks] = vt / 7  # per forward
        emit(f"perfmodel.blocks{m_blocks}", us,
             f"virtual_per_token={vt/7*1e3:.2f}ms")
        _record(f"perfmodel.blocks{m_blocks}",
                virtual_per_token_s=vt / 7)
    # linearity check: time(8 blocks)/time(2 blocks) tracks the block ratio
    # modulo the constant RTT term
    t2, t8 = times[2], times[8]
    rtt_const = 0.005
    slope2 = (t2 - 2 * rtt_const)
    slope8 = (t8 - rtt_const)
    emit("perfmodel.linearity", 0.0,
         f"per-block slope (2-block route)={slope2/2*1e3:.2f}ms "
         f"(8-block)={slope8/8*1e3:.2f}ms (model tau={tau*1e3:.1f}ms)")
    _record("perfmodel.linearity", slope2_s=slope2 / 2, slope8_s=slope8 / 8,
            model_tau_s=tau)

    # §4-style cross-validation under concurrency
    n_requests = 8 if smoke else (20 if full else 10)
    for R in ((4,) if smoke else (1, 4, 8)):
        (eng, simm, err), us = timed(cross_validate, R,
                                     n_requests=n_requests)
        _emit_xval(f"xval.R{R}", eng, simm, err, us)

    # bursty arrivals: same-timestamp bursts admit as ONE bucket group —
    # the coalescable-prefill workload for the batched prefill path
    for R in ((4,) if smoke else (4, 8)):
        (eng, simm, err), us = timed(cross_validate, R,
                                     n_requests=n_requests, trace="bursty")
        _emit_xval(f"xval.bursty.R{R}", eng, simm, err, us)

    # hybrid topology: zamba2-style stack (mamba + shared-attention blocks)
    # with per-family block compute weights — the family-polymorphic state
    # pools against the simulator's weighted eq. (1)
    for R in ((4,) if smoke else (4, 8)):
        (eng, simm, err), us = timed(cross_validate, R,
                                     n_requests=n_requests,
                                     arch="zamba2_7b")
        _emit_xval(f"xval.hybrid.R{R}", eng, simm, err, us)

    # measured prefill throughput: serial (one session per call) vs the
    # bucket-group batched path, same burst, jit-warm
    tput, us = timed(prefill_throughput, R=4, burst=4 if smoke else 8)
    emit("prefill.tput.R4", us,
         f"serial={tput['serial']:.0f} tok/s "
         f"batched={tput['batched']:.0f} tok/s "
         f"speedup={tput['batched'] / tput['serial']:.2f}x")
    _record("prefill.tput.R4", serial_tok_s=tput["serial"],
            batched_tok_s=tput["batched"],
            speedup=tput["batched"] / tput["serial"])

    # measured DECODE throughput — the headline the ROADMAP north-star
    # cares about: device-resident fused rounds vs the per-session serial
    # reference, warm, compile excluded.  R32 is the deliberately larger
    # scenario (8 servers, 32 co-resident sessions, 8-hop routes).
    for name, ns, nsess in (("decode.tput.R8", 2, 8),
                            ("decode.tput.R32", 8, 32)):
        row, us = timed(decode_throughput, n_servers=ns, n_sessions=nsess,
                        n_rounds=2 if smoke else 4)
        emit(name, us,
             f"serial={row['serial_tok_s']:.0f} tok/s "
             f"fused={row['fused_tok_s']:.0f} tok/s "
             f"speedup={row['speedup']:.2f}x "
             f"dispatches/round={row['fused_dispatches_per_round']:.0f}")
        _record(name, **row)

    # device-group serving: mesh-sharded pooled steps vs the mesh=None
    # twin (token parity asserted inside), plus the step-cost-calibrated τ
    row, us = timed(shard_decode_throughput, n_rounds=2 if smoke else 4)
    emit("shard.decode.tput", us,
         f"sharded={row['sharded_tok_s']:.0f} tok/s "
         f"twin={row['twin_tok_s']:.0f} tok/s ratio={row['ratio']:.2f}x "
         f"tau_cal={row['tau_calibrated_s']*1e6:.3f}us "
         f"({row['mesh_devices']} device(s))")
    _record("shard.decode.tput", **row)

    # heterogeneous device groups: a {solo, (1,2), (2,2)} fleet vs the
    # all-solo twin (token + virtual-clock parity asserted when measured)
    # and the calibrated-vs-uniform τ CG-BP placement gap.  Runs in a
    # fresh interpreter because this process's jax device count is frozen
    # at first import and the matrix needs 8 forced host devices.
    rows, us = timed(_hetero_rows, smoke=smoke)
    het, og = rows["hetero.decode.tput"], rows["optgap.hetero"]
    emit("hetero.decode.tput", us,
         f"hetero={het['hetero_tok_s']:.0f} tok/s "
         f"twin={het['twin_tok_s']:.0f} tok/s ratio={het['ratio']:.2f}x "
         f"chips={het['group_chips']} "
         f"tau_spread={het['tau_spread']:.2f}x")
    _record("hetero.decode.tput", **het)
    emit("optgap.hetero", 0.0,
         f"calibrated={og['cost_calibrated_s']*1e3:.1f}ms "
         f"uniform={og['cost_uniform_s']*1e3:.1f}ms "
         f"gap={og['optgap_frac']*100:.0f}% "
         f"placement_differs={og['placement_differs']}")
    _record("optgap.hetero", **og)

    # paged cache pools: co-residency headline (the same topology's
    # worst-case budget caps slab at 1/4 of the cohort) + the
    # oversubscription-with-preemption scenario
    row, us = timed(paged_decode_throughput,
                    n_sessions=32 if smoke else 128,
                    slab_cap=8 if smoke else 32)
    emit("decode.tput.R128", us,
         f"paged={row['paged_tok_s']:.0f} tok/s "
         f"coresident {row['paged_coresident']} vs slab cap "
         f"{row['slab_coresident']} "
         f"({row['coresidency_ratio']:.1f}x)")
    _record("decode.tput.R128", **row)

    ov, us = timed(oversubscription_scenario,
                   n_sessions=6 if smoke else 10,
                   n_new=12 if smoke else 30)
    emit("oversub", us,
         f"slab admits {ov['slab_admitted']}/{ov['n_sessions']}, paged "
         f"serves {ov['completed']}/{ov['n_sessions']} to completion "
         f"({ov['preemptions']} preemptions, {ov['resumes']} resumes)")
    _record("oversub", **ov)

    # simulator throughput on a long trace, BOTH modes on the same trace
    # (exact row parity asserted inside before either number is recorded)
    st, us = timed(sim_throughput, n_requests=600 if smoke else 2000)
    emit("sim.tput", us,
         f"ref={st['requests_per_s_reference']:.0f} req/s "
         f"fast={st['requests_per_s_fast']:.0f} req/s "
         f"speedup={st['speedup']:.1f}x over {st['n_requests']} "
         f"requests (drop_rate={st['drop_rate']:.2f})")
    _record("sim.tput", **st)

    # planet-scale headline: 1M-request diurnal trace through the fast
    # engine (2k-request both-modes parity spot-check runs first)
    st, us = timed(sim_throughput_1m,
                   n_requests=20_000 if smoke else 1_000_000)
    emit("sim.tput.1M", us,
         f"{st['requests_per_s']:.0f} req/s over {st['n_requests']} "
         f"requests, {st['trace_span_s']/3600:.1f}h simulated in "
         f"{st['wall_s']:.1f}s (fast_frac={st['fast_frac']:.3f}, "
         f"drop_rate={st['drop_rate']:.3f})")
    _record("sim.tput.1M", **st)

    # chaos recovery: silent crash of the makespan-critical server,
    # timeout-detected and billed by the engine, priced analytically by
    # the simulator side from the shared detector/splice/replay pieces
    cr, us = timed(chaos_recovery_study)
    emit("chaos.recovery", us,
         f"recovery={cr['recovery_s']:.3f}s "
         f"(predicted {cr['predicted_recovery_s']:.3f}s, "
         f"err={cr['recovery_err']:.1e}), served "
         f"{cr['served']}/{cr['n_sessions']}, "
         f"goodput_frac={cr['goodput_frac']:.2f}")
    _record("chaos.recovery", **cr)

    # elastic-fleet churn: 120 servers, timed join/leave storms, each one
    # a full CG-BP re-placement through OnlineBPRR.replace_servers
    ch, us = timed(sim_churn_study,
                   n_requests=600 if smoke else 2000,
                   n_storms=3 if smoke else 6)
    emit("sim.churn", us,
         f"{ch['n_servers']} servers, {ch['n_replacements']} re-placements "
         f"over {ch['n_storms']} storms, alive_min={ch['alive_min']}, "
         f"drop_rate={ch['drop_rate']:.3f}, "
         f"{ch['requests_per_s']:.0f} req/s")
    _record("sim.churn", **ch)

    # kernel-backend throughput: pallas-vs-xla ratio per serving hot path
    # (decode attention / flash prefill).  On this CPU container the pallas
    # side runs in interpret mode, so the ratio is a placeholder (<~1x);
    # the recorded field is the hook real-TPU runs fill with the true
    # kernel speedup.
    from benchmarks.kernel_bench import throughput_scenarios

    kt, us = timed(throughput_scenarios, full=full)
    for name, row in kt.items():
        emit(name, us / len(kt),
             f"pallas={row['pallas_tok_s']:.0f} tok/s "
             f"xla={row['xla_tok_s']:.0f} tok/s "
             f"ratio={row['pallas_over_xla']:.2f}x")
        _record(name, **row)


def write_json(path: str):
    """Dump the collected scenario metrics as machine-readable JSON."""
    payload = {"benchmark": "engine_validation", "scenarios": _RESULTS}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path} ({len(_RESULTS)} scenarios)")


# scenarios the committed BENCH_engine.json must carry, with the fields
# (and floors) CI verifies WITHOUT re-timing anything — wall-clock numbers
# are whatever the committed full run measured; only structure and the
# machine-independent ratios/counters are checked
_REQUIRED_ROWS = {
    "perfmodel.blocks2": ("virtual_per_token_s",),
    "perfmodel.blocks8": ("virtual_per_token_s",),
    "xval.R4": ("err_per_token", "err_first_token"),
    "prefill.tput.R4": ("serial_tok_s", "batched_tok_s", "speedup"),
    "decode.tput.R8": ("serial_tok_s", "fused_tok_s", "speedup"),
    "decode.tput.R32": ("serial_tok_s", "fused_tok_s", "speedup"),
    "shard.decode.tput": ("sharded_tok_s", "twin_tok_s", "ratio",
                          "token_parity", "tau_calibrated_s"),
    "hetero.decode.tput": ("hetero_tok_s", "twin_tok_s", "ratio",
                           "token_parity", "tau_spread", "n_groups"),
    "optgap.hetero": ("cost_calibrated_s", "cost_uniform_s",
                      "optgap_frac", "placement_differs"),
    "decode.tput.R128": ("paged_tok_s", "slab_coresident",
                         "paged_coresident", "coresidency_ratio"),
    "oversub": ("n_sessions", "slab_admitted", "paged_admitted",
                "completed", "preemptions", "resumes"),
    "sim.tput": ("requests_per_s", "requests_per_s_reference",
                 "requests_per_s_fast", "speedup", "parity"),
    "sim.tput.1M": ("requests_per_s", "n_requests", "wall_s",
                    "parity_spot_check", "fast_frac"),
    "sim.churn": ("n_servers", "n_requests", "n_replacements",
                  "drop_rate", "alive_min"),
    "chaos.recovery": ("recovery_s", "predicted_recovery_s",
                       "recovery_parity", "goodput_frac", "served",
                       "n_sessions"),
}


def check_json(path: str) -> int:
    """``--check-only``: validate the structure of a committed
    BENCH_engine.json (the CI path — no flaky wall-clock re-timing).
    Returns the number of scenarios checked; raises on any violation."""
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("benchmark") == "engine_validation", path
    data = payload["scenarios"]
    for name, fields in _REQUIRED_ROWS.items():
        assert name in data, f"missing scenario {name!r}"
        for field in fields:
            v = data[name].get(field)
            assert isinstance(v, (int, float)) and np.isfinite(v), \
                f"{name}.{field} missing or non-finite: {v!r}"
    for name, row in data.items():
        if name.startswith("xval."):
            assert row["err_per_token"] < 0.10, (name, row)
            assert row["err_first_token"] < 0.10, (name, row)
    # machine-independent floors: the existing speedup ratios plus the
    # paged acceptance criteria (>= 4x co-residency on the same topology;
    # the oversubscribed cohort fully served with actual preemption)
    assert data["prefill.tput.R4"]["speedup"] > 1.0
    assert data["decode.tput.R32"]["speedup"] >= 2.0
    r128 = data["decode.tput.R128"]
    assert r128["coresidency_ratio"] >= 4.0, r128
    # device-group serving: parity is pass/fail (asserted when measured),
    # the calibrated τ must be a usable eq. (1) input
    shard = data["shard.decode.tput"]
    assert shard["token_parity"] == 1, shard
    assert shard["tau_calibrated_s"] > 0 and shard["ratio"] > 0, shard
    # heterogeneous device groups: parity is pass/fail, the calibrated τ
    # vector must be genuinely per-group (spread > 1), and the CG-BP
    # placement under calibrated τ must differ from — and, priced under
    # calibrated τ, cost no more than — the uniform-τ placement
    het = data["hetero.decode.tput"]
    assert het["token_parity"] == 1 and het["ratio"] > 0, het
    assert het["tau_spread"] > 1.0 and het["n_groups"] >= 3, het
    og = data["optgap.hetero"]
    assert og["placement_differs"] == 1, og
    assert og["cost_calibrated_s"] > 0, og
    assert og["optgap_frac"] >= 0.0, og
    assert og["cost_calibrated_s"] <= og["cost_uniform_s"] * (1 + 1e-9), og
    ov = data["oversub"]
    assert ov["slab_admitted"] < ov["n_sessions"], ov
    assert ov["completed"] == ov["n_sessions"] == ov["paged_admitted"], ov
    assert ov["preemptions"] >= 1 and ov["resumes"] >= 1, ov
    # planet-scale simulator floors: exact fast/reference parity was
    # asserted when measured (pass/fail flags), the fast engine must not
    # be slower than the reference, and the 1M-request study must clear
    # 20x the same file's reference throughput on the same machine
    st = data["sim.tput"]
    assert st["parity"] == 1 and st["speedup"] >= 1.0, st
    m1 = data["sim.tput.1M"]
    assert m1["parity_spot_check"] == 1, m1
    assert m1["n_requests"] >= 1_000_000, m1
    assert m1["requests_per_s"] >= 20 * st["requests_per_s_reference"], \
        (m1, st)
    assert 0.0 < m1["fast_frac"] <= 1.0, m1
    ch = data["sim.churn"]
    assert ch["n_servers"] >= 100 and ch["n_replacements"] >= 1, ch
    assert 0.0 <= ch["drop_rate"] <= 0.5, ch
    assert 0 < ch["alive_min"] <= ch["n_servers"], ch
    # chaos recovery: engine-vs-simulator recovery pricing must agree
    # (shared detector/splice/replay pieces — pass/fail, not a tolerance),
    # every session survives the crash, and the billed recovery costs
    # real goodput without collapsing it
    cr = data["chaos.recovery"]
    assert cr["recovery_parity"] == 1 and cr["recovery_s"] > 0.0, cr
    assert cr["served"] == cr["n_sessions"], cr
    assert 0.3 <= cr["goodput_frac"] <= 1.0, cr
    print(f"OK: {len(data)} scenarios, all {len(_REQUIRED_ROWS)} required "
          f"rows present; decode R32 speedup "
          f"{data['decode.tput.R32']['speedup']:.2f}x, paged co-residency "
          f"{r128['coresidency_ratio']:.1f}x, oversub served "
          f"{ov['completed']}/{ov['n_sessions']} with "
          f"{ov['preemptions']} preemptions, sim 1M at "
          f"{m1['requests_per_s']/st['requests_per_s_reference']:.0f}x "
          f"reference")
    return len(data)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="longer traces (20 requests per scenario)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced scenario set for CI")
    ap.add_argument("--check-only", action="store_true",
                    help="validate the committed --json file's structure "
                         "and ratio floors without re-timing anything")
    ap.add_argument("--hetero-child", action="store_true",
                    help="run ONLY the heterogeneous device-group scenarios "
                         "and print their JSON rows to stdout (needs 8 host "
                         "devices; run() spawns this with "
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--sim-scale", action="store_true",
                    help="bounded planet-scale smoke: a 50k-request "
                         "diurnal fast trace must finish under a fixed "
                         "wall budget (the sim-scale CI job)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_engine.json"), help="output path for the JSON metrics")
    args = ap.parse_args()
    if args.hetero_child:
        print(json.dumps(hetero_validation(
            n_rounds=2 if args.smoke else 4)))
    elif args.sim_scale:
        row = sim_scale_smoke()
        print(f"sim-scale OK: {row['n_requests']} requests in "
              f"{row['wall_s']:.1f}s ({row['requests_per_s']:.0f} req/s, "
              f"budget {row['budget_s']:.0f}s, "
              f"drop_rate={row['drop_rate']:.3f})")
    elif args.check_only:
        check_json(args.json)
    else:
        run(full=args.full, smoke=args.smoke)
        write_json(args.json)
