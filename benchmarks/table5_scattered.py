"""Table 5 (+ Tables 9/10): scattered scenarios over the three Topology-Zoo
style networks (AboveNet / BellCanada / GTS-CE)."""
from __future__ import annotations

from repro.core.perf_model import Workload
from repro.sim import run_comparison

from benchmarks.common import (FAST_SEEDS, FULL_SEEDS, emit, improvement,
                               scattered_problem, timed)

PAPER_TABLE5 = {  # (topo, rate, l_out) -> (petals, proposed)
    ("abovenet", 0.1, 64): (4.98, 1.86), ("abovenet", 0.1, 128): (4.03, 1.44),
    ("abovenet", 0.5, 64): (5.26, 1.97), ("abovenet", 0.5, 128): (4.58, 1.35),
    ("bellcanada", 0.1, 64): (6.31, 1.33),
    ("bellcanada", 0.1, 128): (3.82, 1.26),
    ("bellcanada", 0.5, 64): (6.74, 1.49),
    ("bellcanada", 0.5, 128): (4.16, 1.11),
    ("gts_ce", 0.1, 64): (7.05, 1.38), ("gts_ce", 0.1, 128): (4.69, 0.95),
    ("gts_ce", 0.5, 64): (6.89, 1.35), ("gts_ce", 0.5, 128): (4.89, 1.07),
}


def run(full: bool = False):
    seeds = FULL_SEEDS if full else FAST_SEEDS
    n_req = 100 if full else 50
    topos = ("abovenet", "bellcanada", "gts_ce") if full \
        else ("abovenet", "bellcanada")
    for topo in topos:
        for rate in (0.1, 0.5):
            for lout in ((64, 128) if full else (128,)):
                prob = scattered_problem(topo, eta=0.2,
                                         workload=Workload(20, lout))
                out, us = timed(run_comparison, prob,
                                ("petals", "proposed"), n_requests=n_req,
                                rate=rate, seeds=seeds)
                ref = PAPER_TABLE5.get((topo, rate, lout))
                ref_s = (f"paper={ref[0]:.2f}/{ref[1]:.2f}" if ref else "")
                emit(f"table5.{topo}.rate{rate}.lout{lout}", us,
                     f"petals={out['petals']['per_token_all']:.2f}s "
                     f"proposed={out['proposed']['per_token_all']:.2f}s "
                     f"first={out['petals']['first_token']:.0f}/"
                     f"{out['proposed']['first_token']:.0f}s "
                     f"improve={improvement(out):.0%} {ref_s}")


if __name__ == "__main__":
    run()
