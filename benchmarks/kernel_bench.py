"""Kernel parity + analytic-intensity report.

Interpret-mode wall times on CPU are meaningless for TPU perf, so this
suite reports correctness (max err vs oracle) + arithmetic intensity
(FLOPs/byte) per kernel shape — the quantity that situates each kernel on
the TPU roofline (197 TFLOP/s / 819 GB/s ⇒ ridge at ~240 FLOPs/byte)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed


def run(full: bool = False):
    import jax.numpy as jnp

    from repro.kernels import (attention_ref, decode_attention,
                               decode_attention_ref, flash_attention)

    rng = np.random.RandomState(0)
    shapes = [(1, 256, 4, 2, 64)] + ([(2, 512, 8, 2, 64)] if full else [])
    for B, S, H, Kv, D in shapes:
        q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
        k = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
        v = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
        out, us = timed(lambda: flash_attention(
            q, k, v, causal=True, block_q=64, block_kv=64, interpret=True
        ).block_until_ready())
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
        ref = attention_ref(qf, kf, vf).reshape(B, H, S, D).transpose(0, 2, 1, 3)
        err = float(jnp.max(jnp.abs(out - ref)))
        flops = 2 * B * H * S * S * D * 2 / 2  # causal
        bytes_ = (B * S * (H + 2 * Kv) * D * 2 + B * S * H * D * 2)
        emit(f"kernel.flash.B{B}S{S}H{H}", us,
             f"max_err={err:.2e} intensity={flops/bytes_:.0f}flops/B")

    T = 4096 if full else 1024
    B, H, Kv, D = 2, 8, 2, 64
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    out, us = timed(lambda: decode_attention(
        q, ck, cv, T - 1, block_kv=256, interpret=True).block_until_ready())
    G = H // Kv
    ref = decode_attention_ref(
        q.reshape(B, Kv, G, D).reshape(B * Kv, G, D),
        ck.transpose(0, 2, 1, 3).reshape(B * Kv, T, D),
        cv.transpose(0, 2, 1, 3).reshape(B * Kv, T, D), T - 1)
    err = float(jnp.max(jnp.abs(out.reshape(B * Kv, G, D) - ref)))
    flops = 2 * B * H * T * D * 2
    bytes_ = B * T * Kv * D * 2 * 2  # cache read dominates (bf16 on TPU)
    emit(f"kernel.decode.T{T}", us,
         f"max_err={err:.2e} intensity={flops/bytes_:.1f}flops/B "
         f"(memory-bound: cache-read limited)")


if __name__ == "__main__":
    run()
