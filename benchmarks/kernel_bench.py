"""Kernel parity + analytic-intensity report.

Interpret-mode wall times on CPU are meaningless for TPU perf, so this
suite reports correctness (max err vs oracle) + arithmetic intensity
(FLOPs/byte) per kernel shape — the quantity that situates each kernel on
the TPU roofline (197 TFLOP/s / 819 GB/s ⇒ ridge at ~240 FLOPs/byte).

``throughput_scenarios`` additionally measures the pallas-vs-xla wall-time
ratio per serving hot path (decode attention, flash prefill) — the hook
``benchmarks/engine_validation.py --smoke`` records into
``BENCH_engine.json``: ~1x-and-meaningless in interpret mode on CPU, the
real signal on TPU runs where the kernels compile through Mosaic."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, timed


def _best_of(fn, n: int = 3) -> float:
    """Min wall-seconds of ``n`` steady-state calls.

    Benchmark hygiene: ``fn`` must return a jax array; the FIRST call —
    trace + compile — is discarded, and every call is drained with
    ``block_until_ready`` so async dispatch cannot leak a call's work into
    the next measurement window."""
    fn().block_until_ready()  # discarded: trace + compile + first run
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def throughput_scenarios(full: bool = False):
    """{scenario: metrics} for the pallas-vs-xla serving hot paths.

    ``kernels.decode.tput`` — pooled decode attention (rows of one cache
    pool, per-row positions); ``kernels.flash.tput`` — bucketed prefill
    attention.  Each row records tokens/s per backend plus their ratio.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import decode_attention, flash_attention
    from repro.models.attention import attention_core, decode_attention_xla

    # hygiene: jit BOTH sides so the steady-state window never re-traces —
    # the un-jitted pallas wrappers used to pay per-call tracing, skewing
    # the pallas-vs-xla ratio toward trace overhead instead of kernel time
    decode_pl = jax.jit(lambda q, ck, cv, pos: decode_attention(q, ck, cv,
                                                                pos))
    flash_pl = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
    decode_xla = jax.jit(decode_attention_xla)
    core_xla = jax.jit(attention_core)
    rng = np.random.RandomState(0)
    out = {}

    # decode: B pooled rows at mixed positions over a long cache
    B, H, Kv, D = 8, 8, 2, 64
    T = 2048 if full else 512
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    pos = jnp.asarray(rng.randint(T // 2, T, size=B), jnp.int32)
    t_pl = _best_of(lambda: decode_pl(q, ck, cv, pos))
    # the XLA oracle takes a scalar pos; give it the max (same work shape)
    t_xla = _best_of(lambda: decode_xla(q, ck, cv, T - 1))
    out["kernels.decode.tput"] = {
        "pallas_tok_s": B / t_pl, "xla_tok_s": B / t_xla,
        "pallas_over_xla": t_xla / t_pl}

    # flash prefill: one bucket group's worth of rows
    B, S = 4, (1024 if full else 256)
    q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
    k = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
    v = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
    t_pl = _best_of(lambda: flash_pl(q, k, v))
    G = H // Kv
    kx, vx = jnp.repeat(k, G, axis=2), jnp.repeat(v, G, axis=2)
    positions = jnp.arange(S)
    t_xla = _best_of(lambda: core_xla(q, kx, vx, positions, positions))
    out["kernels.flash.tput"] = {
        "pallas_tok_s": B * S / t_pl, "xla_tok_s": B * S / t_xla,
        "pallas_over_xla": t_xla / t_pl}
    return out


def run(full: bool = False):
    import jax.numpy as jnp

    from repro.kernels import (attention_ref, decode_attention,
                               decode_attention_ref, flash_attention)

    rng = np.random.RandomState(0)
    shapes = [(1, 256, 4, 2, 64)] + ([(2, 512, 8, 2, 64)] if full else [])
    for B, S, H, Kv, D in shapes:
        q = jnp.asarray(rng.randn(B, S, H, D), jnp.float32) * 0.3
        k = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
        v = jnp.asarray(rng.randn(B, S, Kv, D), jnp.float32) * 0.3
        flash_run = lambda: flash_attention(
            q, k, v, causal=True, block_q=64, block_kv=64, interpret=True
        ).block_until_ready()
        flash_run()  # warm-up: the timed call measures steady state
        out, us = timed(flash_run)
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
        kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
        vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
        ref = attention_ref(qf, kf, vf).reshape(B, H, S, D).transpose(0, 2, 1, 3)
        err = float(jnp.max(jnp.abs(out - ref)))
        flops = 2 * B * H * S * S * D * 2 / 2  # causal
        bytes_ = (B * S * (H + 2 * Kv) * D * 2 + B * S * H * D * 2)
        emit(f"kernel.flash.B{B}S{S}H{H}", us,
             f"max_err={err:.2e} intensity={flops/bytes_:.0f}flops/B")

    T = 4096 if full else 1024
    B, H, Kv, D = 2, 8, 2, 64
    q = jnp.asarray(rng.randn(B, 1, H, D), jnp.float32) * 0.3
    ck = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    cv = jnp.asarray(rng.randn(B, T, Kv, D), jnp.float32) * 0.3
    decode_run = lambda: decode_attention(
        q, ck, cv, T - 1, block_kv=256, interpret=True).block_until_ready()
    decode_run()  # warm-up: the timed call measures steady state
    out, us = timed(decode_run)
    G = H // Kv
    ref = decode_attention_ref(
        q.reshape(B, Kv, G, D).reshape(B * Kv, G, D),
        ck.transpose(0, 2, 1, 3).reshape(B * Kv, T, D),
        cv.transpose(0, 2, 1, 3).reshape(B * Kv, T, D), T - 1)
    err = float(jnp.max(jnp.abs(out.reshape(B * Kv, G, D) - ref)))
    flops = 2 * B * H * T * D * 2
    bytes_ = B * T * Kv * D * 2 * 2  # cache read dominates (bf16 on TPU)
    emit(f"kernel.decode.T{T}", us,
         f"max_err={err:.2e} intensity={flops/bytes_:.1f}flops/B "
         f"(memory-bound: cache-read limited)")


if __name__ == "__main__":
    run()
