"""Roofline report: aggregates the dry-run artifacts
(experiments/dryrun/*.json) into the per-(arch x shape x mesh) table used by
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get(
    "DRYRUN_DIR",
    "experiments/dryrun_v2" if os.path.isdir("experiments/dryrun_v2")
    else "experiments/dryrun")


def rows():
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def run(full: bool = False):
    data = rows()
    if not data:
        emit("roofline.missing", 0.0,
             f"no dry-run artifacts under {DRYRUN_DIR}; run "
             "PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    for r in data:
        t = r["roofline"]
        emit(f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}",
             r["compile_seconds"] * 1e6,
             f"compute={t['compute_s']*1e3:.1f}ms "
             f"memory={t['memory_s']*1e3:.1f}ms "
             f"(tpu_est={t['memory_s_tpu_est']*1e3:.1f}ms) "
             f"coll={t['collective_s']*1e3:.1f}ms "
             f"dominant={t['dominant']} "
             f"useful_flops={r['useful_flops_ratio']:.2f} "
             f"peak_hbm={r['memory']['peak_hbm_bytes']/1e9:.1f}GB "
             f"fits_tpu_est={r['fits_hbm_16g_tpu_est']}")


if __name__ == "__main__":
    run()
