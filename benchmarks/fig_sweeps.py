"""Figs 6–9, 13, 14: simulator sweeps over #servers C, high-perf fraction
eta, request rate lambda, output length, proportional scaling, and |R|
sensitivity — all five algorithm arms on the scattered scenarios."""
from __future__ import annotations

import numpy as np

from repro.core.perf_model import Workload
from repro.core.placement import auto_R
from repro.sim import run_comparison

from benchmarks.common import (FAST_SEEDS, FULL_SEEDS, emit, improvement,
                               scattered_problem, timed)

ARMS_FAST = ("petals", "proposed", "optimized_number")
ARMS_FULL = ("petals", "proposed", "optimized_order", "optimized_number",
             "optimized_rr")


def _row(tag, out, us):
    parts = [f"{alg}={out[alg]['per_token_all']:.2f}s" for alg in out]
    emit(tag, us, " ".join(parts) + f" improve={improvement(out):.0%}")


def fig6_servers(full=False):
    arms = ARMS_FULL if full else ARMS_FAST
    seeds = FULL_SEEDS if full else FAST_SEEDS
    topo = "bellcanada"
    import math
    for C in ((10, 14, 19, 24) if full else (10, 19)):
        prob = scattered_problem(topo, C=C)
        out, us = timed(run_comparison, prob, arms, n_requests=60,
                        rate=0.5, seeds=seeds)
        _row(f"fig6.{topo}.C{C}", out, us)


def fig7_eta(full=False):
    arms = ARMS_FULL if full else ARMS_FAST
    seeds = FULL_SEEDS if full else FAST_SEEDS
    for eta in ((0.1, 0.2, 0.4, 0.6) if full else (0.1, 0.4)):
        prob = scattered_problem("bellcanada", eta=eta)
        out, us = timed(run_comparison, prob, arms, n_requests=60,
                        rate=0.5, seeds=seeds)
        _row(f"fig7.eta{eta}", out, us)


def fig8_rate(full=False):
    arms = ARMS_FULL if full else ARMS_FAST
    seeds = FULL_SEEDS if full else FAST_SEEDS
    for rate in ((0.1, 0.3, 0.5, 0.8) if full else (0.1, 0.5)):
        prob = scattered_problem("bellcanada")
        n_req = int(200 * rate) if full else 50
        out, us = timed(run_comparison, prob, arms, n_requests=max(n_req, 20),
                        rate=rate, seeds=seeds)
        _row(f"fig8.rate{rate}", out, us)


def fig9_seqlen(full=False):
    arms = ARMS_FULL if full else ARMS_FAST
    seeds = FULL_SEEDS if full else FAST_SEEDS
    for lout in ((32, 64, 128, 256) if full else (64, 256)):
        prob = scattered_problem("bellcanada", workload=Workload(20, lout))
        out, us = timed(run_comparison, prob, arms, n_requests=50,
                        rate=0.5, seeds=seeds)
        _row(f"fig9.lout{lout}", out, us)


def fig13_scaling(full=False):
    """Proportional growth: C servers with rate = (0.1/9)·C (paper Fig 13)."""
    seeds = FULL_SEEDS if full else FAST_SEEDS
    for C in ((9, 18, 36, 59) if full else (9, 29)):
        rate = 0.1 / 9 * C
        prob = scattered_problem("gts_ce", C=C)
        out, us = timed(run_comparison, prob, ("petals", "proposed"),
                        n_requests=60, rate=rate, seeds=seeds)
        _row(f"fig13.C{C}.rate{rate:.2f}", out, us)


def fig14_sensitivity(full=False):
    """Fixed |R| computed for lambda_base=0.5 vs varying actual rates."""
    seeds = FULL_SEEDS if full else FAST_SEEDS
    prob = scattered_problem("bellcanada")
    R_fixed = auto_R(prob, 0.5, 1.5 * prob.workload.l_out)
    for rate in ((0.1, 0.5, 0.8, 1.2) if full else (0.1, 0.8)):
        out, us = timed(run_comparison, prob,
                        ("proposed", "optimized_number"), n_requests=50,
                        rate=rate, seeds=seeds, R=R_fixed)
        emit(f"fig14.R{R_fixed}.rate{rate}", us,
             f"proposed={out['proposed']['per_token_all']:.2f}s "
             f"optimized_number={out['optimized_number']['per_token_all']:.2f}s")


def run(full: bool = False):
    fig6_servers(full)
    fig7_eta(full)
    fig8_rate(full)
    fig9_seqlen(full)
    fig13_scaling(full)
    fig14_sensitivity(full)


if __name__ == "__main__":
    run()
