"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Default mode keeps runtimes
CPU-friendly (fewer Monte-Carlo seeds / requests / sweep points);
``--full`` reproduces the paper-scale settings.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()

    from benchmarks import (fig_sweeps, optimality_gap, roofline,
                            table4_clustered, table5_scattered,
                            table6_runtime)
    suites = [
        ("table4", table4_clustered.run),
        ("table5", table5_scattered.run),
        ("table6", table6_runtime.run),
        ("figs", fig_sweeps.run),
        ("optgap", optimality_gap.run),
        ("roofline", roofline.run),
    ]
    try:
        from benchmarks import engine_validation
        suites.append(("engine_validation", engine_validation.run))
    except ImportError:
        pass
    try:
        from benchmarks import kernel_bench
        suites.append(("kernels", kernel_bench.run))
    except ImportError:
        pass

    only = set(args.only.split(",")) if args.only else None
    failures = 0
    for name, fn in suites:
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            fn(full=args.full)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
