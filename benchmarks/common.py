"""Shared benchmark utilities: CSV emission + standard scenario builders."""
from __future__ import annotations

import sys
import time
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.perf_model import Problem, Workload
from repro.sim import (SimConfig, clustered_scenario, make_topology,
                       place_servers, run_comparison, scattered_scenario)

FAST_SEEDS = (0, 1)
FULL_SEEDS = tuple(range(5))


def emit(name: str, us_per_call: float, derived: str):
    """Scaffold-mandated CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.3f},{derived}")


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def scattered_problem(topology: str, C: Optional[int] = None,
                      eta: float = 0.2, seed: int = 0,
                      workload: Workload = Workload(20, 128)) -> Problem:
    topo = make_topology(topology, seed=seed)
    C = C or max(4, int(0.4 * topo.n))
    server_nodes, flags, client = place_servers(topo, C, eta, seed=seed)
    return scattered_scenario(topo.rtt, server_nodes, client, flags,
                              workload=workload)


def improvement(out: Dict[str, Dict[str, float]], metric="per_token_all",
                base="petals", ours="proposed") -> float:
    b = out[base][metric]
    o = out[ours][metric]
    return 1.0 - o / b if b > 0 else 0.0
