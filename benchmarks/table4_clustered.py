"""Table 4 (+ Tables 7/8): clustered scenario (Table 2) — average per-token
time over all tokens, first-token time, and per-remaining-token time, for
client locations Cluster0/1/2 x request rates x output lengths."""
from __future__ import annotations

from repro.core.perf_model import Workload
from repro.sim import clustered_scenario, run_comparison

from benchmarks.common import FAST_SEEDS, FULL_SEEDS, emit, improvement, timed

PAPER_TABLE4 = {  # (cluster, rate, l_out) -> (petals, proposed) seconds
    (0, 0.1, 64): (6.23, 1.92), (0, 0.1, 128): (4.76, 1.43),
    (0, 0.5, 64): (6.28, 2.00), (0, 0.5, 128): (5.14, 1.34),
    (1, 0.1, 64): (5.44, 1.78), (1, 0.1, 128): (4.60, 1.04),
    (1, 0.5, 64): (5.56, 1.88), (1, 0.5, 128): (4.79, 1.11),
    (2, 0.1, 64): (5.30, 1.79), (2, 0.1, 128): (4.85, 1.31),
    (2, 0.5, 64): (5.34, 1.94), (2, 0.5, 128): (5.25, 1.37),
}


def run(full: bool = False):
    seeds = FULL_SEEDS if full else FAST_SEEDS
    n_req = 100 if full else 60
    rates = (0.1, 0.5)
    louts = (64, 128)
    clusters = (0, 1, 2) if full else (0, 1)
    for cl in clusters:
        for rate in rates:
            for lout in louts:
                prob, _ = clustered_scenario(
                    client_cluster=cl, workload=Workload(20, lout))
                out, us = timed(run_comparison, prob,
                                ("petals", "proposed"), n_requests=n_req,
                                rate=rate, seeds=seeds)
                ref = PAPER_TABLE4.get((cl, rate, lout))
                ref_s = (f"paper={ref[0]:.2f}/{ref[1]:.2f}" if ref else "")
                emit(f"table4.cluster{cl}.rate{rate}.lout{lout}", us,
                     f"petals={out['petals']['per_token_all']:.2f}s "
                     f"proposed={out['proposed']['per_token_all']:.2f}s "
                     f"first={out['petals']['first_token']:.0f}/"
                     f"{out['proposed']['first_token']:.0f}s "
                     f"rest={out['petals']['per_token_rest']:.2f}/"
                     f"{out['proposed']['per_token_rest']:.2f}s "
                     f"improve={improvement(out):.0%} {ref_s}")


if __name__ == "__main__":
    run()
