from repro.data.pipeline import encdec_batches, lm_batches, make_batches, shard_batch

__all__ = ["encdec_batches", "lm_batches", "make_batches", "shard_batch"]
