"""Synthetic-data pipeline: deterministic token streams, packing, host feed.

The paper is an inference paper; training is exercised by the ``train_4k``
shape cells and examples/train_small.py.  The pipeline provides:

* ``lm_batches`` — seeded, reproducible packed LM batches (power-law unigram
  stream packed into fixed-length rows, BOS-separated documents),
* ``encdec_batches`` — frame/token pairs for the audio enc-dec arch,
* ``shard_batch`` — place a host batch onto a mesh by named sharding.

Determinism: batch ``i`` is a pure function of (seed, i) — restarts resume
the stream exactly (checkpoint stores the step counter).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig

BOS = 1


def _zipf_tokens(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Power-law token stream (zipf-ish) clipped into the vocab."""
    raw = rng.zipf(1.3, size=n)
    return (raw % max(2, vocab - 2) + 2).astype(np.int32)


def _doc_lengths(rng: np.random.Generator, total: int) -> np.ndarray:
    out = []
    left = total
    while left > 0:
        ln = int(np.clip(rng.lognormal(5.0, 1.0), 16, 4096))
        out.append(min(ln, left))
        left -= out[-1]
    return np.asarray(out)


def lm_batches(cfg: ModelConfig, batch_size: int, seq_len: int,
               seed: int = 0, start_step: int = 0) -> Iterator[Dict]:
    """Packed LM batches: documents concatenated with BOS separators."""
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step))
        total = batch_size * seq_len
        toks = _zipf_tokens(rng, total, cfg.vocab_size)
        # BOS-separate documents (packing)
        pos = 0
        for ln in _doc_lengths(rng, total):
            toks[pos] = BOS
            pos += ln
        yield {"tokens": toks.reshape(batch_size, seq_len)}
        step += 1


def encdec_batches(cfg: ModelConfig, batch_size: int, seq_len: int,
                   seed: int = 0, start_step: int = 0) -> Iterator[Dict]:
    """Frame/token pairs for the audio enc-dec stub frontend."""
    step = start_step
    while True:
        rng = np.random.default_rng((seed, step, 7))
        frames = rng.standard_normal(
            (batch_size, seq_len, cfg.frame_dim)).astype(np.float32)
        toks = _zipf_tokens(rng, batch_size * seq_len, cfg.vocab_size)
        toks = toks.reshape(batch_size, seq_len)
        toks[:, 0] = BOS
        yield {"frames": frames, "tokens": toks}
        step += 1


def make_batches(cfg: ModelConfig, batch_size: int, seq_len: int,
                 seed: int = 0, start_step: int = 0) -> Iterator[Dict]:
    if cfg.is_enc_dec:
        return encdec_batches(cfg, batch_size, seq_len, seed, start_step)
    return lm_batches(cfg, batch_size, seq_len, seed, start_step)


def shard_batch(batch: Dict, mesh=None, sh=None) -> Dict:
    """Device-put a host batch with the ShardingCtx's batch sharding."""
    import jax
    import jax.numpy as jnp

    if mesh is None or sh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = sh.named_sharding(*(("batch",) + (None,) * (v.ndim - 1)))
        out[k] = jax.device_put(jnp.asarray(v), spec)
    return out
