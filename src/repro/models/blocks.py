"""Per-family block functions — the BPRR placement granularity.

Every block exposes three entry points used across the framework:

* ``init_<kind>(key, cfg)``               -> (params, axes)
* ``<kind>_full(params, cfg, sh, h, ...)`` -> (h, cache_entry)   train/prefill
* ``<kind>_decode(params, cfg, sh, h, cache_entry, pos)`` -> (h, cache_entry)

``cache_entry`` is the per-block serving state (KV / MLA latent / SSM state);
train passes ignore it.  The stack drivers in ``repro.models.model`` scan
these; the geo serving engine (``repro.serving.engine``) applies them one
block at a time according to the paper's placement ``(a_j, m_j)``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamBuilder,
    ShardingCtx,
    apply_mlp,
    apply_norm,
    init_mlp,
    init_norm,
)

_BIG = 1 << 30

# Block kinds a stack can be made of — the granularity the serving layer's
# per-block StateSpec dispatch (repro.serving.kv_cache) is keyed on.
BLOCK_KINDS = ("decoder", "rwkv", "mamba", "mamba_shared", "enc", "dec")


def stack_block_kinds(cfg: ModelConfig):
    """Per-block kind tuple (length ``cfg.n_layers``) in BPRR block order.

    * dense / moe / vlm:  ("decoder",) * n_layers
    * rwkv6:              ("rwkv",) * n_layers
    * zamba2 hybrid:      "mamba" everywhere, except the last block of each
      shared-attention group (every ``shared_attn_period``-th) which is
      "mamba_shared" — a mamba mixer followed by the parameter-shared
      attention+MLP block (KV cache + SSM state on ONE block).
    * seamless enc-dec:   ("enc",) * n_enc + ("dec",) * n_dec.

    Raises ``ValueError`` for families outside :data:`BLOCK_KINDS` so the
    serving layer can surface the supported set.
    """
    if cfg.is_enc_dec:
        return (("enc",) * cfg.n_enc_layers) + (("dec",) * cfg.n_dec_layers)
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period
        n_mega = (cfg.n_layers // period) * period
        return tuple(
            "mamba_shared" if (i < n_mega and i % period == period - 1)
            else "mamba" for i in range(cfg.n_layers))
    if cfg.family == "ssm":
        return ("rwkv",) * cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        return ("decoder",) * cfg.n_layers
    raise ValueError(
        f"unknown block family {cfg.family!r} for {cfg.name!r}; supported "
        "stacks are built from block kinds " + ", ".join(BLOCK_KINDS))


def window_for_layer(cfg: ModelConfig, layer_idx):
    """Traced per-layer sliding window (gemma3 local:global pattern).

    Returns a scalar usable inside a scanned block: the window size for local
    layers, or a huge value for global layers.  ``layer_idx`` may be traced.
    """
    if cfg.sliding_window <= 0:
        return None
    if cfg.local_global_period <= 0:
        return cfg.sliding_window
    is_global = (layer_idx + 1) % cfg.local_global_period == 0
    return jnp.where(is_global, _BIG, cfg.sliding_window)


# ---------------------------------------------------------------------------
# Decoder block (dense / moe / vlm families; gemma3 pattern via window arg)
# ---------------------------------------------------------------------------


def init_decoder_block(key, cfg: ModelConfig):
    pb = ParamBuilder(key)
    pb.sub("ln1", init_norm, cfg)
    if cfg.attn_kind == "mla":
        pb.sub("attn", attn.init_mla, cfg)
    else:
        pb.sub("attn", attn.init_gqa, cfg)
    pb.sub("ln2", init_norm, cfg)
    if cfg.is_moe:
        pb.sub("ffn", moe_mod.init_moe, cfg)
    else:
        pb.sub("ffn", init_mlp, cfg)
    if cfg.sandwich_norm:
        pb.sub("post_ln1", init_norm, cfg)
        pb.sub("post_ln2", init_norm, cfg)
    return pb.build()


def decoder_block_full(params, cfg: ModelConfig, sh: ShardingCtx, h, positions,
                       layer_idx=0, prefix_kv=None, backend: str = "xla"):
    """Full-sequence decoder block.  Returns (h, cache_entry, aux).

    ``prefix_kv``: optional already-cached prefix for chunked prefill — a
    (k, v) pair for GQA or (latent, krope) for MLA covering positions
    [0, P).  ``positions`` must then be ``P + arange(S)``.  The returned
    ``cache_entry`` always covers only the positions in ``h``.
    ``backend``: compute backend for the attention core ("xla" | "pallas").
    """
    win = window_for_layer(cfg, layer_idx)
    x = apply_norm(params["ln1"], cfg, h)
    if cfg.attn_kind == "mla":
        a, kv = attn.apply_mla_full(params["attn"], cfg, sh, x, positions,
                                    prefix_kv=prefix_kv, backend=backend)
        cache = {"latent": kv[0], "krope": kv[1]}
    else:
        a, kv = attn.apply_gqa_full(params["attn"], cfg, sh, x, positions, win,
                                    prefix_kv=prefix_kv, backend=backend)
        cache = {"k": kv[0], "v": kv[1]}
    if cfg.sandwich_norm:
        a = apply_norm(params["post_ln1"], cfg, a)
    h = h + a
    x = apply_norm(params["ln2"], cfg, h)
    aux = {}
    if cfg.is_moe:
        m, aux = moe_mod.apply_moe(params["ffn"], cfg, sh, x)
    else:
        m = apply_mlp(params["ffn"], cfg, sh, x)
    if cfg.sandwich_norm:
        m = apply_norm(params["post_ln2"], cfg, m)
    h = sh.act(h + m, "batch", "seq_act", None)
    return h, cache, aux


def decoder_block_attn_decode(params, cfg: ModelConfig, sh: ShardingCtx, h,
                              cache, pos, layer_idx=0, backend: str = "xla"):
    """Attention half of :func:`decoder_block_decode`: ln1 -> attention ->
    residual (+ sandwich post-norm).  Returns (h, cache) with the FFN half
    still to run — the pooled decode step uses the split to batch the MoE
    FFN over its rows (the pure-EP all-to-all path)."""
    win = window_for_layer(cfg, layer_idx)
    x = apply_norm(params["ln1"], cfg, h)
    if cfg.attn_kind == "mla":
        a, lat, kr = attn.apply_mla_decode(
            params["attn"], cfg, sh, x, cache["latent"], cache["krope"], pos,
            backend=backend)
        cache = {"latent": lat, "krope": kr}
    else:
        a, ck, cv = attn.apply_gqa_decode(
            params["attn"], cfg, sh, x, cache["k"], cache["v"], pos, win,
            backend=backend)
        cache = {"k": ck, "v": cv}
    if cfg.sandwich_norm:
        a = apply_norm(params["post_ln1"], cfg, a)
    return h + a, cache


def decoder_block_ffn(params, cfg: ModelConfig, sh: ShardingCtx, h):
    """FFN half of :func:`decoder_block_decode`: ln2 -> MoE/MLP ->
    residual.  Token-wise (position-free), so callers may regroup a
    (rows, 1, d) decode grid into any (B, S, d) factorization first —
    the EP decode path reshapes rows onto the (data, model) grid."""
    x = apply_norm(params["ln2"], cfg, h)
    if cfg.is_moe:
        m, _ = moe_mod.apply_moe(params["ffn"], cfg, sh, x)
    else:
        m = apply_mlp(params["ffn"], cfg, sh, x)
    if cfg.sandwich_norm:
        m = apply_norm(params["post_ln2"], cfg, m)
    return h + m


def decoder_block_decode(params, cfg: ModelConfig, sh: ShardingCtx, h, cache,
                         pos, layer_idx=0, backend: str = "xla"):
    """Single-token decoder block.  h (B,1,d).  Returns (h, cache)."""
    h, cache = decoder_block_attn_decode(params, cfg, sh, h, cache, pos,
                                         layer_idx, backend=backend)
    return decoder_block_ffn(params, cfg, sh, h), cache


# ---------------------------------------------------------------------------
# Encoder / decoder blocks (seamless enc-dec)
# ---------------------------------------------------------------------------


def init_encoder_block(key, cfg: ModelConfig):
    pb = ParamBuilder(key)
    pb.sub("ln1", init_norm, cfg)
    pb.sub("attn", attn.init_gqa, cfg)
    pb.sub("ln2", init_norm, cfg)
    pb.sub("ffn", init_mlp, cfg)
    return pb.build()


def encoder_block_full(params, cfg: ModelConfig, sh: ShardingCtx, h, positions,
                       backend: str = "xla"):
    """Bidirectional self-attention encoder block."""
    x = apply_norm(params["ln1"], cfg, h)
    q = attn._q_proj(params["attn"], cfg, x)
    k, v = attn._kv_proj(params["attn"], cfg, x)
    if cfg.pos_kind == "rope":
        cos, sin = attn.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        q = attn.apply_rope(q, cos, sin)
        k = attn.apply_rope(k, cos, sin)
    if attn._use_pallas_flash(backend, causal=False):
        out = attn.flash_attention(q, k, v, causal=False)
    else:
        G = cfg.n_heads // cfg.n_kv_heads
        k_exp = jnp.repeat(k, G, axis=2) if G > 1 else k
        v_exp = jnp.repeat(v, G, axis=2) if G > 1 else v
        out = attn.attention_core(q, k_exp, v_exp, positions, positions,
                                  causal=False)
    a = jnp.einsum("bshk,hkd->bsd", out,
                   params["attn"]["wo"].astype(x.dtype))
    h = h + a
    x = apply_norm(params["ln2"], cfg, h)
    h = h + apply_mlp(params["ffn"], cfg, sh, x)
    return sh.act(h, "batch", "seq_act", None)


def init_cross_decoder_block(key, cfg: ModelConfig):
    pb = ParamBuilder(key)
    pb.sub("ln1", init_norm, cfg)
    pb.sub("self_attn", attn.init_gqa, cfg)
    pb.sub("ln_cross", init_norm, cfg)
    pb.sub("cross_attn", attn.init_gqa, cfg)
    pb.sub("ln2", init_norm, cfg)
    pb.sub("ffn", init_mlp, cfg)
    return pb.build()


def cross_decoder_block_full(params, cfg: ModelConfig, sh: ShardingCtx, h,
                             positions, enc_h, prefix_kv=None, enc_kv=None,
                             backend: str = "xla"):
    """Decoder block with cross-attention.  Returns (h, cache_entry).

    ``prefix_kv``: optional already-cached self-attention (k, v) prefix for
    chunked prefill — same contract as ``decoder_block_full``: ``positions``
    must be ``P + arange(S)`` and the returned cache entry covers only the
    chunk.  ``enc_kv``: optional already-projected encoder cross-(k, v);
    when given, the ``gqa_encoder_kv`` projection of ``enc_h`` is skipped
    (it does not depend on the decoder offset, so chunked prefill computes
    it once at offset 0 and reuses the cached value after).
    """
    x = apply_norm(params["ln1"], cfg, h)
    a, kv = attn.apply_gqa_full(params["self_attn"], cfg, sh, x, positions,
                                prefix_kv=prefix_kv, backend=backend)
    h = h + a
    x = apply_norm(params["ln_cross"], cfg, h)
    if enc_kv is None:
        ck, cv = attn.gqa_encoder_kv(params["cross_attn"], cfg, sh, enc_h)
    else:
        ck, cv = enc_kv
    a, _ = attn.apply_gqa_full(params["cross_attn"], cfg, sh, x, positions,
                               cross_kv=(ck, cv), backend=backend)
    h = h + a
    x = apply_norm(params["ln2"], cfg, h)
    h = h + apply_mlp(params["ffn"], cfg, sh, x)
    h = sh.act(h, "batch", "seq_act", None)
    cache = {"k": kv[0], "v": kv[1], "ck": ck, "cv": cv}
    return h, cache


def cross_decoder_block_decode(params, cfg: ModelConfig, sh: ShardingCtx, h,
                               cache, pos, enc_len=None,
                               backend: str = "xla"):
    """Single-token cross-decoder block.

    ``enc_len``: optional (traced) number of VALID encoder positions in the
    ``ck``/``cv`` caches — required when they are allocated longer than the
    session's encoder output (the pooled serving path); ``None`` keeps the
    exact-length monolithic behaviour.
    """
    x = apply_norm(params["ln1"], cfg, h)
    a, ck, cv = attn.apply_gqa_decode(
        params["self_attn"], cfg, sh, x, cache["k"], cache["v"], pos,
        backend=backend)
    h = h + a
    x = apply_norm(params["ln_cross"], cfg, h)
    a, _, _ = attn.apply_gqa_decode(
        params["cross_attn"], cfg, sh, x, cache["ck"], cache["cv"], pos,
        cross=True, kv_len=enc_len, backend=backend)
    h = h + a
    x = apply_norm(params["ln2"], cfg, h)
    h = h + apply_mlp(params["ffn"], cfg, sh, x)
    return h, {"k": ck, "v": cv, "ck": cache["ck"], "cv": cache["cv"]}


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 backbone)
# ---------------------------------------------------------------------------


def init_mamba_block(key, cfg: ModelConfig):
    pb = ParamBuilder(key)
    pb.sub("ln", init_norm, cfg)
    pb.sub("mixer", ssm_mod.init_mamba, cfg)
    return pb.build()


def mamba_block_full(params, cfg: ModelConfig, sh: ShardingCtx, h,
                     backend: str = "xla"):
    x = apply_norm(params["ln"], cfg, h)
    y, state = ssm_mod.apply_mamba_full(params["mixer"], cfg, sh, x,
                                        backend=backend)
    return sh.act(h + y, "batch", "seq_act", None), state


def mamba_block_decode(params, cfg: ModelConfig, sh: ShardingCtx, h, state,
                       backend: str = "xla"):
    # single-step recurrence is elementwise — no kernel; ``backend`` is
    # accepted for call-site uniformity and ignored
    x = apply_norm(params["ln"], cfg, h)
    y, state = ssm_mod.apply_mamba_decode(params["mixer"], cfg, sh, x, state)
    return h + y, state


# ---------------------------------------------------------------------------
# Zamba2 shared attention block (params shared across invocations)
# ---------------------------------------------------------------------------


def init_zamba_shared(key, cfg: ModelConfig):
    """Attention+MLP on concat(hidden, embedding0), width 2*d_model."""
    width = 2 * cfg.d_model
    pb = ParamBuilder(key)
    pb.sub("ln1", init_norm, cfg, width)
    pb.sub("attn", attn.init_gqa, cfg, width)
    pb.sub("ln2", init_norm, cfg, width)
    pb.sub("ffn", init_mlp, cfg, width)
    return pb.build()


def zamba_shared_full(params, cfg: ModelConfig, sh: ShardingCtx, h, emb0,
                      positions, backend: str = "xla"):
    """Returns (h, cache_entry) — KV cache per invocation."""
    xc = jnp.concatenate([h, emb0], axis=-1)
    x = apply_norm(params["ln1"], cfg, xc)
    a, kv = attn.apply_gqa_full(params["attn"], cfg, sh, x, positions,
                                backend=backend)
    h = h + a
    xc = jnp.concatenate([h, emb0], axis=-1)
    x = apply_norm(params["ln2"], cfg, xc)
    h = h + apply_mlp(params["ffn"], cfg, sh, x)
    return sh.act(h, "batch", "seq_act", None), {"k": kv[0], "v": kv[1]}


def zamba_shared_decode(params, cfg: ModelConfig, sh: ShardingCtx, h, emb0,
                        cache, pos, backend: str = "xla"):
    xc = jnp.concatenate([h, emb0], axis=-1)
    x = apply_norm(params["ln1"], cfg, xc)
    a, ck, cv = attn.apply_gqa_decode(
        params["attn"], cfg, sh, x, cache["k"], cache["v"], pos,
        backend=backend)
    h = h + a
    xc = jnp.concatenate([h, emb0], axis=-1)
    x = apply_norm(params["ln2"], cfg, xc)
    h = h + apply_mlp(params["ffn"], cfg, sh, x)
    return h, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# RWKV6 block
# ---------------------------------------------------------------------------


def init_rwkv_block(key, cfg: ModelConfig):
    pb = ParamBuilder(key)
    pb.sub("ln1", init_norm, cfg)
    pb.sub("tm", ssm_mod.init_rwkv_tm, cfg)
    pb.sub("ln2", init_norm, cfg)
    pb.sub("cm", ssm_mod.init_rwkv_cm, cfg)
    return pb.build()


def rwkv_block_full(params, cfg: ModelConfig, sh: ShardingCtx, h,
                    backend: str = "xla"):
    x = apply_norm(params["ln1"], cfg, h)
    y, tm_state = ssm_mod.apply_rwkv_tm_full(params["tm"], cfg, sh, x,
                                             backend=backend)
    h = h + y
    x = apply_norm(params["ln2"], cfg, h)
    y, cm_shift = ssm_mod.apply_rwkv_cm(params["cm"], cfg, sh, x)
    h = sh.act(h + y, "batch", "seq_act", None)
    state = {"wkv": tm_state["wkv"], "shift_tm": tm_state["shift"],
             "shift_cm": cm_shift}
    return h, state


def rwkv_block_decode(params, cfg: ModelConfig, sh: ShardingCtx, h, state,
                      backend: str = "xla"):
    # single-step recurrence is elementwise — no kernel; ``backend`` is
    # accepted for call-site uniformity and ignored
    x = apply_norm(params["ln1"], cfg, h)
    y, tm_state = ssm_mod.apply_rwkv_tm_decode(
        params["tm"], cfg, sh, x,
        {"wkv": state["wkv"], "shift": state["shift_tm"]})
    h = h + y
    x = apply_norm(params["ln2"], cfg, h)
    y, cm_shift = ssm_mod.apply_rwkv_cm(params["cm"], cfg, sh, x,
                                        shift_state=state["shift_cm"])
    h = h + y
    state = {"wkv": tm_state["wkv"], "shift_tm": tm_state["shift"],
             "shift_cm": cm_shift}
    return h, state
