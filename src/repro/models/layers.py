"""Foundational layers: sharding context, param init, norms, RoPE/ALiBi, MLP.

Conventions
-----------
* All modules are pure functions: ``init_*(key, cfg) -> (params, axes)`` and
  ``apply_*(params, cfg, sh, ...) -> ...``.
* ``params`` is a nested dict of jnp arrays; ``axes`` mirrors it with tuples of
  *logical axis names* used by the sharding rules (see repro/launch/sharding).
* ``sh`` is a ``ShardingCtx``: ``sh.act(x, *logical_axes)`` applies a
  ``with_sharding_constraint`` when a mesh is active and is the identity
  otherwise, so the same model code runs in smoke tests (1 CPU device) and in
  the 512-device dry-run.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------


class ShardingCtx:
    """Maps logical axis names to mesh axes; no-op without a mesh.

    ``rules`` maps a logical axis name to a mesh axis name, a tuple of mesh
    axis names, or None (replicated).  Unknown logical names replicate.
    """

    def __init__(self, mesh=None, rules: Optional[Dict[str, object]] = None):
        self.mesh = mesh
        self.rules = dict(rules or {})

    def spec(self, *logical: Optional[str]) -> P:
        return P(*[self.rules.get(a) if a else None for a in logical])

    def act(self, x, *logical: Optional[str]):
        """Constrain an activation's sharding (identity without a mesh)."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical))
        )

    def named_sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def param_shardings(self, axes_tree):
        """NamedSharding pytree for a params tree given its axes tree."""
        if self.mesh is None:
            return None
        return jax.tree.map(
            lambda ax: NamedSharding(self.mesh, self.spec(*ax)),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple),
        )


NULL_SH = ShardingCtx()


# ---------------------------------------------------------------------------
# Param creation
# ---------------------------------------------------------------------------


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape: Sequence[int], axes: Tuple[str, ...], dtype,
               scale: Optional[float] = None):
    """Truncated-normal init with 1/sqrt(fan_in) default scale."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(max(1, fan_in))
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    return w.astype(dtype), tuple(axes)


class ParamBuilder:
    """Collects (params, axes) pairs keyed by name with split PRNG keys."""

    def __init__(self, key):
        self.key = key
        self.params: Dict[str, object] = {}
        self.axes: Dict[str, object] = {}

    def _next(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    def dense(self, name, shape, axes, dtype, scale=None):
        w, ax = dense_init(self._next(), shape, axes, dtype, scale)
        self.params[name] = w
        self.axes[name] = ax

    def zeros(self, name, shape, axes, dtype):
        self.params[name] = jnp.zeros(shape, dtype)
        self.axes[name] = tuple(axes)

    def ones(self, name, shape, axes, dtype):
        self.params[name] = jnp.ones(shape, dtype)
        self.axes[name] = tuple(axes)

    def const(self, name, value, axes):
        self.params[name] = value
        self.axes[name] = tuple(axes)

    def sub(self, name, init_fn, *args, **kw):
        p, a = init_fn(self._next(), *args, **kw)
        self.params[name] = p
        self.axes[name] = a

    def build(self):
        return self.params, self.axes


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def init_norm(key, cfg: ModelConfig, width: Optional[int] = None):
    d = width or cfg.d_model
    pb = ParamBuilder(key)
    if cfg.norm_kind == "rmsnorm":
        pb.ones("scale", (d,), ("embed_nosplit",), jnp.float32)
    elif cfg.norm_kind == "layernorm":
        pb.ones("scale", (d,), ("embed_nosplit",), jnp.float32)
        pb.zeros("bias", (d,), ("embed_nosplit",), jnp.float32)
    # nonparametric: no params
    return pb.build()


def apply_norm(params, cfg: ModelConfig, x):
    """Normalisation with f32 *statistics* but element ops in x.dtype —
    avoids materialising full-width f32 copies of the residual stream
    (matters on backends with weak elementwise fusion; DESIGN.md §6)."""
    d = x.shape[-1]
    if cfg.norm_kind == "rmsnorm":
        ss = jnp.einsum("...d,...d->...", x, x,
                        preferred_element_type=jnp.float32)
        inv = jax.lax.rsqrt(ss / d + cfg.norm_eps)
        return x * inv[..., None].astype(x.dtype) \
            * params["scale"].astype(x.dtype)
    mean = (jnp.einsum("...d->...", x, preferred_element_type=jnp.float32)
            / d)
    centered = x - mean[..., None].astype(x.dtype)
    var = jnp.einsum("...d,...d->...", centered, centered,
                     preferred_element_type=jnp.float32) / d
    out = centered * jax.lax.rsqrt(var + cfg.norm_eps)[..., None].astype(x.dtype)
    if cfg.norm_kind == "layernorm":
        out = out * params["scale"].astype(x.dtype) \
            + params["bias"].astype(x.dtype)
    return out


def rms_norm_simple(x, scale, eps=1e-6):
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss / x.shape[-1] + eps)
    return x * inv[..., None].astype(x.dtype) * scale.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary / ALiBi positions
# ---------------------------------------------------------------------------


def rope_angles(positions, dim: int, theta: float):
    """cos/sin tables for ``positions`` (any shape), rotating ``dim`` dims."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., dim/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, D); cos/sin: (..., S, D/2) broadcast over heads.

    Tables are built in f32 (phase accuracy at long positions) but the
    rotation itself runs in x.dtype to avoid f32 copies of q/k."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def alibi_slopes(n_heads: int):
    """Standard ALiBi geometric slopes (BLOOM)."""
    p = 2 ** int(np.floor(np.log2(n_heads)))
    base = 2.0 ** (-8.0 / p)
    slopes = base ** np.arange(1, p + 1)
    if p < n_heads:
        extra_base = 2.0 ** (-4.0 / p)
        extra = extra_base ** np.arange(1, 2 * (n_heads - p) + 1, 2)
        slopes = np.concatenate([slopes, extra])
    return jnp.asarray(slopes, jnp.float32)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig):
    pb = ParamBuilder(key)
    pb.dense("tok", (cfg.padded_vocab, cfg.d_model), ("vocab", "embed_nosplit"),
             _dtype(cfg), scale=1.0)
    if cfg.frontend == "frames":
        pb.dense("frame_proj", (cfg.frame_dim, cfg.d_model),
                 ("frame", "embed_nosplit"), _dtype(cfg))
    if not cfg.tie_embeddings:
        pb.dense("head", (cfg.d_model, cfg.padded_vocab),
                 ("embed_fsdp", "vocab"), _dtype(cfg))
    pb.sub("final_norm", init_norm, cfg)
    return pb.build()


def embed_tokens(params, cfg: ModelConfig, sh: ShardingCtx, tokens):
    out = jnp.take(params["tok"], tokens, axis=0)
    return sh.act(out, "batch", "seq", None)


def embed_frames(params, cfg: ModelConfig, sh: ShardingCtx, frames):
    out = frames.astype(_dtype(cfg)) @ params["frame_proj"]
    return sh.act(out, "batch", "seq", None)


def lm_head(params, cfg: ModelConfig, sh: ShardingCtx, h):
    h = apply_norm(params["final_norm"], cfg, h)
    w = params["tok"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("...d,dv->...v", h, w.astype(h.dtype))
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    pad = vocab_pad_bias(cfg)
    if pad is not None:
        logits = logits + pad.astype(logits.dtype)
    return sh.act(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Gated / plain MLP
# ---------------------------------------------------------------------------


def vocab_pad_bias(cfg: ModelConfig):
    """Additive bias masking padded vocab columns out of softmax/CE."""
    if cfg.padded_vocab == cfg.vocab_size:
        return None
    idx = jnp.arange(cfg.padded_vocab)
    return jnp.where(idx < cfg.vocab_size, 0.0, -1e30).astype(jnp.float32)


def init_mlp(key, cfg: ModelConfig, width: Optional[int] = None,
             d_ff: Optional[int] = None):
    d = width or cfg.d_model
    f = d_ff or cfg.d_ff
    pb = ParamBuilder(key)
    dt = _dtype(cfg)
    if cfg.norm_kind == "layernorm":  # plain gelu MLP (bloom / seamless style)
        pb.dense("wi", (d, f), ("embed_fsdp", "mlp"), dt)
        pb.dense("wo", (f, cfg.d_model), ("mlp", "embed_fsdp"), dt)
    else:  # gated silu
        pb.dense("wg", (d, f), ("embed_fsdp", "mlp"), dt)
        pb.dense("wu", (d, f), ("embed_fsdp", "mlp"), dt)
        pb.dense("wo", (f, cfg.d_model), ("mlp", "embed_fsdp"), dt)
    return pb.build()


def apply_mlp(params, cfg: ModelConfig, sh: ShardingCtx, x):
    if "wi" in params:
        h = jax.nn.gelu(x @ params["wi"].astype(x.dtype))
        h = sh.act(h, "batch", "seq", "mlp_act")
        return h @ params["wo"].astype(x.dtype)
    g = jax.nn.silu(x @ params["wg"].astype(x.dtype))
    u = x @ params["wu"].astype(x.dtype)
    h = sh.act(g * u, "batch", "seq", "mlp_act")
    return h @ params["wo"].astype(x.dtype)
