"""State-space sequence mixers: Mamba2 (SSD) and RWKV6 (Finch) time/channel mix.

Both use *chunked* formulations: dense intra-chunk math + a log-depth
``jax.lax.associative_scan`` over per-chunk states.  No ``lax.scan`` /
``while`` appears in the full-sequence path, keeping XLA ``cost_analysis``
FLOP counts exact (scan bodies are counted once — DESIGN.md §6) and avoiding
O(S·state) memory.

Decode paths are single-step recurrences over carried state, mirroring what
the Pallas kernels in ``repro.kernels.{ssd,wkv6}`` implement for real TPUs.

State-dict key names are a SERVING CONTRACT: the geo engine's state pools
(``repro.serving.kv_cache``) dispatch writes by leaf name — ``k``/``v``
(and MLA ``latent``/``krope``) are length-indexed and written per chunk,
everything else (``ssm``, ``conv``, ``wkv``, ``shift*``) is recurrent and
overwritten whole.  Renaming a key here silently changes pool semantics;
keep names out of the length-indexed set unless the leaf really has a time
axis.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.ssd import ssd as _ssd_kernel
from repro.kernels.ssd import ssd_unsupported
from repro.kernels.wkv6 import wkv6 as _wkv6_kernel
from repro.kernels.wkv6 import wkv6_unsupported
from repro.models.layers import ParamBuilder, ShardingCtx, rms_norm_simple

MAMBA_CHUNK = 256
RWKV_CHUNK = 16
# Per-step log-decay clamp for RWKV6 (numerical-stability bound for the
# factored intra-chunk form; mirrored exactly by kernels/wkv6/ref.py).
RWKV_MIN_LOG_W = -5.0


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _pad_to(x, mult: int, axis: int):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, s
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), s


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================


def init_mamba(key, cfg: ModelConfig):
    """Projections are separate weights (not one fused in_proj) so each output
    dim shards cleanly under TP without re-shard at the split boundaries."""
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    conv_dim = di + 2 * n
    dt = _dt(cfg)
    pb = ParamBuilder(key)
    pb.dense("wz", (d, di), ("embed_fsdp", "inner"), dt)
    pb.dense("wx", (d, di), ("embed_fsdp", "inner"), dt)
    pb.dense("wB", (d, n), ("embed_fsdp", "state_nosplit"), dt)
    pb.dense("wC", (d, n), ("embed_fsdp", "state_nosplit"), dt)
    pb.dense("wdt", (d, h), ("embed_fsdp", "ssm_heads"), dt)
    pb.dense("conv_w", (w, conv_dim), ("conv", "inner_nosplit"), dt, scale=0.5)
    pb.zeros("conv_b", (conv_dim,), ("inner_nosplit",), dt)
    pb.const("dt_bias", jnp.zeros((h,), jnp.float32), ("ssm_heads",))
    pb.const("A_log", jnp.log(jnp.linspace(1.0, 16.0, h)), ("ssm_heads",))
    pb.zeros("D", (h,), ("ssm_heads",), jnp.float32)
    pb.ones("norm", (di,), ("inner_nosplit",), jnp.float32)
    pb.dense("out_proj", (di, d), ("inner", "embed_fsdp"), dt)
    return pb.build()


def _mamba_inputs(params, cfg: ModelConfig, x):
    """Shared projections for prefill and decode.

    Returns (z, (xc, B, C) pre-conv pieces, dt_raw).  The depthwise conv is
    applied per piece (it never mixes channels) so the TP sharding of xc
    ("inner" -> model axis) survives without a re-shard at split boundaries.
    """
    z = x @ params["wz"].astype(x.dtype)
    xc = x @ params["wx"].astype(x.dtype)
    Bp = x @ params["wB"].astype(x.dtype)
    Cp = x @ params["wC"].astype(x.dtype)
    dt_raw = x @ params["wdt"].astype(x.dtype)
    return z, (xc, Bp, Cp), dt_raw


def _conv_slices(params, cfg: ModelConfig):
    di, n = cfg.d_inner, cfg.ssm_state
    w, b = params["conv_w"], params["conv_b"]
    return ((w[:, :di], b[:di]), (w[:, di: di + n], b[di: di + n]),
            (w[:, di + n:], b[di + n:]))


def _mamba_post(params, cfg: ModelConfig, y, z):
    """Gated RMSNorm + output projection.  y/z: (..., d_inner)."""
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    g = rms_norm_simple(g, params["norm"], cfg.norm_eps)
    return g @ params["out_proj"].astype(g.dtype)


def apply_mamba_full(params, cfg: ModelConfig, sh: ShardingCtx, x,
                     backend: str = "xla"):
    """Full-sequence Mamba2.  x (B,S,d) -> (y (B,S,d), state dict).

    state = {"ssm": (B,h,p,n) f32, "conv": (B, w-1, d_inner+2n)}.
    ``backend``: "xla" runs the chunked jnp scan below; "pallas" runs the
    ``repro.kernels.ssd`` kernel (carried state out) for the scan itself —
    projections/conv/gating stay jnp either way.
    """
    B, S, _ = x.shape
    di, n, h, w = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    p = cfg.ssm_head_dim
    z, pieces, dt_raw = _mamba_inputs(params, cfg, x)

    # causal depthwise conv (width w) applied per piece — preserves sharding
    def conv1d(piece, cw, cb):
        pad = jnp.pad(piece, ((0, 0), (w - 1, 0), (0, 0)))
        out = sum(pad[:, i: i + S] * cw[i].astype(x.dtype) for i in range(w))
        return jax.nn.silu(out + cb.astype(x.dtype)), pad[:, S:]

    tails = []
    convs = []
    for piece, (cw, cb) in zip(pieces, _conv_slices(params, cfg)):
        out, tail = conv1d(piece, cw, cb)
        convs.append(out)
        tails.append(tail)
    xc, Bm, Cm = convs[0], convs[1].astype(jnp.float32), convs[2].astype(jnp.float32)
    conv_tail = jnp.concatenate(tails, axis=-1)  # (B, w-1, di+2n) decode carry
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,h)
    A = -jnp.exp(params["A_log"])  # (h,) negative

    xh = xc.reshape(B, S, h, p).astype(jnp.float32)
    if backend == "pallas" and ssd_unsupported() is None:
        y, ssm_state = _ssd_kernel(xh, Bm, Cm, dtv, A, params["D"])
        y = y.reshape(B, S, di).astype(x.dtype)
        y = sh.act(y, "batch", "seq", "inner_act")
        out = _mamba_post(params, cfg, y, z)
        return out, {"ssm": ssm_state, "conv": conv_tail.astype(jnp.float32)}
    # ---- chunked SSD ----
    Q = min(MAMBA_CHUNK, max(16, S))
    xh, S0 = _pad_to(xh, Q, 1)
    Bm, _ = _pad_to(Bm, Q, 1)
    Cm, _ = _pad_to(Cm, Q, 1)
    dtv, _ = _pad_to(dtv, Q, 1)
    Sp = xh.shape[1]
    nc = Sp // Q
    xh = xh.reshape(B, nc, Q, h, p)
    Bm = Bm.reshape(B, nc, Q, n)
    Cm = Cm.reshape(B, nc, Q, n)
    dtv = dtv.reshape(B, nc, Q, h)

    la = dtv * A  # (B,nc,Q,h) log-decay per step, <= 0
    seg = jnp.cumsum(la, axis=2)  # inclusive
    # intra-chunk:  Y[t] = sum_{i<=t} exp(seg[t]-seg[i]) * (C[t]·B[i]) dt[i] x[i]
    G = jnp.einsum("bcqn,bckn->bcqk", Cm, Bm)  # (B,nc,Q,Q)
    # clamp masked (upper-triangle) exponents to <= 0: they are discarded by
    # the mask, but exp(+big)=inf would poison the VJP (0 * inf = NaN)
    diff = jnp.minimum(seg[:, :, :, None, :] - seg[:, :, None, :, :], 0.0)
    decay = jnp.exp(diff)  # (B,nc,Q,Q,h)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    M = jnp.where(mask[None, None, :, :, None], G[..., None] * decay, 0.0)
    xb = xh * dtv[..., None]  # dt-weighted inputs
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xb)
    # chunk-local end states and decays
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)  # (B,nc,Q,h)
    S_local = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", decay_to_end * dtv, xh, Bm)
    A_chunk = jnp.exp(seg[:, :, -1, :])  # (B,nc,h)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2[..., None, None] * s1 + s2

    A_sc, S_sc = jax.lax.associative_scan(combine, (A_chunk, S_local), axis=1)
    # chunk-start states: shifted inclusive scan (zeros for the first chunk)
    S_start = jnp.concatenate(
        [jnp.zeros_like(S_sc[:, :1]), S_sc[:, :-1]], axis=1)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cm, S_start, jnp.exp(seg))

    y = (y_intra + y_inter).reshape(B, Sp, h, p)[:, :S0]
    y = y + xh.reshape(B, Sp, h, p)[:, :S0] * params["D"][None, None, :, None]
    y = y.reshape(B, S0, di).astype(x.dtype)
    y = sh.act(y, "batch", "seq", "inner_act")
    out = _mamba_post(params, cfg, y, z[:, :S0])

    state = {"ssm": S_sc[:, -1], "conv": conv_tail.astype(jnp.float32)}
    return out, state


def apply_mamba_decode(params, cfg: ModelConfig, sh: ShardingCtx, x, state):
    """Single-token Mamba2 step.  x (B,1,d) -> (y (B,1,d), new state)."""
    B = x.shape[0]
    di, n, h, w = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    p = cfg.ssm_head_dim
    z, pieces, dt_raw = _mamba_inputs(params, cfg, x)
    conv_in = jnp.concatenate(pieces, axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate(
        [state["conv"].astype(x.dtype), conv_in], axis=1)  # (B,w,conv_dim)
    conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"].astype(x.dtype))
    conv = jax.nn.silu(conv + params["conv_b"].astype(x.dtype))  # (B,conv_dim)
    new_conv_state = window[:, 1:].astype(jnp.float32)

    xc = conv[:, :di].reshape(B, h, p).astype(jnp.float32)
    Bm = conv[:, di: di + n].astype(jnp.float32)
    Cm = conv[:, di + n:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dtv * A)  # (B,h)
    s = state["ssm"] * a[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xc, Bm)
    y = jnp.einsum("bn,bhpn->bhp", Cm, s) + xc * params["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    out = _mamba_post(params, cfg, y, z)
    return out, {"ssm": s, "conv": new_conv_state}


# ===========================================================================
# RWKV6 (Finch)
# ===========================================================================

_TM_LORA = 32
_DECAY_LORA = 64
_N_MIX = 5  # w, k, v, r, g


def init_rwkv_tm(key, cfg: ModelConfig):
    d = cfg.d_model
    h, hd = cfg.ssm_heads, cfg.ssm_head_dim
    dt = _dt(cfg)
    pb = ParamBuilder(key)
    pb.zeros("mu_x", (d,), ("embed_nosplit",), jnp.float32)
    pb.zeros("mu", (_N_MIX, d), ("mix", "embed_nosplit"), jnp.float32)
    pb.dense("mix_A", (d, _N_MIX * _TM_LORA), ("embed_nosplit", "lora"), jnp.float32)
    pb.dense("mix_B", (_N_MIX, _TM_LORA, d), ("mix", "lora", "embed_nosplit"),
             jnp.float32, scale=0.1)
    pb.dense("wr", (d, d), ("embed_fsdp", "heads_x_dim"), dt)
    pb.dense("wk", (d, d), ("embed_fsdp", "heads_x_dim"), dt)
    pb.dense("wv", (d, d), ("embed_fsdp", "heads_x_dim"), dt)
    pb.dense("wg", (d, d), ("embed_fsdp", "heads_x_dim"), dt)
    pb.const("w0", jnp.full((d,), -1.0, jnp.float32), ("embed_nosplit",))
    pb.dense("w_A", (d, _DECAY_LORA), ("embed_nosplit", "lora"), jnp.float32)
    pb.dense("w_B", (_DECAY_LORA, d), ("lora", "embed_nosplit"), jnp.float32,
             scale=0.1)
    pb.const("u", jnp.zeros((h, hd), jnp.float32), ("ssm_heads", "ssm_dim"))
    pb.ones("out_norm", (d,), ("embed_nosplit",), jnp.float32)
    pb.dense("wo", (d, d), ("heads_x_dim", "embed_fsdp"), dt)
    return pb.build()


def _rwkv_mix(params, x, sx):
    """Data-dependent token-shift interpolation (ddlerp) for w,k,v,r,g.

    x, sx: (B,S,d).  Returns 5 mixed tensors (B,S,d) in order w,k,v,r,g.
    """
    dx = (sx - x).astype(jnp.float32)
    xx = x.astype(jnp.float32) + dx * params["mu_x"]
    lo = jnp.tanh(xx @ params["mix_A"])  # (B,S,5*lora)
    lo = lo.reshape(*lo.shape[:-1], _N_MIX, _TM_LORA)
    delta = jnp.einsum("bsml,mld->msbd", lo, params["mix_B"]).transpose(0, 2, 1, 3)
    # delta: (5, B, S, d)
    outs = []
    for i in range(_N_MIX):
        mix = params["mu"][i] + delta[i]
        outs.append((x.astype(jnp.float32) + dx * mix).astype(x.dtype))
    return outs


def _rwkv_decay(params, xw):
    """Per-channel log-decay log(w_t) <= 0 with the stability clamp."""
    omega = params["w0"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["w_A"]) @ params["w_B"]
    return jnp.clip(-jnp.exp(omega), RWKV_MIN_LOG_W, -1e-4)


def apply_rwkv_tm_full(params, cfg: ModelConfig, sh: ShardingCtx, x,
                       backend: str = "xla"):
    """Full-sequence RWKV6 time-mix.  x (B,S,d) -> (y, state dict).

    state = {"wkv": (B,h,hd,hd) f32, "shift": (B,d)} — last-token carry.
    ``backend``: "xla" runs ``_wkv6_chunked`` below; "pallas" runs the
    ``repro.kernels.wkv6`` kernel (carried state out) for the recurrence —
    mixing/decay/gating stay jnp either way.
    """
    B, S, d = x.shape
    h, hd = cfg.ssm_heads, cfg.ssm_head_dim
    sx = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _rwkv_mix(params, x, sx)
    r = (xr @ params["wr"].astype(x.dtype)).reshape(B, S, h, hd)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(B, S, h, hd)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(B, S, h, hd)
    g = jax.nn.silu((xg @ params["wg"].astype(x.dtype)).astype(jnp.float32))
    lw = _rwkv_decay(params, xw).reshape(B, S, h, hd)  # log decay per channel

    if backend == "pallas" and wkv6_unsupported() is None:
        y, wkv_state = _wkv6_kernel(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), lw, params["u"])
    else:
        y, wkv_state = _wkv6_chunked(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), lw, params["u"])
    # per-head group-norm then gate
    y = y.reshape(B, S, d)
    y = rms_norm_simple(
        y.reshape(B, S, h, hd), jnp.ones((hd,), jnp.float32), cfg.norm_eps
    ).reshape(B, S, d).astype(jnp.float32)
    y = (y * params["out_norm"] * g).astype(x.dtype)
    out = y @ params["wo"].astype(x.dtype)
    state = {"wkv": wkv_state, "shift": x[:, -1].astype(jnp.float32)}
    return out, state


def _wkv6_chunked(r, k, v, lw, u):
    """Chunked WKV6: out_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t.   All args f32.

    r,k,v,lw: (B,S,h,hd);  u: (h,hd).  Returns (out (B,S,h,hd), S (B,h,hd,hd)).
    Intra-chunk decays use an explicit masked (Q,Q) tensor per channel —
    numerically safe for any clamped lw (DESIGN.md §5).
    """
    B, S, h, hd = r.shape
    Q = min(RWKV_CHUNK, max(4, S))
    r, S0 = _pad_to(r, Q, 1)
    k, _ = _pad_to(k, Q, 1)
    v, _ = _pad_to(v, Q, 1)
    lw, _ = _pad_to(lw, Q, 1)
    Sp = r.shape[1]
    nc = Sp // Q
    rc = r.reshape(B, nc, Q, h, hd)
    kc = k.reshape(B, nc, Q, h, hd)
    vc = v.reshape(B, nc, Q, h, hd)
    lwc = lw.reshape(B, nc, Q, h, hd)

    seg = jnp.cumsum(lwc, axis=2)  # inclusive within chunk
    segx = seg - lwc  # exclusive
    # intra-chunk: out[t] += sum_{i<t} (r_t ⊙ exp(segx_t - seg_i)) · k_i) v_i
    # (exponents clamped to <= 0: masked entries would otherwise be inf and
    # poison the VJP of the mask's where)
    decay = jnp.exp(jnp.minimum(
        segx[:, :, :, None] - seg[:, :, None, :, :], 0.0))  # (B,nc,Q,Q,h,hd)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    decay = jnp.where(mask[None, None, :, :, None, None], decay, 0.0)
    Amat = jnp.einsum("bcthd,bcihd,bctihd->bcthi", rc, kc, decay)
    y_intra = jnp.einsum("bcthi,bcihd->bcthd", Amat, vc)
    # current-token bonus:  (r_t · (u ⊙ k_t)) v_t
    bonus = jnp.einsum("bcthd,hd,bcthd->bcth", rc, u, kc)
    y_intra = y_intra + bonus[..., None] * vc
    # inter-chunk: out[t] += (r_t ⊙ exp(segx_t)) · S_chunk_start
    decay_to_end = jnp.exp(seg[:, :, -1:] - seg)  # (B,nc,Q,h,hd)
    S_local = jnp.einsum("bcihd,bcihe->bchde", kc * decay_to_end, vc)
    A_chunk = jnp.exp(seg[:, :, -1])  # (B,nc,h,hd)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, a2[..., None] * s1 + s2

    A_sc, S_sc = jax.lax.associative_scan(combine, (A_chunk, S_local), axis=1)
    S_start = jnp.concatenate(
        [jnp.zeros_like(S_sc[:, :1]), S_sc[:, :-1]], axis=1)
    y_inter = jnp.einsum("bcthd,bchde->bcthe", rc * jnp.exp(segx), S_start)

    out = (y_intra + y_inter).reshape(B, Sp, h, hd)[:, :S0]
    return out, S_sc[:, -1]


def apply_rwkv_tm_decode(params, cfg: ModelConfig, sh: ShardingCtx, x, state):
    """Single-token RWKV6 time-mix.  x (B,1,d)."""
    B, _, d = x.shape
    h, hd = cfg.ssm_heads, cfg.ssm_head_dim
    sx = state["shift"].astype(x.dtype)[:, None]
    xw, xk, xv, xr, xg = _rwkv_mix(params, x, sx)
    r = (xr @ params["wr"].astype(x.dtype)).reshape(B, h, hd).astype(jnp.float32)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(B, h, hd).astype(jnp.float32)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(B, h, hd).astype(jnp.float32)
    g = jax.nn.silu((xg @ params["wg"].astype(x.dtype)).astype(jnp.float32))
    lw = _rwkv_decay(params, xw).reshape(B, h, hd)

    S = state["wkv"]  # (B,h,hd,hd)
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    out = jnp.einsum("bhd,bhde->bhe", r, S + params["u"][None, ..., None] * kv)
    new_S = jnp.exp(lw)[..., None] * S + kv
    y = out.reshape(B, 1, d)
    y = rms_norm_simple(
        y.reshape(B, 1, h, hd), jnp.ones((hd,), jnp.float32), cfg.norm_eps
    ).reshape(B, 1, d).astype(jnp.float32)
    y = (y * params["out_norm"] * g).astype(x.dtype)
    out = y @ params["wo"].astype(x.dtype)
    return out, {"wkv": new_S, "shift": x[:, 0].astype(jnp.float32)}


def init_rwkv_cm(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    dt = _dt(cfg)
    pb = ParamBuilder(key)
    pb.zeros("mu_k", (d,), ("embed_nosplit",), jnp.float32)
    pb.zeros("mu_r", (d,), ("embed_nosplit",), jnp.float32)
    pb.dense("wk", (d, f), ("embed_fsdp", "mlp"), dt)
    pb.dense("wv", (f, d), ("mlp", "embed_fsdp"), dt)
    pb.dense("wr", (d, d), ("embed_fsdp", "embed_nosplit"), dt)
    return pb.build()


def apply_rwkv_cm(params, cfg: ModelConfig, sh: ShardingCtx, x, shift_state=None):
    """RWKV6 channel-mix.  Full-seq if shift_state is None (returns state)."""
    if shift_state is None:
        sx = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
        new_state = x[:, -1].astype(jnp.float32)
    else:
        sx = shift_state.astype(x.dtype)[:, None]
        new_state = x[:, 0].astype(jnp.float32)
    dx = sx - x
    xk = x + dx * params["mu_k"].astype(x.dtype)
    xr = x + dx * params["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ params["wk"].astype(x.dtype)))
    kk = sh.act(kk, "batch", "seq", "mlp_act")
    kv = kk @ params["wv"].astype(x.dtype)
    r = jax.nn.sigmoid((xr @ params["wr"].astype(x.dtype)).astype(jnp.float32))
    return (r * kv.astype(jnp.float32)).astype(x.dtype), new_state
