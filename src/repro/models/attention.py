"""Attention: GQA (bias/qk-norm/sliding-window/ALiBi) and MLA (DeepSeek-V2).

Three compute paths, chosen statically from sequence length:

* ``dense``   — materialise (S, T) logits; used for S*T small (train_4k).
* ``flash``   — double python-loop over (q-chunk, kv-chunk) pairs with online
                softmax, skipping fully-masked upper-triangle pairs.  Unrolled
                (no ``lax.scan``) so XLA ``cost_analysis`` FLOP counts stay
                exact (scan bodies are counted once, see DESIGN.md §6) and
                peak memory stays O(chunk * chunk).
* ``decode``  — single-query attention over a KV cache (grouped einsum, no KV
                head expansion).

The serving engine selects between two COMPUTE BACKENDS per attention call
(threaded from ``serving.GeoServingSystem(backend=...)`` down through the
block functions): ``backend="xla"`` runs the paths above (the oracle — and
the dry-run lowering path: Pallas kernels cannot lower to the CPU backend
used by the 512-device dry-run), ``backend="pallas"`` dispatches to the
kernels in ``repro.kernels`` (interpret mode off-TPU, Mosaic on real TPUs)
whenever the kernels' ``*_unsupported`` predicates accept the call's
feature set, and falls back to the XLA path otherwise — a kernel gap can
cost performance, never correctness.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import (
    decode_attention,
    decode_attention_unsupported,
    flash_attention,
    flash_attention_unsupported,
)
from repro.kernels.runtime import NO_WINDOW
from repro.models.layers import (
    ParamBuilder,
    ShardingCtx,
    alibi_slopes,
    apply_rope,
    rope_angles,
    rms_norm_simple,
)

_NEG_INF = -1e30
_BIG_WINDOW = NO_WINDOW  # "no window" — shared sentinel, kernels/runtime.py
Q_CHUNK = 2048
KV_CHUNK = 1024
DENSE_MAX_T = 2048  # use the dense path when kv length <= this


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Mask / bias
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, kv_pos, window, slopes=None, causal=True):
    """Additive f32 bias (H|1, S, T): causal + sliding window + optional ALiBi.

    ``window`` may be a traced scalar (data-dependent local/global layers).
    """
    diff = q_pos[:, None] - kv_pos[None, :]  # (S, T); >= 0 means past/self
    if causal:
        ok = (diff >= 0) & (diff < window)
    else:
        ok = jnp.ones_like(diff, dtype=bool)
    bias = jnp.where(ok, 0.0, _NEG_INF).astype(jnp.float32)[None]  # (1,S,T)
    if slopes is not None:
        bias = bias + slopes[:, None, None] * (-jnp.abs(diff))[None].astype(jnp.float32)
    return bias


# ---------------------------------------------------------------------------
# Core softmax-attention on (B, S, H, D) with expanded KV heads
# ---------------------------------------------------------------------------


def _dense_attn(q, k, v, bias):
    """q (B,S,H,D), k (B,T,H,D), v (B,T,H,Dv), bias (H|1,S,T) -> (B,S,H,Dv)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bshd,bthd->bhst", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale + bias[None]
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs.astype(v.dtype), v)


def _flash_attn(q, k, v, q_pos, kv_pos, window, slopes=None, causal=True,
                q_start=0):
    """Double-chunked online-softmax attention (unrolled; no scan).

    (q-chunk, kv-chunk) pairs that are *statically* above the causal diagonal
    are skipped entirely — halving FLOPs vs dense-then-mask.  Safe with a
    traced ``window`` (a window only masks more, never less, than causal).
    Assumes q_pos/kv_pos are aligned aranges when ``causal`` (self-attention),
    with queries offset by the static ``q_start`` (chunked prefill: queries
    [q_start, q_start+S) attend over keys [0, q_start+S)).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    scale = 1.0 / np.sqrt(D)
    n_q = (S + Q_CHUNK - 1) // Q_CHUNK
    n_kv = (T + KV_CHUNK - 1) // KV_CHUNK
    outs = []
    dep = None  # forces sequential q-chunk scheduling (bounds peak memory)
    for qi in range(n_q):
        q_lo, q_hi = qi * Q_CHUNK, min(S, (qi + 1) * Q_CHUNK)
        qc = q[:, q_lo:q_hi]
        if dep is not None:
            # optimization_barrier ties this chunk's inputs to the previous
            # chunk's output so XLA cannot interleave all chains at once
            # (each chain holds an O(chunk*chunk) f32 logits block).
            qc, _ = jax.lax.optimization_barrier((qc, dep))
        qp = q_pos[q_lo:q_hi]
        m = jnp.full((B, H, q_hi - q_lo), _NEG_INF, jnp.float32)
        l = jnp.zeros((B, H, q_hi - q_lo), jnp.float32)
        acc = jnp.zeros((B, q_hi - q_lo, H, Dv), jnp.float32)
        for ki in range(n_kv):
            k_lo, k_hi = ki * KV_CHUNK, min(T, (ki + 1) * KV_CHUNK)
            if causal and k_lo > q_start + q_hi - 1:
                continue  # statically above the causal diagonal
            kc, vc = k[:, k_lo:k_hi], v[:, k_lo:k_hi]
            kp = kv_pos[k_lo:k_hi]
            logits = jnp.einsum(
                "bshd,bthd->bhst", qc, kc, preferred_element_type=jnp.float32
            ) * scale
            logits = logits + _mask_bias(qp, kp, window, slopes, causal)[None]
            blk_max = jnp.max(logits, axis=-1)
            new_m = jnp.maximum(m, blk_max)
            corr = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhst,bthd->bshd", p.astype(v.dtype), vc
            ).astype(jnp.float32)
            m = new_m
        out = acc / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
        out = out.astype(q.dtype)
        dep = out
        outs.append(out)
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def attention_core(q, k, v, q_pos, kv_pos, window=None, slopes=None,
                   causal=True, q_start=0):
    """Dispatch dense vs flash based on static shapes.

    ``q_start`` (static) is the absolute position of the first query — only
    used by the flash path's static causal-skip when queries are a suffix of
    the key range (chunked prefill); the mask itself is always positional.
    """
    window = _BIG_WINDOW if window is None else window
    S, T = q.shape[1], k.shape[1]
    if T <= DENSE_MAX_T and S * T <= DENSE_MAX_T * DENSE_MAX_T // 4:
        bias = _mask_bias(q_pos, kv_pos, window, slopes, causal)
        return _dense_attn(q, k, v, bias)
    return _flash_attn(q, k, v, q_pos, kv_pos, window, slopes, causal,
                       q_start)


def _use_pallas_flash(backend: str, *, causal=True, window=None, slopes=None,
                      q_start: int = 0) -> bool:
    """Dispatch predicate for full-sequence attention: the Pallas flash
    kernel serves the call iff the backend asks for it AND the kernel's own
    guard accepts the feature set (otherwise the XLA path is the
    fallback — same numbers, no silent mishandling)."""
    return (backend == "pallas"
            and flash_attention_unsupported(
                causal=causal, window=window, slopes=slopes,
                q_start=q_start) is None)


def _use_pallas_decode(backend: str, *, causal=True, window=None,
                       slopes=None, kv_len=None, scale=None) -> bool:
    """Dispatch predicate for single-token decode attention (see
    :func:`_use_pallas_flash`)."""
    return (backend == "pallas"
            and decode_attention_unsupported(
                causal=causal, window=window, slopes=slopes, kv_len=kv_len,
                scale=scale) is None)


def decode_attention_xla(q, ck, cv, pos, window=None, slopes=None,
                         causal=True, kv_len=None):
    """Single-step attention over a cache without KV-head expansion.

    q (B,1,H,D); ck (B,T,Kv,D); cv (B,T,Kv,Dv); pos: current position scalar.
    ``kv_len``: optional (traced) count of valid cache positions — masks
    ``kv_pos >= kv_len``.  Needed by non-causal (cross) attention when the
    cache is allocated longer than the valid prefix; causal attention is
    already masked by ``pos``.
    """
    B, _, H, D = q.shape
    T, Kv = ck.shape[1], ck.shape[2]
    G = H // Kv
    window = _BIG_WINDOW if window is None else window
    qg = q.reshape(B, Kv, G, D)
    scale = 1.0 / np.sqrt(D)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, ck,
                        preferred_element_type=jnp.float32) * scale
    kv_pos = jnp.arange(T)
    diff = pos - kv_pos
    ok = ((diff >= 0) & (diff < window)) if causal else jnp.ones((T,), bool)
    if kv_len is not None:
        ok = ok & (kv_pos < kv_len)
    if slopes is not None:
        logits = logits + (slopes.reshape(Kv, G)[None, :, :, None]
                           * (-jnp.abs(diff))[None, None, None, :])
    logits = jnp.where(ok[None, None, None, :], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs.astype(cv.dtype), cv)
    return out.reshape(B, 1, H, cv.shape[-1])


# ---------------------------------------------------------------------------
# GQA attention module
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, width: Optional[int] = None):
    d = width or cfg.d_model
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = _dt(cfg)
    pb = ParamBuilder(key)
    pb.dense("wq", (d, H, hd), ("embed_fsdp", "heads", "head_dim"), dt)
    pb.dense("wk", (d, Kv, hd), ("embed_fsdp", "kv_heads", "head_dim"), dt)
    pb.dense("wv", (d, Kv, hd), ("embed_fsdp", "kv_heads", "head_dim"), dt)
    pb.dense("wo", (H, hd, cfg.d_model), ("heads", "head_dim", "embed_fsdp"), dt)
    if cfg.qkv_bias:
        pb.zeros("bq", (H, hd), ("heads", "head_dim"), dt)
        pb.zeros("bk", (Kv, hd), ("kv_heads", "head_dim"), dt)
        pb.zeros("bv", (Kv, hd), ("kv_heads", "head_dim"), dt)
    if cfg.qk_norm:
        pb.ones("q_norm", (hd,), ("head_dim",), jnp.float32)
        pb.ones("k_norm", (hd,), ("head_dim",), jnp.float32)
    return pb.build()


def _q_proj(params, cfg, x):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
    return q


def _kv_proj(params, cfg, x):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return k, v


def gqa_encoder_kv(params, cfg: ModelConfig, sh: ShardingCtx, enc_h):
    """Cross-attention K/V from encoder states (computed once per session)."""
    k, v = _kv_proj(params, cfg, enc_h)
    return sh.act(k, "batch", "seq", "kv_heads_act", None), \
        sh.act(v, "batch", "seq", "kv_heads_act", None)


def apply_gqa_full(params, cfg: ModelConfig, sh: ShardingCtx, x, positions,
                   window=None, cross_kv=None, prefix_kv=None,
                   backend: str = "xla"):
    """Full-sequence attention (train / prefill).

    Returns (out, (k, v)) — k/v in un-expanded (B,S,Kv,hd) layout for caching
    (None for cross-attention).  ``cross_kv``: encoder (k, v) — non-causal.

    ``prefix_kv``: optional (k, v) of an already-prefilled prefix (chunked
    prefill).  The chunk's queries attend over prefix + chunk keys; the
    returned cache entry holds only the CHUNK's k/v (the prefix is already
    cached).  ``positions`` must then be ``P + arange(S_chunk)`` where P is
    the prefix length.  ``backend``: "xla" (oracle) or "pallas" (flash
    kernel when the feature set is supported, XLA fallback otherwise).
    """
    causal = cross_kv is None
    q_start = 0
    q = _q_proj(params, cfg, x)
    if causal:
        k, v = _kv_proj(params, cfg, x)
        if cfg.qk_norm:
            q = rms_norm_simple(q, params["q_norm"], cfg.norm_eps)
            k = rms_norm_simple(k, params["k_norm"], cfg.norm_eps)
        if cfg.pos_kind == "rope":
            cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        k = sh.act(k, "batch", "seq", "kv_heads_act", None)
        v = sh.act(v, "batch", "seq", "kv_heads_act", None)
        kv_out = (k, v)
        if prefix_kv is not None:
            pk, pv = prefix_kv
            q_start = pk.shape[1]
            k = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            v = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            kv_pos = jnp.arange(k.shape[1])
        else:
            kv_pos = positions
    else:
        k, v = cross_kv
        kv_pos = jnp.arange(k.shape[1])
        kv_out = None
    # Sequence-parallel attention (hillclimb B, EXPERIMENTS.md §Perf): when
    # the head count does not divide the model axis (qwen/llama4 40H,
    # gemma 8H), shard the QUERY sequence over "model" instead — attention
    # compute/memory drops by the axis size at the cost of replicated-KV
    # reads.  "attn_seq_q" maps to None for head-shardable archs.
    q = sh.act(q, "batch", "attn_seq_q", "heads_act", None)
    slopes = alibi_slopes(cfg.n_heads) if cfg.pos_kind == "alibi" else None
    win = window if causal else None  # non-causal ignores the window
    if _use_pallas_flash(backend, causal=causal, window=win, slopes=slopes,
                         q_start=q_start):
        # kernel contract: q_pos = q_start + arange(S), kv_pos = arange(T)
        # — exactly what the (chunked-)prefill call sites pass; GQA groups
        # are index-mapped inside the kernel (no KV head expansion copy)
        out = flash_attention(q, k, v, causal=causal, window=win,
                              slopes=slopes, q_start=q_start)
    else:
        G = cfg.n_heads // cfg.n_kv_heads
        k_exp = jnp.repeat(k, G, axis=2) if G > 1 else k
        v_exp = jnp.repeat(v, G, axis=2) if G > 1 else v
        out = attention_core(q, k_exp, v_exp, positions, kv_pos, window,
                             slopes, causal=causal, q_start=q_start)
    out = sh.act(out, "batch", "attn_seq_q", "heads_act", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, kv_out


def apply_gqa_decode(params, cfg: ModelConfig, sh: ShardingCtx, x, cache_k,
                     cache_v, pos, window=None, cross: bool = False,
                     kv_len=None, backend: str = "xla"):
    """Single-token decode.  x (B,1,d), cache (B,T,Kv,hd).

    Self-attention: writes the new token's K/V into the cache at ``pos`` and
    attends over the updated cache.  Returns (y, cache_k, cache_v).
    Cross-attention: the cache is the (static) encoder KV; returned
    unchanged.  ``kv_len`` masks cache positions beyond the valid encoder
    prefix when the cache is over-allocated (pooled serving).  ``backend``:
    "xla" (oracle) or "pallas" (decode kernel when the feature set is
    supported, XLA fallback otherwise).
    """
    q = _q_proj(params, cfg, x)
    if not cross:
        k, v = _kv_proj(params, cfg, x)
        if cfg.qk_norm:
            q = rms_norm_simple(q, params["q_norm"], cfg.norm_eps)
            k = rms_norm_simple(k, params["k_norm"], cfg.norm_eps)
        if cfg.pos_kind == "rope":
            posv = jnp.asarray(pos)[None]
            cos, sin = rope_angles(posv, cfg.head_dim, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), pos, axis=1)
    elif cfg.qk_norm:
        q = rms_norm_simple(q, params["q_norm"], cfg.norm_eps)

    slopes = alibi_slopes(cfg.n_heads) if cfg.pos_kind == "alibi" else None
    win = None if cross else window  # non-causal ignores the window
    if _use_pallas_decode(backend, causal=not cross, window=win,
                          slopes=slopes, kv_len=kv_len):
        out = decode_attention(q, cache_k, cache_v, pos, window=win,
                               slopes=slopes, causal=not cross,
                               kv_len=kv_len)
    else:
        out = decode_attention_xla(q, cache_k, cache_v, pos, window, slopes,
                                   causal=not cross, kv_len=kv_len)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA attention module (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    nope, rope = cfg.head_dim, cfg.rope_head_dim
    lora, qlora = cfg.kv_lora_rank, cfg.q_lora_rank
    dt = _dt(cfg)
    pb = ParamBuilder(key)
    pb.dense("wdq", (d, qlora), ("embed_fsdp", "qlora"), dt)
    pb.ones("q_norm", (qlora,), ("qlora",), jnp.float32)
    pb.dense("wuq", (qlora, H, nope + rope), ("qlora", "heads", "qk_dim"), dt)
    pb.dense("wdkv", (d, lora + rope), ("embed_fsdp", "kvlora"), dt)
    pb.ones("kv_norm", (lora,), ("kvlora",), jnp.float32)
    pb.dense("wuk", (lora, H, nope), ("kvlora", "heads", "qk_dim"), dt)
    pb.dense("wuv", (lora, H, nope), ("kvlora", "heads", "qk_dim"), dt)
    pb.dense("wo", (H, nope, d), ("heads", "qk_dim", "embed_fsdp"), dt)
    return pb.build()


def _mla_q(params, cfg, x, positions):
    nope, rope = cfg.head_dim, cfg.rope_head_dim
    cq = x @ params["wdq"].astype(x.dtype)
    cq = rms_norm_simple(cq, params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, params["wuq"].astype(x.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    cos, sin = rope_angles(positions, rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_latent(params, cfg: ModelConfig, x, positions):
    """Down-project to the cached representation: latent (B,S,lora) + k_rope."""
    lora, rope = cfg.kv_lora_rank, cfg.rope_head_dim
    ckv = x @ params["wdkv"].astype(x.dtype)
    latent, k_rope = ckv[..., :lora], ckv[..., lora:]
    latent = rms_norm_simple(latent, params["kv_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, rope, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return latent, k_rope


def apply_mla_full(params, cfg: ModelConfig, sh: ShardingCtx, x, positions,
                   prefix_kv=None, backend: str = "xla"):
    """Full-sequence MLA (unabsorbed — faithful for train/prefill).

    Returns (out, (latent, k_rope)) for caching.  ``prefix_kv``: optional
    (latent, k_rope) of an already-prefilled prefix (chunked prefill); the
    prefix latents are up-projected alongside the chunk's and the chunk's
    queries attend over both.  The returned cache entry holds only the
    CHUNK's latent/k_rope.  ``backend``: "xla" or "pallas" (the flash
    kernel runs the up-projected per-head attention, Kv = H).
    """
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    latent, k_rope = mla_latent(params, cfg, x, positions)
    kv_out = (latent, k_rope)
    q_start = 0
    if prefix_kv is not None:
        plat, pkr = prefix_kv
        q_start = plat.shape[1]
        latent = jnp.concatenate([plat.astype(latent.dtype), latent], axis=1)
        k_rope = jnp.concatenate([pkr.astype(k_rope.dtype), k_rope], axis=1)
        kv_pos = jnp.arange(latent.shape[1])
    else:
        kv_pos = positions
    k_nope = jnp.einsum("bsl,lhk->bshk", latent, params["wuk"].astype(x.dtype))
    v = jnp.einsum("bsl,lhk->bshk", latent, params["wuv"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    krope_bc = jnp.broadcast_to(
        k_rope[:, :, None, :],
        k_nope.shape[:3] + (k_rope.shape[-1],))
    k = jnp.concatenate([k_nope, krope_bc], axis=-1)
    q = sh.act(q, "batch", "seq", "heads_act", None)
    k = sh.act(k, "batch", "seq", "heads_act", None)
    v = sh.act(v, "batch", "seq", "heads_act", None)
    if _use_pallas_flash(backend, q_start=q_start):
        # per-head K/V (the MLA up-projection), so Kv = H; the faithful
        # 1/sqrt(nope+rope) scale is 1/sqrt(Dk) here — the kernel default
        out = flash_attention(q, k, v, causal=True, q_start=q_start)
    else:
        out = attention_core(q, k, v, positions, kv_pos, q_start=q_start)
    out = sh.act(out, "batch", "seq", "heads_act", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    # steer XLA to reduce-scatter (not all-reduce + slice) into the
    # sequence-sharded residual layout (hillclimb A iter 3)
    y = sh.act(y, "batch", "seq_act", None)
    return y, kv_out


def apply_mla_decode(params, cfg: ModelConfig, sh: ShardingCtx, x,
                     cache_latent, cache_krope, pos, backend: str = "xla"):
    """Absorbed-form MLA decode: attend in latent space (MQA with kv_head=1).

    cache_latent (B,T,lora), cache_krope (B,T,rope).  Writes the new token's
    latent/k_rope at ``pos`` and attends.  Returns (y, cache_latent,
    cache_krope).  ``backend``: "xla" (the oracle pre-scales q to undo the
    helper's 1/sqrt(lora+rope)) or "pallas" (the kernel takes the faithful
    1/sqrt(nope+rope) scale directly).
    """
    nope, rope = cfg.head_dim, cfg.rope_head_dim
    posv = jnp.asarray(pos)[None]
    q_nope, q_rope = _mla_q(params, cfg, x, posv)
    new_latent, new_krope = mla_latent(params, cfg, x, posv)
    cache_latent = jax.lax.dynamic_update_slice_in_dim(
        cache_latent, new_latent.astype(cache_latent.dtype), pos, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, new_krope.astype(cache_krope.dtype), pos, axis=1)
    # absorb W_uk into the query:  q_lat[h] = q_nope[h] @ W_uk[:, h, :]^T
    q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, params["wuk"].astype(x.dtype))
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,1,H,lora+rope)
    keys = jnp.concatenate([cache_latent, cache_krope], axis=-1)[:, :, None, :]
    # the faithful softmax scale is 1/sqrt(nope+rope), not the
    # 1/sqrt(lora+rope) either helper would derive from q_eff's width
    faithful = 1.0 / np.sqrt(nope + rope)
    if _use_pallas_decode(backend, scale=faithful):
        ctx = decode_attention(q_eff, keys, cache_latent[:, :, None, :],
                               pos, scale=faithful)
    else:
        # decode_attention_xla has no scale override — pre-scale q so its
        # 1/sqrt(lora+rope) lands on the faithful value
        scale_fix = np.sqrt(q_eff.shape[-1]) * faithful
        ctx = decode_attention_xla(q_eff * scale_fix, keys,
                                   cache_latent[:, :, None, :], pos)
    # ctx (B,1,H,lora): apply W_uv per head then the output projection.
    v_heads = jnp.einsum("bshl,lhk->bshk", ctx, params["wuv"].astype(x.dtype))
    y = jnp.einsum("bshk,hkd->bsd", v_heads, params["wo"].astype(x.dtype))
    return y, cache_latent, cache_krope
