"""Mixture-of-Experts FFN: top-k token-choice routing with capacity dispatch.

Dispatch is sort-based (argsort by expert id → gather into an (E, C, d)
buffer → grouped einsum → weighted scatter-add back), which avoids the
O(T·E·C) one-hot dispatch tensor of the classic Switch formulation — essential
for 160-expert DeepSeek-V2 at 1M tokens/step.  Tokens beyond an expert's
capacity ``C = ceil(T·k/E · capacity_factor)`` are dropped (standard TPU MoE
semantics); the residual connection carries dropped tokens through.

Sharding: the (E, C, d) dispatch buffer and expert weights are sharded over
the ``experts`` logical axis (mapped to the data axis → expert parallelism;
XLA inserts the all-to-alls) and ``expert_mlp`` over the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.layers import ParamBuilder, ShardingCtx


def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


EP_PAD_GROUP = 256  # pad expert allocation to the full-chip EP group size
EP_MIN_EXPERTS = 64  # only pad/EP-dispatch genuinely expert-rich archs


def expert_alloc(E: int) -> int:
    """Experts allocated in weights: padded to 256-way pure EP for archs with
    many experts (deepseek 160 -> 256; one expert per chip on a 256-chip pod;
    dummy experts receive no tokens).  Small-E archs stay unpadded."""
    if E >= EP_MIN_EXPERTS:
        return ((E + EP_PAD_GROUP - 1) // EP_PAD_GROUP) * EP_PAD_GROUP
    return E


def init_moe(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    Ea = expert_alloc(E)
    dt = _dt(cfg)
    pb = ParamBuilder(key)
    pb.dense("router", (d, E), ("embed_nosplit", "experts_nosplit"), jnp.float32)
    pb.dense("wg", (Ea, d, f), ("experts", "embed_nosplit", "expert_mlp"), dt)
    pb.dense("wu", (Ea, d, f), ("experts", "embed_nosplit", "expert_mlp"), dt)
    pb.dense("wo", (Ea, f, d), ("experts", "expert_mlp", "embed_nosplit"), dt)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        pb.dense("swg", (d, fs), ("embed_fsdp", "mlp"), dt)
        pb.dense("swu", (d, fs), ("embed_fsdp", "mlp"), dt)
        pb.dense("swo", (fs, d), ("mlp", "embed_fsdp"), dt)
    return pb.build()


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(np.ceil(n_tokens * cfg.moe_top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, int(np.ceil(c / 8) * 8))  # pad to a lane-friendly multiple


def router_topk(params, cfg: ModelConfig, xf):
    """Softmax router with renormalised top-k weights.  xf: (T, d)."""
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe_top_k)  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)
    return top_w, top_e, probs


def _sort_dispatch(xf, top_w, top_e, E_slots: int, C: int):
    """Sort-based capacity dispatch.  Returns (xe (E_slots, C, d),
    slot_token (E_slots*C,), slot_weight, counts (E_slots,), keep)."""
    T, d = xf.shape
    k = top_e.shape[-1]
    expert_flat = top_e.reshape(-1)  # (T*k,)
    weight_flat = top_w.reshape(-1)
    token_flat = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(expert_flat, stable=True)
    sorted_e = expert_flat[order]
    sorted_t = token_flat[order]
    sorted_w = weight_flat[order]
    counts = jnp.zeros((E_slots,), jnp.int32).at[sorted_e].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E_slots * C)  # drop sentinel
    slot_token = jnp.full((E_slots * C + 1,), T, jnp.int32).at[slot].set(
        sorted_t, mode="drop")[: E_slots * C]
    slot_weight = jnp.zeros((E_slots * C + 1,), jnp.float32).at[slot].set(
        sorted_w, mode="drop")[: E_slots * C]
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = x_pad[slot_token].reshape(E_slots, C, d)
    return xe, slot_token, slot_weight, counts, keep


def _combine(ye, slot_token, slot_weight, T: int):
    d = ye.shape[-1]
    yf = ye.reshape(-1, d) * slot_weight[:, None].astype(ye.dtype)
    return jnp.zeros((T, d), ye.dtype).at[slot_token].add(yf, mode="drop")


def _expert_mlp(xe, wg, wu, wo):
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype)))
    u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", g * u, wo.astype(xe.dtype))


def _shared_expert(params, cfg, sh, x, out):
    if cfg.n_shared_experts:
        gs = jax.nn.silu(x @ params["swg"].astype(x.dtype))
        us = x @ params["swu"].astype(x.dtype)
        hs = sh.act(gs * us, "batch", "seq", "mlp_act")
        y = hs @ params["swo"].astype(x.dtype)
        # reduce-scatter into the sequence-sharded residual layout
        out = out + sh.act(y, "batch", "seq_act", None)
    return out


def _ep_eligible(params, cfg: ModelConfig, sh: ShardingCtx, x) -> bool:
    """Use the shard_map pure-EP path when: mesh present, padded weights,
    and the (batch, seq) token grid divides the (data..., model) EP group."""
    if sh.mesh is None or params["wg"].shape[0] == cfg.n_experts:
        return False
    sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
    model = sizes.get("model", 1)
    n_data = int(np.prod([sizes[a] for a in ("pod", "data") if a in sizes]))
    B, S, _ = x.shape
    return (S % model == 0 and B % n_data == 0 and S // model >= 1
            and sh.rules.get("batch") is not None)


def apply_moe(params, cfg: ModelConfig, sh: ShardingCtx, x):
    """x (B, S, d) -> (B, S, d); routed top-k experts + optional shared expert.

    Returns (out, aux_metrics) with the load-balancing auxiliary loss terms.
    Dispatch substrate (DESIGN.md §6 / EXPERIMENTS.md §Perf hillclimb A):

    * pure-EP shard_map path — expert-rich archs (deepseek) on a mesh:
      experts padded to one-per-chip over (data x model); local top-k +
      sort dispatch; ONE all-to-all out + one back per layer.  ~50x less
      wire than XLA's handling of the global gather/scatter formulation.
    * global sort-dispatch path — small meshes / small-E archs / decode.
    """
    if _ep_eligible(params, cfg, sh, x):
        return _apply_moe_ep(params, cfg, sh, x)

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    T = B * S
    C = _capacity(cfg, T)
    xf = x.reshape(T, d)
    top_w, top_e, probs = router_topk(params, cfg, xf)
    xe, slot_token, slot_weight, counts, keep = _sort_dispatch(
        xf, top_w, top_e, E, C)
    xe = sh.act(xe, "experts", None, None)
    ye = _expert_mlp(xe, params["wg"][:E], params["wu"][:E], params["wo"][:E])
    ye = sh.act(ye, "experts", None, None)
    out = _combine(ye, slot_token, slot_weight, T).reshape(B, S, d)
    out = _shared_expert(params, cfg, sh, x, out)
    frac = counts.astype(jnp.float32) / jnp.maximum(T * k, 1)
    mean_prob = jnp.mean(probs, axis=0)
    aux = {"moe_aux_loss": E * jnp.sum(frac * mean_prob),
           "moe_drop_frac": 1.0 - jnp.sum(keep) / jnp.maximum(T * k, 1)}
    return sh.act(out, "batch", "seq_act", None), aux


def _apply_moe_ep(params, cfg: ModelConfig, sh: ShardingCtx, x):
    """Pure expert parallelism over the whole mesh via shard_map."""
    from jax.sharding import PartitionSpec as P

    mesh = sh.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ep_axes = tuple(a for a in ("data", "model") if a in sizes)
    n_ep = int(np.prod([sizes[a] for a in ep_axes]))
    batch_ax = sh.rules.get("batch")
    bt = batch_ax if isinstance(batch_ax, (tuple, list)) else (batch_ax,)
    E, k = cfg.n_experts, cfg.moe_top_k
    E_alloc = params["wg"].shape[0]
    assert E_alloc % n_ep == 0
    E_per = E_alloc // n_ep
    B, S, d = x.shape

    def body(x_loc, router, wg, wu, wo):
        B_l, S_l, _ = x_loc.shape
        T_l = B_l * S_l
        xf = x_loc.reshape(T_l, d)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
        C_src = max(8, int(np.ceil(T_l * k / E * cfg.capacity_factor / 8) * 8))
        xe, slot_token, slot_weight, counts, keep = _sort_dispatch(
            xf, top_w, top_e, E_alloc, C_src)
        send = xe.reshape(n_ep, E_per * C_src, d)
        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0, concat_axis=0,
                                  tiled=False)
        tok = recv.reshape(n_ep, E_per, C_src, d).transpose(1, 0, 2, 3)
        tok = tok.reshape(E_per, n_ep * C_src, d)
        ye = _expert_mlp(tok, wg, wu, wo)
        back = ye.reshape(E_per, n_ep, C_src, d).transpose(1, 0, 2, 3)
        back = back.reshape(n_ep, E_per * C_src, d)
        ret = jax.lax.all_to_all(back, ep_axes, split_axis=0, concat_axis=0,
                                 tiled=False)
        out = _combine(ret.reshape(E_alloc * C_src, d), slot_token,
                       slot_weight, T_l).reshape(B_l, S_l, d)
        # global aux stats (cheap scalar psums over every mesh axis)
        all_axes = tuple(mesh.axis_names)
        tot = jax.lax.psum(jnp.float32(T_l * k), all_axes)
        counts_g = jax.lax.psum(counts[:E].astype(jnp.float32), all_axes)
        mean_prob = jax.lax.pmean(jnp.mean(probs, axis=0), all_axes)
        kept = jax.lax.psum(jnp.sum(keep).astype(jnp.float32), all_axes)
        aux = {"moe_aux_loss": E * jnp.sum(counts_g / tot * mean_prob),
               "moe_drop_frac": 1.0 - kept / tot}
        return out, aux

    x_spec = P(bt[0] if len(bt) == 1 else tuple(bt), "model", None)
    out, aux = compat.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(None, None), P(ep_axes, None, None),
                  P(ep_axes, None, None), P(ep_axes, None, None)),
        out_specs=(x_spec, P()),
    )(x, params["router"], params["wg"], params["wu"], params["wo"])
    out = _shared_expert(params, cfg, sh, x, out)
    return sh.act(out, "batch", "seq_act", None), aux
