"""Model API: parameter init, train loss, prefill, decode — for all families.

A model's stack is a list of *scan segments* (``StackPlan``); each segment is
``lax.scan``'d over stacked per-layer params.  Segment layouts per family:

* dense / moe / vlm (incl. gemma3's 5:1 local:global, driven by a per-layer
  index scan input): ``[("blocks", decoder, n_layers)]``
* seamless enc-dec:   ``[("enc", encoder, 24), ("dec", cross_decoder, 24)]``
* zamba2 hybrid:      ``[("mega", 6 mamba + shared attn, 13), ("tail", mamba, 3)]``
  (shared attention params live outside the scan and are closed over)
* rwkv6:              ``[("blocks", rwkv, n_layers)]``

Scan keeps compile time ~O(1) in depth; the dry-run corrects XLA's
count-the-body-once cost accounting per segment (DESIGN.md §6).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.layers import (
    NULL_SH,
    ShardingCtx,
    embed_frames,
    embed_tokens,
    init_embedding,
    lm_head,
)

_LOSS_CHUNKS = 4


# ---------------------------------------------------------------------------
# Stack plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SegmentSpec:
    name: str
    kind: str  # decoder | enc | dec | mega | mamba | rwkv
    n: int  # scan length
    blocks_per_step: int = 1

    @property
    def n_blocks(self) -> int:
        return self.n * self.blocks_per_step


def stack_plan(cfg: ModelConfig) -> List[SegmentSpec]:
    if cfg.is_enc_dec:
        return [SegmentSpec("enc", "enc", cfg.n_enc_layers),
                SegmentSpec("dec", "dec", cfg.n_dec_layers)]
    if cfg.family == "hybrid":
        period = cfg.shared_attn_period
        n_mega, n_tail = divmod(cfg.n_layers, period)
        plan = [SegmentSpec("mega", "mega", n_mega, blocks_per_step=period)]
        if n_tail:
            plan.append(SegmentSpec("tail", "mamba", n_tail))
        return plan
    if cfg.family == "ssm":
        return [SegmentSpec("blocks", "rwkv", cfg.n_layers)]
    return [SegmentSpec("blocks", "decoder", cfg.n_layers)]


_SEG_INIT = {
    "decoder": B.init_decoder_block,
    "enc": B.init_encoder_block,
    "dec": B.init_cross_decoder_block,
    "mamba": B.init_mamba_block,
    "rwkv": B.init_rwkv_block,
}


def _tuple_leaf(x):
    return isinstance(x, tuple)


def _stack_axes(axes, extra=("layers",)):
    return jax.tree.map(lambda a: tuple(extra) + a, axes, is_leaf=_tuple_leaf)


def _shape_axes(init_fn, *args):
    """(ShapeDtypeStruct params, axes) of an init without allocating.

    The axes tree is static python data built during tracing, captured via a
    side channel (``jax.eval_shape`` cannot return string leaves).
    """
    box = {}

    def f(k):
        p, a = init_fn(k, *args)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["axes"]


def _vmap_init(init_fn, key, n, cfg):
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: init_fn(k, cfg)[0])(keys)
    _, axes = _shape_axes(init_fn, cfg)
    return params, _stack_axes(axes)


def init_params(key, cfg: ModelConfig):
    """Returns (params, axes) — axes mirrors params with logical-name tuples."""
    keys = jax.random.split(key, 8)
    params: Dict = {}
    axes: Dict = {}
    p, a = init_embedding(keys[0], cfg)
    params["embed"], axes["embed"] = p, a
    params["segments"], axes["segments"] = {}, {}
    for i, seg in enumerate(stack_plan(cfg)):
        k = keys[2 + i]
        if seg.kind == "mega":
            per = seg.blocks_per_step

            def mega_one(kk, cfg=cfg, per=per):
                return _vmap_init(B.init_mamba_block, kk, per, cfg)

            kk = jax.random.split(k, seg.n)
            ps = jax.vmap(lambda kx: mega_one(kx)[0])(kk)
            _, ax = _shape_axes(mega_one)
            params["segments"][seg.name] = {"mamba": ps}
            axes["segments"][seg.name] = {"mamba": _stack_axes(ax)}
        else:
            ps, ax = _vmap_init(_SEG_INIT[seg.kind], k, seg.n, cfg)
            params["segments"][seg.name] = ps
            axes["segments"][seg.name] = ax
    if cfg.family == "hybrid":
        p, a = B.init_zamba_shared(keys[1], cfg)
        params["shared"], axes["shared"] = p, a
    return params, axes


@functools.lru_cache(maxsize=32)
def init_params_shapes(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, axes tree) without allocation."""
    return _shape_axes(init_params, cfg)


def param_axes(cfg: ModelConfig):
    """Axes tree without materialising params (for sharding rules)."""
    return init_params_shapes(cfg)[1]


# ---------------------------------------------------------------------------
# Block-order parameter views (the serving engine's placement granularity)
# ---------------------------------------------------------------------------


def hybrid_mamba_stack(params, cfg: ModelConfig):
    """All ``n_layers`` mamba mixer params stacked on axis 0 in BPRR block
    order (hybrid family): the mega segment's ``(n_mega, per, ...)`` leaves
    flattened, the tail segment concatenated.  The serving layer slices
    per-server block ranges out of this view; the shared attention params
    (``params["shared"]``) ride alongside, not inside."""
    segs = params["segments"]
    mega = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                        segs["mega"]["mamba"])
    if "tail" in segs:
        return jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                            mega, segs["tail"])
    return mega


def block_param_range(params, cfg: ModelConfig, kind: str, lo: int, hi: int):
    """Per-layer block params stacked on axis 0 for absolute BPRR blocks
    ``[lo, hi)`` — all of one ``kind`` (see ``blocks.stack_block_kinds``).

    "mamba_shared" blocks return their mamba mixer params; the shared
    attention half lives in ``params["shared"]`` (parameter sharing means it
    is NOT per-block)."""
    segs = params["segments"]
    if kind in ("decoder", "rwkv"):
        return jax.tree.map(lambda x: x[lo:hi], segs["blocks"])
    if kind in ("mamba", "mamba_shared"):
        flat = hybrid_mamba_stack(params, cfg)
        return jax.tree.map(lambda x: x[lo:hi], flat)
    if kind == "enc":
        return jax.tree.map(lambda x: x[lo:hi], segs["enc"])
    if kind == "dec":
        ne = cfg.n_enc_layers
        return jax.tree.map(lambda x: x[lo - ne:hi - ne], segs["dec"])
    raise ValueError(
        f"unknown block kind {kind!r}; supported: decoder, rwkv, mamba, "
        "mamba_shared, enc, dec")


def block_param_axes(cfg: ModelConfig, kind: str):
    """Logical-axes tree matching :func:`block_param_range`'s output
    structure for one kind (slicing a layer range keeps every leaf's axes,
    so no range argument is needed).  Used to derive per-server
    NamedShardings when a geo server is a TP/EP device group."""
    axes = param_axes(cfg)["segments"]
    if kind in ("decoder", "rwkv"):
        return axes["blocks"]
    if kind in ("mamba", "mamba_shared"):
        # hybrid_mamba_stack merges the mega segment's (n_mega, per) leading
        # dims into one block axis: drop one of the two stacked "layers"
        mega = axes.get("mega", {}).get("mamba")
        if mega is not None:
            return jax.tree.map(lambda a: a[1:], mega, is_leaf=_tuple_leaf)
        return axes["tail"]
    if kind == "enc":
        return axes["enc"]
    if kind == "dec":
        return axes["dec"]
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Segment scan bodies (shared by forward passes AND the dry-run's exact
# scan-cost correction, which lowers each body separately — DESIGN.md §6)
# ---------------------------------------------------------------------------


def make_full_body(seg: SegmentSpec, cfg: ModelConfig, sh: ShardingCtx,
                   positions, emb0=None, enc_h=None, collect_caches=False,
                   shared_params=None):
    """Returns body(carry, (params_slice, x)) for a full-sequence scan.

    carry: (h, aux_acc) for "decoder"; h otherwise.
    """
    if seg.kind == "decoder":
        def body(carry, x):
            hh, aux_acc = carry
            p, idx = x
            hh, cache, aux = B.decoder_block_full(p, cfg, sh, hh, positions,
                                                  idx)
            aux_acc = {k2: aux_acc[k2] + jnp.float32(aux.get(k2, 0.0))
                       for k2 in aux_acc}
            return (hh, aux_acc), (cache if collect_caches else 0)
        return body
    if seg.kind == "rwkv":
        def body(carry, x):
            hh, state = B.rwkv_block_full(x[0], cfg, sh, carry)
            return hh, (state if collect_caches else 0)
        return body
    if seg.kind == "mamba":
        def body(carry, x):
            hh, state = B.mamba_block_full(x[0], cfg, sh, carry)
            return hh, (state if collect_caches else 0)
        return body
    if seg.kind == "mega":
        def body(carry, x):
            p = x[0]
            hh = carry
            m_states = []
            for j in range(seg.blocks_per_step):
                pj = jax.tree.map(lambda q: q[j], p["mamba"])
                hh, st = B.mamba_block_full(pj, cfg, sh, hh)
                m_states.append(st)
            hh, attn_cache = B.zamba_shared_full(shared_params, cfg, sh, hh,
                                                 emb0, positions)
            m_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *m_states)
            ys = ({"mamba": m_stack, "attn": attn_cache}
                  if collect_caches else 0)
            return hh, ys
        return body
    if seg.kind == "enc":
        def body(carry, x):
            return B.encoder_block_full(x[0], cfg, sh, carry, positions), 0
        return body
    if seg.kind == "dec":
        def body(carry, x):
            hh, cache = B.cross_decoder_block_full(x[0], cfg, sh, carry,
                                                   positions, enc_h)
            return hh, (cache if collect_caches else 0)
        return body
    raise ValueError(f"unexpected segment kind {seg.kind}")


def make_decode_body(seg: SegmentSpec, cfg: ModelConfig, sh: ShardingCtx,
                     pos, emb0=None, shared_params=None):
    """Returns body(h, (params_slice, cache_slice, *extras)) -> (h, cache)."""
    if seg.kind == "decoder":
        def body(carry, x):
            p, c, idx = x
            return B.decoder_block_decode(p, cfg, sh, carry, c, pos, idx)
        return body
    if seg.kind == "rwkv":
        def body(carry, x):
            p, c = x
            return B.rwkv_block_decode(p, cfg, sh, carry, c)
        return body
    if seg.kind == "mamba":
        def body(carry, x):
            p, c = x
            return B.mamba_block_decode(p, cfg, sh, carry, c)
        return body
    if seg.kind == "mega":
        def body(carry, x):
            p, c = x
            hh = carry
            new_m = []
            for j in range(seg.blocks_per_step):
                pj = jax.tree.map(lambda q: q[j], p["mamba"])
                cj = jax.tree.map(lambda q: q[j], c["mamba"])
                hh, st = B.mamba_block_decode(pj, cfg, sh, hh, cj)
                new_m.append(st)
            hh, attn_c = B.zamba_shared_decode(shared_params, cfg, sh, hh,
                                               emb0, c["attn"], pos)
            m_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
            return hh, {"mamba": m_stack, "attn": attn_c}
        return body
    if seg.kind == "dec":
        def body(carry, x):
            p, c = x
            return B.cross_decoder_block_decode(p, cfg, sh, carry, c, pos)
        return body
    raise ValueError(seg.kind)


def _maybe_remat(body, remat):
    if remat:
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    return body


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_full(params, cfg: ModelConfig, sh: ShardingCtx, batch,
                 remat: bool = False, collect_caches: bool = False,
                 cache_len: Optional[int] = None):
    """Run the stack over full sequences.

    Returns (h_final, aux, caches) where caches is a dict segment -> stacked
    cache entries (only if collect_caches).
    """
    if cfg.is_enc_dec:
        return _forward_encdec(params, cfg, sh, batch, remat, collect_caches,
                               cache_len)
    tokens = batch["tokens"]
    S = tokens.shape[1]
    positions = jnp.arange(S)
    h = embed_tokens(params["embed"], cfg, sh, tokens)
    h = sh.act(h, "batch", "seq_act", None)
    emb0 = h
    caches: Dict = {}
    aux_total = {"moe_aux_loss": jnp.float32(0.0),
                 "moe_drop_frac": jnp.float32(0.0)}

    for seg in stack_plan(cfg):
        seg_params = params["segments"][seg.name]
        body = _maybe_remat(
            make_full_body(seg, cfg, sh, positions, emb0=emb0,
                           collect_caches=collect_caches,
                           shared_params=params.get("shared")), remat)
        if seg.kind == "decoder":
            (h, aux_total), ys = jax.lax.scan(
                body, (h, aux_total), (seg_params, jnp.arange(seg.n)))
        else:
            h, ys = jax.lax.scan(body, h, (seg_params, None), length=seg.n)
        if collect_caches:
            caches[seg.name] = ys
    if collect_caches and cache_len is not None:
        caches = _pad_caches(caches, cfg, cache_len, S)
    return h, aux_total, caches


def _forward_encdec(params, cfg: ModelConfig, sh: ShardingCtx, batch, remat,
                    collect_caches, cache_len):
    frames = batch["frames"]
    tokens = batch["tokens"]
    plan = {s.name: s for s in stack_plan(cfg)}
    enc_pos = jnp.arange(frames.shape[1])
    dec_pos = jnp.arange(tokens.shape[1])
    enc_h = embed_frames(params["embed"], cfg, sh, frames)

    enc_body = _maybe_remat(
        make_full_body(plan["enc"], cfg, sh, enc_pos), remat)
    enc_h, _ = jax.lax.scan(enc_body, enc_h, (params["segments"]["enc"], None))

    h = embed_tokens(params["embed"], cfg, sh, tokens)
    dec_body = _maybe_remat(
        make_full_body(plan["dec"], cfg, sh, dec_pos, enc_h=enc_h,
                       collect_caches=collect_caches), remat)
    h, ys = jax.lax.scan(dec_body, h, (params["segments"]["dec"], None))
    caches = {}
    aux = {"moe_aux_loss": jnp.float32(0.0), "moe_drop_frac": jnp.float32(0.0)}
    if collect_caches:
        caches["dec"] = ys
        if cache_len is not None:
            caches = _pad_caches(caches, cfg, cache_len, tokens.shape[1])
    return h, aux, caches


_PADDED_CACHE_KEYS = frozenset({"k", "v", "latent", "krope"})


def _pad_caches(caches, cfg: ModelConfig, cache_len: int, cur_len: int):
    """Grow KV-type cache time axes (axis 2: layers, B, T, ...) to cache_len.

    SSM states and cross-attention caches ("ck"/"cv") are length-free and
    left untouched.  Padding is by leaf *name* so shape coincidences (e.g.
    wkv head counts equal to cur_len) can never mis-pad.
    """
    if cache_len < cur_len:
        raise ValueError("cache_len must be >= prefill length")
    if cache_len == cur_len:
        return caches

    def pad_leaf(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else None
        if name in _PADDED_CACHE_KEYS and x.ndim >= 3 and x.shape[2] == cur_len:
            widths = [(0, 0)] * x.ndim
            widths[2] = (0, cache_len - cur_len)
            return jnp.pad(x, widths)
        return x

    return jax.tree_util.tree_map_with_path(pad_leaf, caches)


# ---------------------------------------------------------------------------
# Loss (next-token CE, chunked over sequence to bound logits memory)
# ---------------------------------------------------------------------------


def train_loss(params, cfg: ModelConfig, sh: ShardingCtx, batch,
               remat: bool = True):
    """Mean next-token cross-entropy (+ MoE aux loss).  Returns (loss, metrics)."""
    h, aux, _ = forward_full(params, cfg, sh, batch, remat=remat)
    tokens = batch["tokens"]
    Bsz, S = tokens.shape
    n_chunks = _LOSS_CHUNKS if S % _LOSS_CHUNKS == 0 and S >= _LOSS_CHUNKS else 1
    csz = S // n_chunks
    total = jnp.float32(0.0)
    denom = Bsz * (S - 1)
    for i in range(n_chunks):
        hs = h[:, i * csz: (i + 1) * csz]
        logits = lm_head(params["embed"], cfg, sh, hs).astype(jnp.float32)
        # labels: next token; positions beyond S-1 are masked out
        idx = jnp.arange(i * csz, (i + 1) * csz)
        valid = idx < (S - 1)
        labels = jnp.take(tokens, jnp.minimum(idx + 1, S - 1), axis=1)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * valid[None, :]
        total = total + jnp.sum(ce)
    loss = total / denom
    metrics = {"ce_loss": loss}
    if cfg.is_moe:
        loss = loss + 0.01 * aux["moe_aux_loss"] / max(1, cfg.n_layers)
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
        metrics["moe_drop_frac"] = aux["moe_drop_frac"] / max(1, cfg.n_layers)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, sh: ShardingCtx, batch,
            cache_len: Optional[int] = None):
    """Process the prompt; returns (last-token logits, caches)."""
    h, _, caches = forward_full(params, cfg, sh, batch, remat=False,
                                collect_caches=True, cache_len=cache_len)
    logits = lm_head(params["embed"], cfg, sh, h[:, -1:])
    return logits[:, 0], caches


def decode_step(params, cfg: ModelConfig, sh: ShardingCtx, caches, tokens,
                pos):
    """One decode step.  tokens (B,), pos scalar.  Returns (logits, caches)."""
    h = embed_tokens(params["embed"], cfg, sh, tokens[:, None])
    emb0 = h
    new_caches = {}
    for seg in stack_plan(cfg):
        if seg.kind == "enc":
            continue  # encoder has no decode-time work (cross KV is cached)
        seg_params = params["segments"][seg.name]
        cache = caches[seg.name]
        body = make_decode_body(seg, cfg, sh, pos, emb0=emb0,
                                shared_params=params.get("shared"))
        if seg.kind == "decoder":
            xs = (seg_params, cache, jnp.arange(seg.n))
        else:
            xs = (seg_params, cache)
        h, ys = jax.lax.scan(body, h, xs)
        new_caches[seg.name] = ys
    logits = lm_head(params["embed"], cfg, sh, h)
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# Cache construction (for the dry-run decode cells and the serving engine)
# ---------------------------------------------------------------------------


def init_decode_caches(cfg: ModelConfig, batch_size: int, cache_len: int,
                       enc_len: Optional[int] = None):
    """Zero-initialised cache pytree for decode at a given cache length."""
    Bsz, T = batch_size, cache_len
    cdt = jnp.dtype(cfg.param_dtype)
    caches: Dict = {}
    for seg in stack_plan(cfg):
        n = seg.n
        if seg.kind == "decoder":
            if cfg.attn_kind == "mla":
                caches[seg.name] = {
                    "latent": jnp.zeros((n, Bsz, T, cfg.kv_lora_rank), cdt),
                    "krope": jnp.zeros((n, Bsz, T, cfg.rope_head_dim), cdt),
                }
            else:
                kv = (n, Bsz, T, cfg.n_kv_heads, cfg.head_dim)
                caches[seg.name] = {"k": jnp.zeros(kv, cdt),
                                    "v": jnp.zeros(kv, cdt)}
        elif seg.kind == "dec":
            kv = (n, Bsz, T, cfg.n_kv_heads, cfg.head_dim)
            ckv = (n, Bsz, enc_len or T, cfg.n_kv_heads, cfg.head_dim)
            caches[seg.name] = {"k": jnp.zeros(kv, cdt),
                                "v": jnp.zeros(kv, cdt),
                                "ck": jnp.zeros(ckv, cdt),
                                "cv": jnp.zeros(ckv, cdt)}
        elif seg.kind == "rwkv":
            h_, hd = cfg.ssm_heads, cfg.ssm_head_dim
            caches[seg.name] = {
                "wkv": jnp.zeros((n, Bsz, h_, hd, hd), jnp.float32),
                "shift_tm": jnp.zeros((n, Bsz, cfg.d_model), jnp.float32),
                "shift_cm": jnp.zeros((n, Bsz, cfg.d_model), jnp.float32),
            }
        elif seg.kind in ("mamba", "mega"):
            h_, p_, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            conv_dim = cfg.d_inner + 2 * cfg.ssm_state
            m_state = {
                "ssm": jnp.zeros((n, Bsz, h_, p_, ns), jnp.float32),
                "conv": jnp.zeros((n, Bsz, cfg.conv_width - 1, conv_dim),
                                  jnp.float32),
            }
            if seg.kind == "mega":
                per = seg.blocks_per_step
                m_state = {
                    "ssm": jnp.zeros((n, per, Bsz, h_, p_, ns), jnp.float32),
                    "conv": jnp.zeros((n, per, Bsz, cfg.conv_width - 1,
                                       conv_dim), jnp.float32),
                }
                kv = (n, Bsz, T, cfg.n_kv_heads, cfg.head_dim)
                caches[seg.name] = {
                    "mamba": m_state,
                    "attn": {"k": jnp.zeros(kv, cdt),
                             "v": jnp.zeros(kv, cdt)},
                }
            else:
                caches[seg.name] = m_state
        elif seg.kind == "enc":
            continue
    return caches
