from repro.models.layers import NULL_SH, ShardingCtx
from repro.models.model import (
    decode_step,
    init_decode_caches,
    init_params,
    init_params_shapes,
    param_axes,
    prefill,
    stack_plan,
    train_loss,
)

__all__ = [
    "NULL_SH",
    "ShardingCtx",
    "decode_step",
    "init_decode_caches",
    "init_params",
    "init_params_shapes",
    "param_axes",
    "prefill",
    "stack_plan",
    "train_loss",
]
