from repro.models.blocks import stack_block_kinds
from repro.models.layers import NULL_SH, ShardingCtx
from repro.models.model import (
    block_param_range,
    decode_step,
    hybrid_mamba_stack,
    init_decode_caches,
    init_params,
    init_params_shapes,
    param_axes,
    prefill,
    stack_plan,
    train_loss,
)

__all__ = [
    "NULL_SH",
    "ShardingCtx",
    "block_param_range",
    "decode_step",
    "hybrid_mamba_stack",
    "init_decode_caches",
    "init_params",
    "init_params_shapes",
    "param_axes",
    "prefill",
    "stack_block_kinds",
    "stack_plan",
    "train_loss",
]
