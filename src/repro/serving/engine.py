"""Geo-distributed serving engine: the PETALS architecture natively in JAX,
with continuous batching across sessions.

Executes REAL block-level forward passes according to a BPRR placement with
client-centric (hub-spoke) communication and client-side input caches —
the paper's Fig. 1 — while a virtual clock accounts time with the validated
performance models (eq. (1)): the engine cross-validates the simulator.

Multi-session execution (eq. (5)/(20) semantics):

* every server keeps ONE family-polymorphic stacked state pool
  (``repro.serving.kv_cache``) whose rows are per-session slots; a single
  jitted step — vmapped over rows, scanned over the server's hosted block
  runs — decodes every resident session at once.  The decode round is
  DEVICE-RESIDENT (``decode_mode="fused"``): one batched embed, one fused
  gather+step+scatter dispatch per (hop, server) over fixed-width round
  buffers, one fused lm_head+sample tail, one host sync per round, with
  every pooled step donating its cache pool (in-place update — see
  docs/serving.md "Round anatomy" for the aliasing contract).  Which
  state a block row
  carries (KV tensors, MLA latents, SSM+conv state, wkv/shift state,
  self-KV + encoder cross-KV) is dispatched per block via its
  :class:`~repro.serving.kv_cache.StateSpec`; the pool shape is fixed, so
  the step traces exactly once per server: admitting/retiring sessions
  flips mask bits instead of re-tracing, and per-session results are
  bit-for-bit identical whether a session runs alone or among
  ``max_sessions`` neighbours.
* cache block-slots follow the paper's memory model: server j has
  ⌊(M_j − s_m·m_j)/s_c⌋ slots; a session routed through k_j of its blocks
  occupies k_j slots from start to retirement (no-overbooking commitment).
  ``try_admit_session``/``retire_session`` enforce the budget; the
  continuous-batching scheduler (repro.serving.scheduler) defers sessions
  that do not fit and re-admits them as slots free.

Fault tolerance (DESIGN.md §7) is unchanged in spirit and now concurrent:
client-side per-hop input caches let a failed block range be re-routed over
surviving servers and replayed exactly — with any number of co-resident
sessions.  Elastic join/leave triggers CG-BP re-placement at the slow time
scale; stragglers feed per-server slowdowns into the routing costs.

Supported block families (``kv_cache.SUPPORTED_KINDS``): "decoder" (dense /
MoE / VLM / gemma-pattern), "rwkv" (attention-free), "mamba" /
"mamba_shared" (zamba2 hybrids), and "enc" / "dec" (seamless
encoder-decoder).  Enc-dec sessions carry encoder ``frames`` alongside the
decoder prompt; hybrid stacks thread the original embedding (``emb0``) to
the parameter-shared attention blocks.  Token selection is per-session
policy (``repro.serving.sampling.SamplingSpec``): seeded greedy /
temperature / top-k, threaded through the pooled rounds as vmapped row
inputs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.perf_model import Placement, Problem, Route
from repro.core.placement import petals_bp
from repro.core.routing import petals_route, shortest_path_route
from repro.models.layers import NULL_SH, embed_frames, embed_tokens, lm_head
from repro.models.model import block_param_range
from repro.serving.faults import (FailureDetector, FaultPlan,
                                  NoCapacityError, recovery_replay_cost)
from repro.serving.kv_cache import (CachePool, bucket_for,
                                    default_prefill_buckets, kind_runs,
                                    make_paged_decode_step,
                                    make_paged_prefill_step,
                                    make_paged_round_step,
                                    make_pool_decode_step,
                                    make_pool_prefill_step,
                                    make_pool_round_step,
                                    make_prefill_block, pages_for,
                                    state_specs)
from repro.serving.sampling import (SamplingSpec, make_round_tail,
                                    make_sampler)


@dataclass
class EngineSession:
    """Client-side state for one session: its route, token buffer, per-hop
    input history (the failover replay cache), and the virtual-clock
    accounting (prefill / per-token / end times per eq. (1)).

    Enc-dec sessions additionally carry the encoder input ``frames``
    (S_enc, frame_dim), its length, and — once prefilled — the encoder
    output ``enc_out`` (a client-side artifact, like the hop histories:
    failover replay rebuilds cross-KV from it)."""

    sid: int
    client: int
    route: Route
    prompt_len: int
    n_new: int
    arrival: float = 0.0
    start: float = 0.0
    pos: int = 0  # next cache write position
    tokens: List[int] = field(default_factory=list)  # prompt + generated
    n_generated: int = 0
    # admitted | prefilling | active | preempted | failed | done —
    # "preempted": evicted from every route server (page pressure, or a
    # capacity-starved failover deferral), resumable via the
    # failover-replay machinery
    state: str = "admitted"
    # machine-readable reason when state == "failed" (e.g. "no_route",
    # "no_capacity", "server_lost_mid_prefill")
    fail_reason: Optional[str] = None
    n_preemptions: int = 0  # times this session was swapped out
    # failure-recovery accounting (timeout detection -> backoff -> billed
    # replay; see docs/concurrency.md "Failure model")
    n_detections: int = 0  # timeout-detected server losses on this route
    n_retries: int = 0  # backoff probes sent while confirming a suspect
    n_replays: int = 0  # cache rebuilds billed (failover or resume)
    detect_time: float = 0.0  # deadline waits (virtual seconds)
    backoff_time: float = 0.0  # probe backoff sleeps (virtual seconds)
    replay_time: float = 0.0  # replay compute + input RTTs (virtual s)
    n_defer_resumes: int = 0  # capacity-deferral resume attempts
    # per-hop input history (the PETALS fault-tolerance cache); entry 0 is
    # the prompt-phase record — a plain array for single-phase stacks, a
    # {"enc": ..., "dec": ...} dict for enc-dec — followed by one record per
    # decoded token that flowed through the hop: a (1, 1, d) array on the
    # host-staged paths, or a lazy ((members, 1, d) hop gather, index)
    # tuple on the fused path (materialized by GeoServingSystem._hop_record
    # only if a failover replays it)
    hop_inputs: List[List] = field(default_factory=list)
    sampling: SamplingSpec = field(default_factory=SamplingSpec)
    frames: Optional[np.ndarray] = None  # encoder input (enc-dec only)
    enc_len: int = 0
    enc_out: Optional[jnp.ndarray] = None  # encoder output (client cache)
    virtual_time: float = 0.0  # accumulated service time (prefill + decode)
    prefill_time: float = 0.0
    per_token_time: float = 0.0
    end: float = float("inf")
    # logits behind tokens[-1]: a concrete (V,)/(1, V) array, or — on the
    # fused round path — a lazy ((W, V) rows, slot) reference materialized
    # on first read so the round hot loop never pays per-session slicing
    # dispatches (see the ``last_logits`` property below)
    _logits_box: Optional[object] = None
    # transient per-round hidden state / original embedding
    _h: Optional[jnp.ndarray] = None
    _emb0: Optional[jnp.ndarray] = None

    @property
    def last_logits(self) -> Optional[jnp.ndarray]:
        box = self._logits_box
        if isinstance(box, tuple):  # lazy (rows, slot) from a fused round
            rows, g = box
            box = rows[g]
            self._logits_box = box
        return box

    @last_logits.setter
    def last_logits(self, value):
        self._logits_box = value

    @property
    def recovery_time(self) -> float:
        """Total virtual-clock time this session spent recovering from
        failures: detection waits + backoff sleeps + billed replay."""
        return self.detect_time + self.backoff_time + self.replay_time


class BlockServer:
    """One 'server': params for its block range + a stacked session pool.

    The hosted range may mix block families; ``self.kinds`` is its static
    per-layer kind tuple and ``self.runs`` the contiguous same-kind runs
    the pooled steps scan over.  Exposes two pooled compute entry points,
    both vmapped over the pool's rows so they trace once per server:
    :meth:`decode_rows` (one token for every masked row) and
    :meth:`prefill_rows` (one padded prompt chunk for every masked row —
    the bucket-group prefill path).
    """

    def __init__(self, sid: int, cfg: ModelConfig, params, a: int, m: int,
                 *, n_rows: int, max_len: int, cap_slots: int,
                 enc_len: int = 0, slowdown: float = 1.0,
                 backend: str = "xla", cache_layout: str = "slab",
                 page_size: int = 0, mesh=None, mesh_rules=None,
                 group=None):
        self.sid = sid
        self.backend = backend
        self.cfg = cfg
        self.a, self.m = int(a), int(m)
        self.specs = state_specs(cfg)[self.a: self.a + self.m]
        self.kinds = tuple(s.kind for s in self.specs)
        self.runs = kind_runs(self.kinds)
        self.n_enc = cfg.n_enc_layers
        # per-run stacked block params (axis 0 over the run's layers)
        self.run_params = tuple(
            block_param_range(params, cfg, kind, self.a + lo, self.a + hi)
            for kind, lo, hi in self.runs)
        self.shared = params.get("shared")  # zamba2 shared attention
        self.layer_ids = jnp.arange(self.a, self.a + self.m, dtype=jnp.int32)
        self.cache_layout = cache_layout
        self.pool = CachePool(cfg, self.kinds, n_rows, max_len, cap_slots,
                              enc_len=enc_len, layout=cache_layout,
                              page_size=page_size)
        self.alive = True
        # crashed: the server stopped responding but no client has noticed
        # yet — dispatches to it miss their deadline and the engine bills
        # timeout detection before flipping ``alive`` (FaultPlan path).
        # suspected: it was once declared dead by timeout; routing keeps an
        # additive cost penalty against it even after a rejoin.
        self.crashed = False
        self.suspected = False
        self.slowdown = slowdown
        # Optional TP/EP device group: this server's params + pool live
        # sharded over the group's mesh per the logical-axis rules, and its
        # pooled steps constrain every operand accordingly (docs/serving.md
        # "Device-group servers").  A solo group (mesh=None) is the
        # single-device twin.  `group` is the DeviceGroup descriptor (or a
        # bare Mesh / None); `mesh`/`mesh_rules` remain as sugar for a
        # single-group server.
        from repro.launch.sharding import DeviceGroup, as_device_group

        if group is None:
            group = as_device_group(mesh)
            if mesh_rules is not None and group.rules is None:
                group = DeviceGroup(mesh=group.mesh, rules=mesh_rules)
        else:
            group = as_device_group(group)
        self.group = group
        self.mesh = mesh = group.mesh
        self.n_chips = group.n_chips
        if mesh is not None:
            from repro.launch.sharding import (
                block_param_shardings, pool_tree_shardings, thaw_rules)
            from repro.models.model import block_param_axes

            frozen = group.frozen_rules_for(cfg, n_rows, max_len)
            rules = thaw_rules(frozen)
            self.mesh_rules = rules
            self.run_params = tuple(
                jax.device_put(p, block_param_shardings(
                    mesh, rules, block_param_axes(cfg, kind), p))
                for p, (kind, _lo, _hi) in zip(self.run_params, self.runs))
            if self.shared is not None:
                self.shared = jax.device_put(
                    self.shared, jax.tree.map(
                        lambda _: NamedSharding(mesh, P()), self.shared))
            self.pool.tree = jax.device_put(
                self.pool.tree,
                pool_tree_shardings(mesh, rules, self.pool.tree))
        else:
            self.mesh_rules = None
            frozen = None
        if cache_layout == "paged":
            self._step = make_paged_decode_step(cfg, self.kinds, backend,
                                                page_size, mesh, frozen)
            self._round_step = make_paged_round_step(cfg, self.kinds,
                                                     backend, page_size,
                                                     mesh, frozen)
            self._prefill_pool = make_paged_prefill_step(cfg, self.kinds,
                                                         backend, page_size,
                                                         mesh, frozen)
        else:
            self._step = make_pool_decode_step(cfg, self.kinds, backend,
                                               mesh, frozen)
            self._round_step = make_pool_round_step(cfg, self.kinds,
                                                    backend, mesh, frozen)
            self._prefill_pool = make_pool_prefill_step(cfg, self.kinds,
                                                        backend, mesh,
                                                        frozen)
        self._prefill_blocks = {k: make_prefill_block(cfg, k, backend)
                                for k in set(self.kinds)}
        # constant-shape filler for unused emb0/enc_rows step inputs, so the
        # jit trace key never varies with them
        self._dummy = jnp.zeros((1, 1, 1), jnp.float32)
        self._zero_encl = jnp.zeros((n_rows,), jnp.int32)

    # -- session admission bookkeeping --------------------------------------
    def fits(self, sid: int, k_blocks: int, n_pages: int = 0,
             worst_pages: Optional[int] = None) -> bool:
        if self.cache_layout == "paged":
            return self.pool.fits(sid, k_blocks, n_pages, worst_pages)
        return self.pool.fits(sid, k_blocks)

    def admit(self, sid: int, k_blocks: int, n_pages: int = 0) -> int:
        return self.pool.alloc(sid, k_blocks, n_pages)

    def evict(self, sid: int):
        self.pool.release(sid)

    def n_sessions(self) -> int:
        return self.pool.n_sessions()

    # -- compute ------------------------------------------------------------
    def _layer_params(self, l_rel: int):
        for r, (kind, lo, hi) in enumerate(self.runs):
            if lo <= l_rel < hi:
                return jax.tree.map(lambda x: x[l_rel - lo],
                                    self.run_params[r])
        raise IndexError(l_rel)

    def prefill_range(self, sid: int, h, lo: int, hi: int, positions,
                      emb0=None, enc_h=None):
        """Prefill blocks [lo, hi) for one session (serial reference path);
        fills its pool row.  ``emb0``/``enc_h``: the extra inputs shared-
        attention / cross-attention blocks need."""
        assert self.alive, f"server {self.sid} is dead"
        row = self.pool.rows[sid]
        S = h.shape[1]
        entries = []
        for l in range(lo, hi):
            kind = self.kinds[l - self.a]
            p = self._layer_params(l - self.a)
            fb = self._prefill_blocks[kind]
            if kind == "decoder":
                h, cache, _ = fb(p, h, positions, jnp.int32(l))
            elif kind in ("rwkv", "mamba"):
                h, cache = fb(p, h)
            elif kind == "mamba_shared":
                h, cache = fb(p, self.shared, h, emb0, positions)
            elif kind == "enc":
                h = fb(p, h, positions)
                cache = {}
            else:  # dec
                h, cache = fb(p, h, positions, enc_h)
            entries.append(cache)
        self.pool.write_prefill_range(lo - self.a, hi - self.a, row,
                                      entries, S)
        return h

    def prefill_rows(self, h_rows, layer_active, offset: int = 0,
                     phase: str = "all", emb0_rows=None, enc_rows=None):
        """THE batched prefill: one jitted call prefills a (padded) prompt
        chunk starting at ``offset`` for every masked row, writing the
        chunk's state into the pool.  ``phase`` selects encoder vs
        non-encoder runs for enc-dec stacks (see make_pool_prefill_step)."""
        assert self.alive, f"server {self.sid} is dead"
        args = (h_rows,
                self._dummy if emb0_rows is None else emb0_rows,
                self._dummy if enc_rows is None else enc_rows,
                layer_active, self.layer_ids, offset, phase)
        if self.cache_layout == "paged":
            h_out, self.pool.tree = self._prefill_pool(
                self.run_params, self.shared, self.pool.tree,
                self.pool.page_table(), *args)
        else:
            h_out, self.pool.tree = self._prefill_pool(
                self.run_params, self.shared, self.pool.tree, *args)
        return h_out

    def decode_rows(self, h_rows, pos_rows, layer_active, emb0_rows=None,
                    enc_len_rows=None):
        """THE batched step: one jitted call decodes all masked rows.

        The pool tree is donated into the step (cache updated in place);
        the stale input tree is rebound here and must never be read again
        — see docs/serving.md "Round anatomy" for the aliasing contract."""
        assert self.alive, f"server {self.sid} is dead"
        args = (h_rows, pos_rows,
                self._dummy if emb0_rows is None else emb0_rows,
                self._zero_encl if enc_len_rows is None else enc_len_rows,
                layer_active, self.layer_ids)
        if self.cache_layout == "paged":
            h_out, self.pool.tree = self._step(
                self.run_params, self.shared, self.pool.tree,
                self.pool.page_table(), *args)
        else:
            h_out, self.pool.tree = self._step(
                self.run_params, self.shared, self.pool.tree, *args)
        return h_out

    def round_rows(self, h_round, pos_round, encl_round, slot_of_row,
                   row_of_slot, layer_active, emb0_round=None):
        """The fused device-resident hop: gather this server's rows out of
        the round buffers, decode them through the pooled step, scatter the
        results back — ONE dispatch, donated pool, no host transfer."""
        assert self.alive, f"server {self.sid} is dead"
        args = (h_round, pos_round,
                self._dummy if emb0_round is None else emb0_round,
                encl_round, slot_of_row, row_of_slot, layer_active,
                self.layer_ids)
        if self.cache_layout == "paged":
            h_round, self.pool.tree = self._round_step(
                self.run_params, self.shared, self.pool.tree,
                self.pool.page_table(), *args)
        else:
            h_round, self.pool.tree = self._round_step(
                self.run_params, self.shared, self.pool.tree, *args)
        return h_round

    def decode_range(self, sid: int, h, lo: int, hi: int, pos: int,
                     emb0=None, enc_len: int = 0):
        """Single-session decode of blocks [lo, hi) via the pooled step (the
        same program as the batched path — bit-for-bit identical).  Encoder
        blocks in the range are skipped (no decode-time work)."""
        lo = max(lo, self.n_enc)
        if lo >= hi:
            return h
        row = self.pool.rows[sid]
        N = self.pool.n_rows
        h_rows = jnp.zeros((N,) + h.shape[1:], h.dtype).at[row].set(h[0])
        pos_rows = jnp.zeros((N,), jnp.int32).at[row].set(pos)
        emb0_rows = None
        if emb0 is not None:
            emb0_rows = jnp.zeros((N,) + emb0.shape[1:],
                                  emb0.dtype).at[row].set(emb0[0])
        encl_rows = None
        if enc_len:
            encl_rows = self._zero_encl.at[row].set(enc_len)
        mask = np.zeros((self.m, N), bool)
        mask[lo - self.a: hi - self.a, row] = True
        h_out = self.decode_rows(h_rows, pos_rows, jnp.asarray(mask),
                                 emb0_rows, encl_rows)
        return h_out[row][None]

    # -- cost introspection -------------------------------------------------
    def decode_step_cost(self):
        """CostSummary of THE pooled decode step this server dispatches per
        round, from an ahead-of-time lowering+compile on abstract operands
        (no execution).  With a mesh the numbers are per-device after SPMD
        partitioning — the basis for the device-group τ calibration
        (``GeoServingSystem.calibrate_taus``)."""
        from repro.launch import costs as C

        def abst(x):
            return jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(
                    a.shape, a.dtype, sharding=getattr(a, "sharding", None)),
                x)

        N = self.pool.n_rows
        d = self.cfg.d_model
        act = jnp.dtype(getattr(self.cfg, "act_dtype", "float32"))
        h = jax.ShapeDtypeStruct((N, 1, d), act)
        pos = jax.ShapeDtypeStruct((N,), jnp.int32)
        emb0 = (jax.ShapeDtypeStruct((N, 1, d), act)
                if any(s.needs_emb0 for s in self.specs)
                else abst(self._dummy))
        encl = jax.ShapeDtypeStruct((N,), jnp.int32)
        la = jax.ShapeDtypeStruct((self.m, N), jnp.bool_)
        lids = abst(self.layer_ids)
        args = (abst(self.run_params), abst(self.shared),
                abst(self.pool.tree))
        if self.cache_layout == "paged":
            args += (abst(self.pool.page_table()),)
        args += (h, pos, emb0, encl, la, lids)
        compiled = self._step.lower(*args).compile()
        return C.summarize_compiled(compiled)


@dataclass
class _PrefillGroup:
    """Co-admitted sessions sharing one route, one prompt-length bucket and
    (enc-dec) one encoder length, prefilled together in chunk rounds
    through the pooled prefill step.

    ``bucket is None`` marks a chunked group: prompts longer than the
    largest bucket, processed in max-bucket-sized chunks that interleave
    with decode rounds (``GeoServingSystem.prefill_round``).
    """

    route: Route
    bucket: Optional[int]
    members: List[EngineSession]
    enc_len: int = 0  # shared encoder length (enc-dec groups)
    offset: int = 0  # tokens prefilled so far (next chunk start)
    # per-sid per-hop activation chunks, stitched into the client-side
    # failover cache (EngineSession.hop_inputs) at completion
    hop_chunks: Dict[int, List[List[jnp.ndarray]]] = field(
        default_factory=dict)
    # per-sid per-hop ENC-phase inputs (enc-dec groups; None elsewhere)
    enc_inputs: Dict[int, List[Optional[jnp.ndarray]]] = field(
        default_factory=dict)


class GeoServingSystem:
    """Client-centric distributed inference with online BPRR and
    continuous batching across sessions — for both decode (one pooled step
    per server per round) and prefill (bucket groups of co-admitted
    sessions, padded to a shared prompt-length bucket).

    ``prefill_mode``: "batched" (default) coalesces same-round admissions
    into bucket groups; "serial" keeps the legacy one-session-per-call
    prefill — the bit-for-bit reference path for the batched one.
    ``prefill_buckets``: prompt-length buckets; prompts are right-padded to
    the smallest fitting bucket, and prompts longer than the largest bucket
    are prefilled in max-bucket-sized chunks that interleave with decode
    rounds.  Defaults to powers of two up to ``max_seq_len`` (no chunking).
    Stacks with recurrent state (rwkv, zamba2 hybrids) always prefill at
    the exact prompt length — grouping batches equal lengths instead.
    ``max_enc_len``: cross-KV pool capacity for enc-dec stacks (defaults to
    ``max_seq_len``).
    ``decode_mode``: "fused" (default) keeps each decode round resident on
    device end to end — ONE batched embed dispatch, one fused
    gather+step+scatter dispatch per (hop, server), ONE fused
    lm_head+sample tail, and a single host sync on the round's token
    vector; "serial" is the pre-refactor reference path (per-session embed
    and lm_head, host-staged row buffers between hops) kept for
    round-for-round comparison and as the per-session throughput baseline.
    Token streams, admission, and the virtual clock are identical between
    the two modes; logits agree to float-ulp (the fused tail projects all
    round slots in one GEMM, whose per-row reduction order XLA may pick
    differently than the width-1 reference — see ``make_round_tail``).
    Within ONE mode, solo-vs-grouped stays bit-exact: the fused round's
    fixed-width buffers make it structural, exactly like the pooled step.
    ``backend``: compute backend for every pooled step — ``"xla"`` (default;
    the oracle paths, runs everywhere) or ``"pallas"`` (the
    ``repro.kernels`` TPU kernels; interpret mode off-TPU).  Dispatch is
    per block call: a kernel whose ``*_unsupported`` predicate rejects the
    call's feature set falls back to the XLA path, so backend choice can
    never change which features work — and round RESULTS (token streams,
    admission, virtual clock) are backend-independent (logits agree to
    float-eps; see docs/serving.md).
    ``cache_layout``: ``"slab"`` (default) books worst-case fixed-width
    cache rows at admission — the exact reference twin; ``"paged"`` books
    ``page_size``-token pages instead (page-granular eq. (5)/(20)
    accounting, see docs/serving.md "Paged pools"): admission charges only
    the prompt's pages, sessions grow page-by-page during decode, and
    under page pressure the engine PREEMPTS a victim session (its pages
    are freed; its client-side hop histories remain) and later resumes it
    through the failover-replay machinery — token streams are bit-identical
    to the slab layout and to an unpreempted run, and the virtual clock
    differs from them by EXACTLY the billed resume-replay cost (zero when
    nothing was preempted).
    ``page_size``: tokens per page; must divide ``max_seq_len`` (defaults
    to the largest divisor ≤ 16).
    ``fault_plan`` / ``detector``: deterministic fault injection on the
    virtual clock and the timeout/backoff policy that prices failure
    detection — see docs/concurrency.md "Failure model".
    """

    def __init__(self, cfg: ModelConfig, params, problem: Problem,
                 algorithm: str = "proposed", R: Optional[int] = None,
                 max_new_tokens: int = 64, max_sessions: int = 8,
                 max_seq_len: Optional[int] = None,
                 prefill_mode: str = "batched",
                 prefill_buckets: Optional[Tuple[int, ...]] = None,
                 max_enc_len: Optional[int] = None,
                 decode_mode: str = "fused",
                 backend: str = "xla",
                 cache_layout: str = "slab",
                 page_size: Optional[int] = None,
                 mesh=None, mesh_rules=None, device_groups=None,
                 fault_plan: Optional[FaultPlan] = None,
                 detector: Optional[FailureDetector] = None):
        from repro.kernels.runtime import resolve_backend

        assert problem.L == cfg.n_layers
        assert prefill_mode in ("batched", "serial"), prefill_mode
        assert decode_mode in ("fused", "serial"), decode_mode
        assert cache_layout in ("slab", "paged"), cache_layout
        self.backend = resolve_backend(backend)
        self.cfg = cfg
        self.params = params
        self.problem = problem
        # Optional device-group serving.  Two spellings:
        #   * `device_groups={server_id: DeviceGroup | Mesh | None}` — the
        #     heterogeneous form: every BlockServer shards over ITS OWN
        #     group (missing / None entries are the solo-device twin), so a
        #     2-device TP server and a 4-device EP server coexist and
        #     calibrate_taus() yields a genuinely per-server τ vector;
        #   * the legacy `mesh=` (+ optional `mesh_rules=`) kwarg — sugar
        #     that broadcasts ONE group to all servers, byte-identical to
        #     the old global-mesh behavior.
        # `mesh_rules` overrides the derived logical-axis rules (see
        # launch.sharding.serving_rules); accepted as a dict or a frozen
        # tuple-of-pairs.
        from repro.launch.sharding import DeviceGroup, as_device_group

        if device_groups is not None and mesh is not None:
            raise ValueError(
                "pass either device_groups= or the global mesh= sugar, "
                "not both")
        self.mesh = mesh
        if mesh_rules is not None and not isinstance(mesh_rules, tuple):
            from repro.launch.sharding import freeze_rules
            mesh_rules = freeze_rules(dict(mesh_rules))
        self.mesh_rules = mesh_rules
        if device_groups is not None:
            self.device_groups = {int(j): as_device_group(g)
                                  for j, g in device_groups.items()}
        elif mesh is not None:
            g = DeviceGroup(mesh=mesh, rules=mesh_rules)
            self.device_groups = {j: g for j in range(problem.n_servers)}
        else:
            self.device_groups = {}
        self.algorithm = algorithm
        self.max_new_tokens = max_new_tokens
        self.max_sessions = int(max_sessions)
        self.max_seq_len = int(
            max_seq_len if max_seq_len is not None
            else problem.workload.l_in + max_new_tokens + 32)
        self.cache_layout = cache_layout
        if cache_layout == "paged":
            if page_size is None:  # largest divisor of max_seq_len <= 16
                page_size = next(p for p in range(min(16, self.max_seq_len),
                                                  0, -1)
                                 if self.max_seq_len % p == 0)
            page_size = int(page_size)
            if page_size < 1 or self.max_seq_len % page_size != 0:
                raise ValueError(
                    f"page_size {page_size} must divide max_seq_len "
                    f"{self.max_seq_len}")
        else:
            page_size = 0
        self.page_size = page_size
        # FIFO resume queue + preemption bookkeeping (paged layout)
        self._preempt_order: List[int] = []
        self.prefill_mode = prefill_mode
        self.specs = state_specs(cfg)
        self._recurrent = any(s.recurrent for s in self.specs)
        self._needs_emb0 = any(s.needs_emb0 for s in self.specs)
        self._n_enc = int(cfg.n_enc_layers)
        self._is_enc_dec = cfg.is_enc_dec
        self.max_enc_len = int(max_enc_len) if max_enc_len is not None \
            else self.max_seq_len
        if prefill_buckets is None:
            prefill_buckets = default_prefill_buckets(self.max_seq_len)
        self.prefill_buckets = tuple(sorted(
            {min(int(b), self.max_seq_len) for b in prefill_buckets}))
        assert self.prefill_buckets, "prefill_buckets must be non-empty"
        self._prefill_groups: List[_PrefillGroup] = []
        if algorithm == "proposed":
            from repro.core.placement import auto_R, cg_bp
            self.R = R if R is not None else auto_R(problem, 0.1, 60.0)
            self.placement, _ = cg_bp(problem, self.R)
        else:
            self.R = R
            self.placement = petals_bp(problem)
        self.servers: Dict[int, BlockServer] = {}
        self._build_servers()
        self.sessions: Dict[int, EngineSession] = {}
        self._sid = 0
        self._embed = jax.jit(
            lambda emb, tok: embed_tokens(emb, cfg, NULL_SH, tok))
        self._embed_frames = jax.jit(
            lambda emb, fr: embed_frames(emb, cfg, NULL_SH, fr))
        self._lm_head = jax.jit(
            lambda emb, h: lm_head(emb, cfg, NULL_SH, h))
        self._sampler = make_sampler()
        self.decode_mode = decode_mode
        self._round_tail = make_round_tail(cfg)
        # fixed round width: the device-resident round buffers span W slots
        # whatever the round's membership, so the fused programs trace once
        # and per-session results are bit-identical solo or grouped.  Grown
        # (rare re-trace) if a round ever exceeds it.
        self._round_width = max(1, self.max_sessions)
        # per-round dispatch accounting (the perf contract: ONE embed, ONE
        # lm_head+sample tail, one fused dispatch per (hop, server), ONE
        # host sync — tests/test_round_fusion.py asserts against this)
        self.round_stats = {"rounds": 0, "embed_dispatches": 0,
                            "tail_dispatches": 0, "hop_dispatches": 0,
                            "preemptions": 0, "resumes": 0,
                            "detections": 0, "retries": 0, "replays": 0,
                            "rejoins": 0, "dispatch_errors": 0,
                            "detect_s": 0.0, "backoff_s": 0.0,
                            "replay_s": 0.0}
        # fault injection: a seedable FaultPlan drives crashes / rejoins /
        # stragglers / dispatch errors on the virtual clock via
        # ``apply_faults(now)``; the detector prices timeout detection +
        # backoff (docs/concurrency.md "Failure model")
        self.fault_plan = fault_plan
        self.detector = detector if detector is not None else \
            FailureDetector()
        self._fault_cursor = 0
        # servers with a pending one-shot admission-dispatch fault
        self._dispatch_faults: set = set()
        # calibration-time taus: set_slowdown() factors are ABSOLUTE
        # multipliers over these, so straggler intervals restore cleanly
        self._base_taus = [float(s.tau) for s in problem.servers]

    # ------------------------------------------------------------------
    def _cap_slots(self, j: int, m: int) -> int:
        spec = self.problem.servers[j]
        cap = int(np.floor(
            (spec.mem_bytes - self.problem.s_m * m) / self.problem.s_c))
        return max(cap, 0)

    def _build_servers(self):
        for j in range(self.problem.n_servers):
            a, m = int(self.placement.a[j]), int(self.placement.m[j])
            if m <= 0:
                continue
            if j in self.servers:
                continue  # keep live objects (running sessions hold caches)
            cap = self._cap_slots(j, m)
            # pool arrays need >= 1 row for fixed jit shapes, but the
            # block-slot budget stays honest: cap == 0 admits nothing.
            # Paged layout: rows are cheap (the expensive self-KV bytes
            # live in the shared page arrays), so every session the engine
            # could ever co-host gets a row — co-residency is then bounded
            # by the page-unit budget, not the worst-case slot count.
            if self.cache_layout == "paged":
                n_rows = max(1, self.max_sessions)
            else:
                n_rows = max(1, min(self.max_sessions, cap))
            self.servers[j] = BlockServer(
                j, self.cfg, self.params, a, m, n_rows=n_rows,
                max_len=self.max_seq_len, cap_slots=cap,
                enc_len=self.max_enc_len if self._is_enc_dec else 0,
                backend=self.backend, cache_layout=self.cache_layout,
                page_size=self.page_size,
                group=self.device_groups.get(j))

    def alive_placement(self) -> Placement:
        a = np.array(self.placement.a)
        m = np.array(self.placement.m)
        for j in range(len(m)):
            if j in self.servers and not self.servers[j].alive:
                m[j] = 0
            if j not in self.servers:
                m[j] = 0
        return Placement(a=a, m=m)

    # ------------------------------------------------------------------
    # τ calibration from the (sharded) pooled step
    # ------------------------------------------------------------------
    def calibrate_taus(self) -> Dict[int, float]:
        """Per-server τ (per-block per-token decode seconds, eq. (1))
        derived from each server's ACTUAL pooled decode step: AOT
        lowering + compile, ``launch.costs`` roofline over the per-device
        cost analysis.  With device groups, each server's step is ITS OWN
        SPMD-partitioned program over its own ``srv.n_chips`` devices, so
        a heterogeneous deployment (solo next to 2-device TP next to
        4-device EP) yields a genuinely non-constant τ vector — that
        heterogeneity flows straight into the perf model the placement
        (MILP/CG-BP), eq. (20) routing, and the simulator consume."""
        from repro.launch import costs as C

        taus = {}
        for j, srv in self.servers.items():
            cost = srv.decode_step_cost()
            taus[j] = C.tau_from_step_cost(cost, srv.n_chips, srv.m,
                                           srv.pool.n_rows)
        return taus

    def calibrated_problem(self) -> Problem:
        """A copy of ``self.problem`` whose server τs come from
        :meth:`calibrate_taus` — feed it back into placement / the
        simulator.  The live engine's virtual clock keeps the original
        problem: swapping τ mid-flight would break the mesh-vs-reference
        parity contract."""
        from repro.core.perf_model import with_server_taus

        return with_server_taus(self.problem, self.calibrate_taus())

    # ------------------------------------------------------------------
    # Session lifecycle (continuous batching API)
    # ------------------------------------------------------------------
    def create_session(self, tokens: np.ndarray, client: int, route: Route,
                       n_new: int, arrival: float = 0.0,
                       frames: Optional[np.ndarray] = None,
                       sampling: Optional[SamplingSpec] = None) -> int:
        """Register an admitted session (no compute, no slots yet).

        ``frames``: (S_enc, frame_dim) encoder input — required for enc-dec
        stacks, rejected otherwise.  ``sampling``: per-session token policy
        (defaults to greedy)."""
        S = len(tokens)
        if S + n_new > self.max_seq_len:
            raise ValueError(
                f"prompt {S} + n_new {n_new} exceeds max_seq_len "
                f"{self.max_seq_len}; raise max_seq_len at engine build")
        enc_len = 0
        if self._is_enc_dec:
            if frames is None:
                raise ValueError(
                    "enc-dec stacks need encoder `frames` per session")
            frames = np.asarray(frames)
            if frames.ndim != 2 or frames.shape[1] != self.cfg.frame_dim:
                raise ValueError(
                    f"frames must be (S_enc, {self.cfg.frame_dim}); got "
                    f"{frames.shape}")
            enc_len = int(frames.shape[0])
            if enc_len > self.max_enc_len:
                raise ValueError(
                    f"encoder input {enc_len} exceeds max_enc_len "
                    f"{self.max_enc_len}; raise max_enc_len at engine build")
        elif frames is not None:
            raise ValueError("`frames` is only meaningful for enc-dec stacks")
        sid = self._sid
        self._sid += 1
        self.sessions[sid] = EngineSession(
            sid=sid, client=client, route=route, prompt_len=S, n_new=n_new,
            arrival=arrival, tokens=[int(t) for t in np.asarray(tokens)],
            hop_inputs=[[] for _ in route.servers],
            sampling=sampling if sampling is not None else SamplingSpec(),
            frames=frames, enc_len=enc_len)
        return sid

    def _prompt_pages(self, sess: EngineSession) -> int:
        """Pages booked at admission: enough for the prompt (paged)."""
        return pages_for(sess.prompt_len, self.page_size)

    def _worst_pages(self, sess: EngineSession) -> int:
        """Fully-grown page count — the solo-completability bound admission
        asserts so preempted sessions can always eventually resume."""
        return pages_for(sess.prompt_len + sess.n_new, self.page_size)

    def fits_session(self, sid: int) -> bool:
        """True iff every route server has a free row AND block-slots
        (slab) / prompt pages plus solo-completability headroom (paged)
        for this session (no-overbooking check)."""
        sess = self.sessions[sid]
        if self.cache_layout == "paged":
            p, w = self._prompt_pages(sess), self._worst_pages(sess)
            return all(self.servers[j].alive
                       and self.servers[j].fits(sid, k, p, w)
                       for j, k in zip(sess.route.servers,
                                       sess.route.blocks))
        return all(self.servers[j].alive and self.servers[j].fits(sid, k)
                   for j, k in zip(sess.route.servers, sess.route.blocks))

    def try_admit_session(self, sid: int, now: float = 0.0) -> bool:
        """Claim slots and run the prefill to completion (synchronous
        single-session admission; any other pending prefill groups are also
        driven to completion).  Returns False (and claims nothing) when
        some server's pool is exhausted — the caller defers and re-admits
        after a retirement."""
        ok = self.try_admit_sessions([sid], now=now)
        if ok:
            self.drain_prefill()
        return bool(ok)

    def try_admit_sessions(self, sids: List[int], now: float = 0.0
                           ) -> List[int]:
        """Claim slots for every session that fits and coalesce the admitted
        ones into bucket groups for batched prefill.  Returns the admitted
        sids; the rest claimed nothing (the caller defers them).

        Within one batch, admission is FIFO per client: once an earlier
        session of a client fails to fit, later sessions of the same client
        are not attempted (they would otherwise overtake it).

        Prefill compute does NOT run here — the caller advances it with
        :meth:`prefill_round` (interleaving decode rounds between chunks) or
        :meth:`drain_prefill`.  In ``prefill_mode="serial"`` the legacy
        one-session-at-a-time prefill runs immediately instead.
        """
        admitted: List[EngineSession] = []
        failed_clients: set = set()
        for sid in sids:
            sess = self.sessions[sid]
            # one-shot admission-dispatch fault (FaultPlan kind
            # "dispatch_error"): the admit RPC through a faulted server
            # fails once; the caller defers and retries like a full pool
            faulted = [j for j in sess.route.servers
                       if j in self._dispatch_faults]
            if faulted:
                self._dispatch_faults.difference_update(faulted)
                self.round_stats["dispatch_errors"] += 1
                failed_clients.add(sess.client)
                continue
            if sess.client in failed_clients or not self.fits_session(sid):
                failed_clients.add(sess.client)
                continue
            n_pages = (self._prompt_pages(sess)
                       if self.cache_layout == "paged" else 0)
            for j, k in zip(sess.route.servers, sess.route.blocks):
                self.servers[j].admit(sid, k, n_pages=n_pages)
            sess.start = now
            admitted.append(sess)
        if not admitted:
            return []
        if self.prefill_mode == "serial":
            for sess in admitted:
                self._prefill_serial(sess)
                self._finalize_prefill(sess, sess._h[:, -1:])
            return [s.sid for s in admitted]
        # batched: group by (route, bucket[, enc_len]).  Stacks with
        # recurrent state (rwkv, mamba) use the EXACT prompt length as the
        # bucket (no padding, no chunking — bucket_for's family rule);
        # attention-family prompts longer than the largest bucket go to the
        # chunked group of their route (bucket None).
        groups: Dict[Tuple[Route, Optional[int], int],
                     List[EngineSession]] = {}
        for sess in admitted:
            sess.state = "prefilling"
            b = bucket_for(self.prefill_buckets, sess.prompt_len, self.specs)
            groups.setdefault((sess.route, b, sess.enc_len),
                              []).append(sess)
        for (route, b, enc_len), members in groups.items():
            self._prefill_groups.append(_PrefillGroup(
                route=route, bucket=b, members=members, enc_len=enc_len,
                hop_chunks={s.sid: [[] for _ in route.servers]
                            for s in members},
                enc_inputs={s.sid: [None] * len(route.servers)
                            for s in members}))
        return [s.sid for s in admitted]

    # -- batched prefill ------------------------------------------------
    def has_pending_prefill(self) -> bool:
        """True while some admitted session still has prompt chunks left."""
        return bool(self._prefill_groups)

    def prefill_round(self) -> List[int]:
        """Advance every pending bucket group by ONE chunk round (all hops).
        Sessions whose prompt completes become active and emit their first
        token.  Returns their sids.  Callers interleave this with
        :meth:`decode_round` so long chunked prompts do not head-of-line
        block resident sessions."""
        done: List[int] = []
        still: List[_PrefillGroup] = []
        for g in self._prefill_groups:
            done.extend(self._prefill_group_round(g))
            if any(s.state == "prefilling" and s.prompt_len > g.offset
                   for s in g.members):
                still.append(g)
        self._prefill_groups = still
        return done

    def drain_prefill(self):
        """Run prefill rounds until no admitted session is mid-prompt."""
        while self._prefill_groups:
            self.prefill_round()

    def _prefill_plan(self, prompt_len: int) -> List[Tuple[int, int, int]]:
        """Deterministic chunk plan [(offset, span, t_pad), ...] for one
        prompt — a function of the prompt length ONLY (never of group
        co-members), so a session runs the exact same pooled programs
        whether admitted alone or inside a bucket group, and failover
        replay can rebuild bit-identical caches from the plan."""
        if self._recurrent:  # order-sensitive state: exact length, one shot
            return [(0, prompt_len, prompt_len)]
        b = bucket_for(self.prefill_buckets, prompt_len)
        if b is not None:
            return [(0, prompt_len, min(b, self.max_seq_len))]
        chunk_unit = max(self.prefill_buckets)
        plan: List[Tuple[int, int, int]] = []
        off = 0
        while off < prompt_len:
            t_pad = min(chunk_unit, self.max_seq_len - off)
            plan.append((off, min(prompt_len - off, t_pad), t_pad))
            off += t_pad
        return plan

    def _prefill_enc_phase(self, g: _PrefillGroup,
                           active: List[EngineSession]):
        """One exact-length pooled pass over the encoder blocks of a group's
        route (enc-dec stacks; runs once, before the first decoder chunk).
        Leaves each member's encoder output on ``sess.enc_out``."""
        for s in active:
            s._h = self._embed_frames(
                self.params["embed"],
                jnp.asarray(s.frames, jnp.float32)[None])
        e = 0
        for hop, (j, k) in enumerate(zip(g.route.servers, g.route.blocks)):
            if e >= self._n_enc:
                break
            srv = self.servers[j]
            lo, hi = e, min(e + k, self._n_enc)
            N = srv.pool.n_rows
            d = active[0]._h.shape[-1]
            h_buf = np.zeros((N, g.enc_len, d), np.asarray(active[0]._h).dtype)
            mask = np.zeros((srv.m, N), bool)
            for s in active:
                row = srv.pool.rows[s.sid]
                g.enc_inputs[s.sid][hop] = s._h
                h_buf[row] = np.asarray(s._h[0])
                mask[lo - srv.a: hi - srv.a, row] = True
            h_out = srv.prefill_rows(jnp.asarray(h_buf), jnp.asarray(mask),
                                     offset=0, phase="enc")
            for s in active:
                s._h = h_out[srv.pool.rows[s.sid]][None]
            e += k
        for s in active:
            s.enc_out = s._h

    def _prefill_group_round(self, g: _PrefillGroup) -> List[int]:
        """One chunk round for one bucket group: embed the (padded) token
        chunk of every member, run the pooled prefill step per hop, account
        the virtual clock, and finalize members whose prompt completed.

        A route server lost mid-prefill (dead, crashed, or wiped by a
        rejoin) fails the group's in-flight members with a machine-readable
        reason: the per-hop input histories that failover replay needs are
        only complete at prompt completion, so there is nothing to splice
        from yet.  Crashed-but-undetected servers bill timeout detection on
        the members first (their dispatch is what discovers the loss);
        sessions in other groups and already-active sessions are untouched
        and keep their bit-exact streams."""
        active = [s for s in g.members
                  if s.state == "prefilling" and s.prompt_len > g.offset]
        if not active:
            return []
        lost = [j for j in g.route.servers
                if j not in self.servers or not self.servers[j].alive
                or self.servers[j].crashed
                or any(s.sid not in self.servers[j].pool.rows
                       for s in active)]
        if lost:
            for j in lost:
                srv = self.servers.get(j)
                if srv is not None and srv.alive and srv.crashed:
                    self._detect_crash(j, [
                        (s, self._expected_hop_prefill(s, j))
                        for s in active])
            for s in active:
                self._abort_session(s, reason="server_lost_mid_prefill")
            return []
        # this round's padded width comes from the SAME plan failover replay
        # uses (any active member's plan has an entry at g.offset, and t_pad
        # is session-independent by construction) — one source of truth for
        # the chunk schedule
        ref_len = max(s.prompt_len for s in active)
        t_pad = next(tp for off, _, tp in self._prefill_plan(ref_len)
                     if off == g.offset)
        spans = {s.sid: min(s.prompt_len - g.offset, t_pad) for s in active}
        if self._is_enc_dec and g.offset == 0:
            self._prefill_enc_phase(g, active)
        for s in active:
            chunk = s.tokens[g.offset: g.offset + spans[s.sid]]
            chunk = chunk + [0] * (t_pad - len(chunk))
            s._h = self._embed(self.params["embed"],
                               jnp.asarray([chunk], jnp.int32))
            if self._needs_emb0:
                s._emb0 = s._h
        e = 0
        phase = "dec" if self._is_enc_dec else "all"
        for hop, (j, k) in enumerate(zip(g.route.servers, g.route.blocks)):
            srv = self.servers[j]
            lo, hi = max(e, self._n_enc), e + k
            if lo < hi:  # hop hosts decode-phase blocks
                N = srv.pool.n_rows
                d = active[0]._h.shape[-1]
                dt = np.asarray(active[0]._h).dtype
                h_buf = np.zeros((N, t_pad, d), dt)
                emb0_buf = (np.zeros((N, t_pad, d), dt)
                            if self._needs_emb0 else None)
                enc_buf = None
                if self._is_enc_dec:
                    enc_buf = np.zeros(
                        (N, g.enc_len, d),
                        np.asarray(active[0].enc_out).dtype)
                mask = np.zeros((srv.m, N), bool)
                for s in active:
                    row = srv.pool.rows[s.sid]
                    # client-side failover cache: the UNPADDED chunk
                    # entering this hop (stitched to the full prompt at
                    # completion)
                    g.hop_chunks[s.sid][hop].append(s._h[:, : spans[s.sid]])
                    h_buf[row] = np.asarray(s._h[0])
                    if emb0_buf is not None:
                        emb0_buf[row] = np.asarray(s._emb0[0])
                    if enc_buf is not None:
                        enc_buf[row] = np.asarray(s.enc_out[0])
                    mask[lo - srv.a: hi - srv.a, row] = True
                h_out = srv.prefill_rows(
                    jnp.asarray(h_buf), jnp.asarray(mask), offset=g.offset,
                    phase=phase,
                    emb0_rows=(None if emb0_buf is None
                               else jnp.asarray(emb0_buf)),
                    enc_rows=(None if enc_buf is None
                              else jnp.asarray(enc_buf)))
                for s in active:
                    s._h = h_out[srv.pool.rows[s.sid]][None]
            # Virtual clock, consistent with eq. (1): the group's chunk
            # travels the hop as ONE message — its members share a single
            # RTT — and each session is charged its own (weighted) k·τ^I of
            # block compute (member rows overlap inside the pooled step).
            # The accounting is family-agnostic like the paper's model:
            # encoder blocks bill their prefill compute here even though
            # they do no decode-phase work.  Per-session latency therefore
            # equals the serial eq. (1) value for unchunked groups; chunked
            # prompts pay one RTT per chunk per hop plus τ^I evaluated at
            # the actual chunk length.
            # unchunked groups bill the workload's nominal l_in (like the
            # simulator); chunked prompts bill the actual span.  Encoder-
            # only hops are traversed exactly once (the encoder phase, at
            # offset 0), so later chunk rounds do not bill them again.
            if lo < hi or g.offset == 0:
                for s in active:
                    tau = self.problem.servers[j].tau_prefill(
                        self.problem.workload.l_in if g.bucket is not None
                        else spans[s.sid])
                    s.prefill_time += (
                        self.problem.rtt_prefill[s.client, j]
                        + self.problem.llm.tau_weight(e, e + k)
                        * tau * srv.slowdown)
            e += k
        g.offset += t_pad
        done: List[int] = []
        for s in active:
            if s.prompt_len <= g.offset:
                for hop in range(len(g.route.servers)):
                    parts = g.hop_chunks[s.sid][hop]
                    stitched = (None if not parts
                                else parts[0] if len(parts) == 1
                                else jnp.concatenate(parts, axis=1))
                    if self._is_enc_dec:
                        s.hop_inputs[hop].append(
                            {"enc": g.enc_inputs[s.sid][hop],
                             "dec": stitched})
                    else:
                        s.hop_inputs[hop].append(stitched)
                self._finalize_prefill(s, s._h[:, spans[s.sid] - 1:
                                               spans[s.sid]])
                done.append(s.sid)
        return done

    def _prefill_serial(self, sess: EngineSession):
        """Legacy one-session-per-call prefill — the exact-length reference
        path for the bucketed one (identical token streams; the bucketed
        path's *structural* bit guarantee is solo-vs-group through the same
        pooled program): per-layer block calls, eq. (1) accounting."""
        if self._is_enc_dec:
            eh = self._embed_frames(
                self.params["embed"],
                jnp.asarray(sess.frames, jnp.float32)[None])
            enc_recs: List[Optional[jnp.ndarray]] = \
                [None] * len(sess.route.servers)
            e = 0
            for hop, (j, k) in enumerate(zip(sess.route.servers,
                                             sess.route.blocks)):
                if e >= self._n_enc:
                    break
                lo, hi = e, min(e + k, self._n_enc)
                enc_recs[hop] = eh
                eh = self.servers[j].prefill_range(
                    sess.sid, eh, lo, hi, jnp.arange(sess.enc_len))
                e += k
            sess.enc_out = eh
        prompt = jnp.asarray(sess.tokens[: sess.prompt_len],
                             jnp.int32)[None, :]
        h = self._embed(self.params["embed"], prompt)
        emb0 = h if self._needs_emb0 else None
        positions = jnp.arange(sess.prompt_len)
        e = 0
        for hop, (j, k) in enumerate(zip(sess.route.servers,
                                         sess.route.blocks)):
            srv = self.servers[j]
            lo, hi = max(e, self._n_enc), e + k
            if self._is_enc_dec:
                sess.hop_inputs[hop].append(
                    {"enc": enc_recs[hop], "dec": h if lo < hi else None})
            else:
                sess.hop_inputs[hop].append(h)
            if lo < hi:
                h = srv.prefill_range(sess.sid, h, lo, hi, positions,
                                      emb0=emb0, enc_h=sess.enc_out)
            sess.prefill_time += (
                self.problem.rtt_prefill[sess.client, j]
                + self.problem.llm.tau_weight(e, e + k)
                * self.problem.servers[j].tau_prefill(
                    self.problem.workload.l_in) * srv.slowdown)
            e += k
        sess._h = h

    def _finalize_prefill(self, sess: EngineSession, h_last):
        """Prefill done: close the virtual-clock accounting and emit the
        first generated token from the prompt's last-position logits via
        the session's sampling policy."""
        sess.pos = sess.prompt_len
        sess.virtual_time += sess.prefill_time
        sess.per_token_time = self._route_per_token(sess)
        sess.state = "active"
        sess.end = (sess.start + sess.prefill_time
                    + max(sess.n_new - 1, 0) * sess.per_token_time)
        logits = self._lm_head(self.params["embed"], h_last)
        sess.last_logits = logits[0, 0]
        sess.tokens.append(self._sample_tokens([sess])[0])
        sess.n_generated = 1
        sess._h = None
        sess._emb0 = None

    def _sample_tokens(self, sessions: List[EngineSession]) -> List[int]:
        """One vmapped sampler call for a round's sessions: per-row
        (temperature, top_k, key) inputs — policies vary per session
        without retracing.  Session ``s`` draws the key for token index
        ``s.n_generated`` (deterministic per (seed, index))."""
        logits = jnp.stack([s.last_logits for s in sessions])
        temps, topks, keys = [], [], []
        for s in sessions:
            t, k = s.sampling.row_params()
            temps.append(t)
            topks.append(k)
            keys.append(s.sampling.key_for(s.n_generated))
        toks = self._sampler(logits, jnp.asarray(temps, jnp.float32),
                             jnp.asarray(topks, jnp.int32), jnp.stack(keys))
        return [int(t) for t in np.asarray(toks)]

    def _route_per_token(self, sess: EngineSession) -> float:
        t = 0.0
        e = 0
        for j, k in zip(sess.route.servers, sess.route.blocks):
            t += (self.problem.rtt_token[sess.client, j]
                  + self.problem.llm.tau_weight(e, e + k)
                  * self.problem.servers[j].tau
                  * self.servers[j].slowdown)
            e += k
        return t

    # ------------------------------------------------------------------
    # Timeout-based failure detection (docs/concurrency.md "Failure model")
    # ------------------------------------------------------------------
    def _expected_hop_decode(self, sess: EngineSession, hop: int) -> float:
        """Eq. (1) expected decode hop time — the client's dispatch
        deadline is ``detector.timeout_factor`` times this."""
        j = sess.route.servers[hop]
        e_lo, e_hi = self._hop_span(sess, hop)
        return (self.problem.rtt_token[sess.client, j]
                + self.problem.llm.tau_weight(e_lo, e_hi)
                * self.problem.servers[j].tau * self.servers[j].slowdown)

    def _expected_hop_prefill(self, sess: EngineSession, j: int) -> float:
        """Expected prefill hop time for route server ``j`` (deadline
        basis when the loss is discovered mid-prefill)."""
        e = 0
        for jj, k in zip(sess.route.servers, sess.route.blocks):
            if jj == j:
                return (self.problem.rtt_prefill[sess.client, j]
                        + self.problem.llm.tau_weight(e, e + k)
                        * self.problem.servers[j].tau_prefill(
                            self.problem.workload.l_in)
                        * self.servers[j].slowdown)
            e += k
        return float(self.problem.rtt_prefill[sess.client, j])

    def _detect_crash(self, j: int, affected):
        """Declare crashed server ``j`` dead by timeout: every session in
        ``affected`` — ``(session, expected_hop_time)`` pairs, all of them
        concurrently blocked on the same silent server — bills the missed
        deadline plus ``max_probes`` backoff probes on its virtual clock,
        then the server is marked dead + suspected (routing penalty)."""
        srv = self.servers[j]
        backoff = self.detector.backoff_time()
        for sess, expected in affected:
            detect = self.detector.detect_time(expected)
            sess.detect_time += detect
            sess.backoff_time += backoff
            sess.virtual_time += detect + backoff
            sess.n_detections += 1
            sess.n_retries += self.detector.max_probes
            self.round_stats["detections"] += 1
            self.round_stats["retries"] += self.detector.max_probes
            self.round_stats["detect_s"] += detect
            self.round_stats["backoff_s"] += backoff
        srv.alive = False
        srv.suspected = True

    def _hop_needs_failover(self, sess: EngineSession, hop: int) -> bool:
        """A hop must be spliced when its server is gone (dead / removed)
        or no longer holds the session's cache row (it rejoined with an
        empty pool, or a resume skipped it while dead)."""
        j = sess.route.servers[hop]
        srv = self.servers.get(j)
        return (srv is None or not srv.alive
                or sess.sid not in srv.pool.rows)

    def decode_round(self, sids: Optional[List[int]] = None) -> Dict[int, int]:
        """One continuous-batching round: every listed active session (all
        unfinished active sessions when ``sids`` is None) advances one token
        through its route; co-resident sessions share ONE pooled step per
        (hop, server) group.  Returns {sid: new_token}.

        In ``decode_mode="fused"`` (default) the round is device-resident:
        one batched embed, one fused gather+step+scatter dispatch per
        (hop, server), one fused lm_head+sample tail, and a single host
        sync on the sampled token vector.  ``decode_mode="serial"`` runs
        the pre-refactor per-session reference — identical tokens, logits
        and virtual-clock accounting.

        Preempted sessions (paged page pressure, or a capacity-starved
        failover deferral on either layout) are resumed (FIFO) when they
        fit again — resume replay is billed on the virtual clock.  Paged
        layout additionally grows every decoding session's pages to cover
        the write position, preempting victims under page pressure (see
        ``preempt_session``)."""
        explicit = sids is not None
        if self.fault_plan is not None:
            # virtual-clock fault injection: events due by the round's
            # earliest member clock fire before the round dispatches
            clock = [s.virtual_time + s.start
                     for s in self.sessions.values()
                     if s.state in ("active", "preempted")]
            if clock:
                self.apply_faults(min(clock))
        self._resume_preempted()
        if sids is None:
            sids = [s.sid for s in self.sessions.values()
                    if s.state == "active" and s.n_generated < s.n_new]
        group = [self.sessions[sid] for sid in sids
                 if self.sessions[sid].state == "active"]
        if self.cache_layout == "paged":
            group = self._ensure_page_capacity(group)
        if not group and not explicit and any(
                s.state == "preempted" and s.n_generated < s.n_new
                for s in self.sessions.values()):
            # nothing resident could decode, but swapped-out sessions
            # still owe tokens: force-resume the queue head (evicting
            # finished-but-unretired holdouts) so the round makes
            # progress — admission's solo-fit bound guarantees the
            # oldest preempted session eventually fits
            self._resume_preempted(force=True)
            group = [s for s in self.sessions.values()
                     if s.state == "active" and s.n_generated < s.n_new]
            if self.cache_layout == "paged":
                group = self._ensure_page_capacity(group)
            if not group:
                # livelock guard: even a forced resume could not seat the
                # queue head (e.g. its failover replacement chain is
                # capacity-starved for good) — fail it with a reason so
                # the caller's drive loop terminates
                self._abort_stuck_head()
        if not group:
            return {}
        if self.decode_mode == "serial":
            return self._decode_round_serial(group)
        return self._decode_round_fused(group)

    # ------------------------------------------------------------------
    # Paged layout: page growth, preemption, resume
    # ------------------------------------------------------------------
    def _pick_victim(self, j: int, protect: set,
                     finished_only: bool = False) -> Optional[int]:
        """Choose a session to preempt on server ``j``: finished-but-
        unretired sessions first (their caches are dead weight — no replay
        ever needed), then the latest-admitted active session (LIFO — the
        earliest-admitted session always survives, so every round makes
        forward progress).  Mid-prefill sessions are never victims: their
        pages are exactly their in-flight prompt."""
        cands = []
        for sid in self.servers[j].pool.rows:
            if sid in protect:
                continue
            s = self.sessions.get(sid)
            if s is None or s.state != "active":
                continue
            finished = s.n_generated >= s.n_new
            if finished_only and not finished:
                continue
            cands.append((0 if finished else 1, -sid, sid))
        return min(cands)[2] if cands else None

    def preempt_session(self, sid: int):
        """Swap a session out under page pressure: free its rows/pages on
        EVERY route server.  The client-side artifacts that survive — hop
        input histories, tokens, ``enc_out``, the sampling policy — are
        exactly the failover-replay cache, so ``_try_resume`` can rebuild
        bit-identical server state later.  Swapping OUT is free, but the
        rebuild is real compute: ``_try_resume`` bills the replay (prompt
        prefill + k·τ per regenerated token per hop, eq. (1)) on the
        virtual clock, exactly like a failover replay."""
        sess = self.sessions[sid]
        assert sess.state == "active", sess.state
        sess.last_logits  # materialize a lazy fused-round logits box
        sess.state = "preempted"
        sess.n_preemptions += 1
        sess._h = None
        sess._emb0 = None
        for j in set(sess.route.servers):
            if j in self.servers:
                self.servers[j].evict(sid)
        self._preempt_order.append(sid)
        self.round_stats["preemptions"] += 1

    def _grow_session(self, sess: EngineSession, need: int,
                      protect: set) -> bool:
        """Grow ``sess`` to ``need`` pages on every route server,
        preempting victims under pressure.  False when even preempting
        every candidate cannot make room (the caller then self-preempts
        the session; partial growth is harmless — pages stay booked)."""
        for j, k in zip(sess.route.servers, sess.route.blocks):
            srv = self.servers.get(j)
            if srv is None or not srv.alive or sess.sid not in srv.pool.rows:
                continue  # dead / not-yet-resident hop: _failover re-books
            pool = srv.pool
            while not pool.can_grow(sess.sid, need):
                victim = self._pick_victim(j, protect)
                if victim is None:
                    return False
                self.preempt_session(victim)
            pool.grow_pages(sess.sid, need)
        return True

    def _ensure_page_capacity(self, group: List[EngineSession]
                              ) -> List[EngineSession]:
        """Before a decode round: every member needs pages covering its
        write position.  Members are grown oldest-first (admission order);
        one that cannot fit even after evicting every victim preempts
        ITSELF and retries in a later round.  Returns the surviving group
        in the caller's order."""
        kept: List[EngineSession] = []
        for sess in sorted(group, key=lambda s: s.sid):
            if sess.state != "active":  # preempted as a victim just now
                continue
            need = pages_for(sess.pos + 1, self.page_size)
            if self._grow_session(sess, need,
                                  protect={s.sid for s in kept}
                                  | {sess.sid}):
                kept.append(sess)
            else:
                self.preempt_session(sess.sid)
        order = {s.sid: i for i, s in enumerate(group)}
        return sorted(kept, key=lambda s: order[s.sid])

    def _resume_preempted(self, force: bool = False):
        """Resume swapped-out sessions in preemption (FIFO) order while
        they fit; stop at the first that does not (no overtaking — the
        queue head's admission-time solo-fit bound guarantees it
        eventually fits).  ``force``: additionally evict finished-but-
        unretired page holders to make room for the queue head."""
        while self._preempt_order:
            sid = self._preempt_order[0]
            sess = self.sessions.get(sid)
            if (sess is None or sess.state != "preempted"
                    or sess.n_generated >= sess.n_new):
                self._preempt_order.pop(0)  # retired / finished meanwhile
                continue
            if not self._try_resume(sess, evict_finished=force):
                return
            self._preempt_order.pop(0)
            force = False  # only the queue head gets the forced eviction

    def _try_resume(self, sess: EngineSession,
                    evict_finished: bool = False) -> bool:
        """Re-admit a preempted session on its route's ALIVE servers and
        replay its client-side history — each hop independently replays
        its own recorded inputs (prompt chunks through the deterministic
        chunk plan, then one pooled decode per generated token), exactly
        the failover machinery, so the rebuilt caches are bit-identical.
        The rebuild is billed on the virtual clock (the swap carve-out is
        gone): per replayed hop, one input round-trip + weighted prompt
        prefill + k·τ per regenerated token.  Dead route servers are
        skipped: the next traverse splices them out via ``_failover`` once
        the session is resident again."""
        paged = self.cache_layout == "paged"
        need = pages_for(max(sess.pos, 1), self.page_size) if paged else 0
        worst = self._worst_pages(sess) if paged else None
        e = 0
        hops = []  # (hop index, server, block range) of alive hops
        for hop, (j, k) in enumerate(zip(sess.route.servers,
                                         sess.route.blocks)):
            lo, hi = e, e + k
            e += k
            if j in self.servers and self.servers[j].alive:
                hops.append((hop, j, lo, hi))
        if not hops:
            # the whole route died while swapped out: resume holding
            # nothing — the next traverse's ``_failover`` splices a full
            # replacement chain (booking its own pages) from the client-
            # side history, exactly as for a resident session
            sess.state = "active"
            self.round_stats["resumes"] += 1
            return True
        for _, j, lo, hi in hops:
            while not self.servers[j].fits(sess.sid, hi - lo, need, worst):
                if not evict_finished:
                    return False
                victim = self._pick_victim(j, protect={sess.sid},
                                           finished_only=True)
                if victim is None:
                    return False
                self.preempt_session(victim)
        for _, j, lo, hi in hops:
            self.servers[j].admit(sess.sid, hi - lo, n_pages=need)
        self._replay_session(sess)
        # bill the rebuild: each replayed hop re-ran its prompt prefill
        # plus one decode step per recorded token (eq. (1) terms)
        cost = 0.0
        for hop, j, lo, hi in hops:
            n_tok = max(len(sess.hop_inputs[hop]) - 1, 0) \
                if max(lo, self._n_enc) < hi else 0
            cost += recovery_replay_cost(
                self.problem, sess.client, [(j, lo, hi)], n_tok,
                slowdown_of=lambda jj: self.servers[jj].slowdown)
        sess.replay_time += cost
        sess.virtual_time += cost
        sess.n_replays += 1
        sess.end = (sess.start + sess.virtual_time
                    + max(sess.n_new - sess.n_generated, 0)
                    * sess.per_token_time)
        self.round_stats["replays"] += 1
        self.round_stats["replay_s"] += cost
        sess.state = "active"
        self.round_stats["resumes"] += 1
        return True

    def _replay_session(self, sess: EngineSession):
        """Rebuild a preempted session's caches on its (alive) route
        servers from the client-side hop histories.  Unlike ``_failover``
        — which chains activations through a REPLACEMENT chain — every
        original hop has its own complete input history, so hops replay
        independently and the outputs are discarded."""
        S = sess.prompt_len
        e = 0
        for hop, (j, k) in enumerate(zip(sess.route.servers,
                                         sess.route.blocks)):
            e_lo, e_hi = e, e + k
            e += k
            if j not in self.servers or not self.servers[j].alive:
                continue
            rec = sess.hop_inputs[hop][0]
            if self._is_enc_dec:
                hs_enc = rec.get("enc") if isinstance(rec, dict) else None
                hs_dec = rec.get("dec") if isinstance(rec, dict) else rec
                self._replay_prefill_encdec(sess, j, e_lo, e_hi, hs_enc,
                                            hs_dec)
            else:
                self._replay_prefill_range(sess, j, e_lo, e_hi, rec)
            if max(e_lo, self._n_enc) >= e_hi:
                continue  # encoder-only hop: no decode records
            for t_idx, h_tok in enumerate(sess.hop_inputs[hop][1:]):
                h_tok = self._hop_record(h_tok)
                pos = S + t_idx
                emb0 = None
                if self._needs_emb0:
                    emb0 = self._embed(
                        self.params["embed"],
                        jnp.asarray([[sess.tokens[pos]]], jnp.int32))
                self.servers[j].decode_range(
                    sess.sid, h_tok, max(e_lo, self._n_enc), e_hi, pos,
                    emb0=emb0, enc_len=sess.enc_len)

    def _decode_round_serial(self, group: List[EngineSession]
                             ) -> Dict[int, int]:
        """The pre-refactor round: per-session embed / lm_head dispatches
        and host-staged row buffers between hops (``_traverse``).  Kept as
        the reference (identical tokens/clock, float-ulp logits) and the
        per-session throughput baseline for the fused path
        (``BENCH_engine.json`` ``decode.tput.*``)."""
        for sess in group:
            tok = jnp.asarray([[sess.tokens[-1]]], jnp.int32)
            sess._h = self._embed(self.params["embed"], tok)
            sess._emb0 = sess._h
        self._traverse(group)
        emit = [s for s in group if s.state == "active"]
        for sess in emit:  # aborted-by-failover sessions are excluded
            sess.pos += 1
            logits = self._lm_head(self.params["embed"], sess._h)
            sess.last_logits = logits[0, 0]
        out: Dict[int, int] = {}
        if emit:
            for sess, nxt in zip(emit, self._sample_tokens(emit)):
                sess.tokens.append(nxt)
                sess.n_generated += 1
                sess.virtual_time += sess.per_token_time
                sess._h = None
                sess._emb0 = None
                out[sess.sid] = nxt
        return out

    def _decode_round_fused(self, group: List[EngineSession]
                            ) -> Dict[int, int]:
        """Device-resident round over fixed-width (W, ...) buffers: the
        hidden states never leave the device between the embed and the
        round tail, and the ONLY host sync is the final batched token
        readback (one ``np.asarray``)."""
        if len(group) > self._round_width:  # rare: re-trace at the new W
            self._round_width = len(group)
        W = self._round_width
        slot = {s.sid: i for i, s in enumerate(group)}
        tok_buf = np.zeros((W, 1), np.int32)
        pos_buf = np.zeros((W,), np.int32)
        encl_buf = np.zeros((W,), np.int32)
        for i, s in enumerate(group):
            tok_buf[i, 0] = s.tokens[-1]
            pos_buf[i] = s.pos
            encl_buf[i] = s.enc_len
        h_round = self._embed(self.params["embed"], jnp.asarray(tok_buf))
        self.round_stats["embed_dispatches"] += 1
        emb0_round = h_round if self._needs_emb0 else None
        h_round = self._traverse_fused(group, slot, h_round,
                                       jnp.asarray(pos_buf), emb0_round,
                                       jnp.asarray(encl_buf))
        emit = [s for s in group if s.state == "active"]
        out: Dict[int, int] = {}
        if emit:
            temps = np.zeros((W,), np.float32)
            topks = np.zeros((W,), np.int32)
            # uint32: the full SamplingSpec.seed range (validated there)
            seeds = np.zeros((W,), np.uint32)
            tindex = np.zeros((W,), np.int32)
            for s in emit:
                g = slot[s.sid]
                temps[g], topks[g] = s.sampling.row_params()
                seeds[g] = s.sampling.seed
                tindex[g] = s.n_generated
            toks_dev, logits_rows = self._round_tail(
                self.params["embed"], h_round, jnp.asarray(temps),
                jnp.asarray(topks), jnp.asarray(seeds),
                jnp.asarray(tindex))
            self.round_stats["tail_dispatches"] += 1
            toks = np.asarray(toks_dev)  # THE one host sync of the round
            for s in emit:
                g = slot[s.sid]
                s.pos += 1
                s._logits_box = (logits_rows, g)  # lazy: sliced on read
                nxt = int(toks[g])
                s.tokens.append(nxt)
                s.n_generated += 1
                s.virtual_time += s.per_token_time
                out[s.sid] = nxt
        self.round_stats["rounds"] += 1
        return out

    def _hop_span(self, sess: EngineSession, hop: int) -> Tuple[int, int]:
        e_lo = sum(sess.route.blocks[:hop])
        return e_lo, e_lo + sess.route.blocks[hop]

    def _traverse_core(self, group: List[EngineSession], process_group):
        """THE decode traversal skeleton shared by the host-staged and
        device-resident paths: advance every session in ``group`` through
        its full route (one token's worth of work), batching per
        (hop, server).  Hops hosting only encoder blocks are skipped —
        they do no decode-time work (and need no failover: their blocks
        are stateless).  ``process_group(srv, members, progress)`` runs
        ONE (server, members) hop group — the only thing the two variants
        differ in — after which each member's progress advances."""
        progress = {s.sid: 0 for s in group}

        def skip_enc_hops(s):
            while (s.state == "active"
                   and progress[s.sid] < len(s.route.servers)):
                e_lo, e_hi = self._hop_span(s, progress[s.sid])
                if max(e_lo, self._n_enc) < e_hi:
                    return
                progress[s.sid] += 1

        while True:
            for s in group:
                skip_enc_hops(s)
            pending = [s for s in group
                       if s.state == "active"
                       and progress[s.sid] < len(s.route.servers)]
            if not pending:
                return
            # timeout detection first: a crashed-but-undetected server is
            # discovered by the dispatches that miss their deadline THIS
            # round — every session concurrently waiting on it bills the
            # detection wait + backoff probes, then the server is declared
            # dead (suspected) and the failovers below splice it out
            crashed_now = sorted({
                s.route.servers[progress[s.sid]] for s in pending
                if (srv := self.servers.get(
                    s.route.servers[progress[s.sid]])) is not None
                and srv.alive and srv.crashed})
            for j in crashed_now:
                self._detect_crash(j, [
                    (s, self._expected_hop_decode(s, progress[s.sid]))
                    for s in pending
                    if s.route.servers[progress[s.sid]] == j])
            # failover: splice routes of sessions facing a dead server or
            # one that lost their cache row (rejoined with an empty pool,
            # or a resume that skipped then-dead hops)
            for s in pending:
                hop = progress[s.sid]
                while self._hop_needs_failover(s, hop):
                    try:
                        self._failover(s, hop)
                    except NoCapacityError:
                        # transient: capacity frees as co-residents retire.
                        # Park the session in the resume queue instead of
                        # failing it; a lone legacy-decode session still
                        # propagates (its caller owns the retry).
                        if len(group) == 1:
                            raise
                        self._defer_session(s)
                        break
                    except RuntimeError:
                        # no surviving chain covers the blocks: fail it
                        # alone — co-resident sessions must keep decoding.
                        # A lone session propagates (legacy decode semantics).
                        if len(group) == 1:
                            raise
                        self._abort_session(s, reason="no_route")
                        break
            pending = [s for s in pending if s.state == "active"]
            groups: Dict[int, List[EngineSession]] = {}
            for s in pending:
                groups.setdefault(s.route.servers[progress[s.sid]],
                                  []).append(s)
            for j, members in groups.items():
                process_group(self.servers[j], members, progress)
                for s in members:
                    progress[s.sid] += 1

    def _traverse(self, group: List[EngineSession]):
        """Host-staged traversal (``decode_mode="serial"`` and the legacy
        per-session ``decode``): per-session hidden states are scattered
        into (N, ...) row buffers on the host before every hop.  The
        device-resident round uses ``_traverse_fused`` — same skeleton
        (``_traverse_core``), different hop-group body."""

        def process_group(srv, members, progress):
            N = srv.pool.n_rows
            d = members[0]._h.shape[-1]
            dt = np.asarray(members[0]._h).dtype
            h_buf = np.zeros((N, 1, d), dt)
            pos_buf = np.zeros((N,), np.int32)
            emb0_buf = (np.zeros((N, 1, d), dt)
                        if self._needs_emb0 else None)
            encl_buf = (np.zeros((N,), np.int32)
                        if self._is_enc_dec else None)
            mask = np.zeros((srv.m, N), bool)
            rows = {}
            for s in members:
                hop = progress[s.sid]
                row = srv.pool.rows[s.sid]
                e_lo, e_hi = self._hop_span(s, hop)
                lo = max(e_lo, self._n_enc)
                s.hop_inputs[hop].append(s._h)
                h_buf[row] = np.asarray(s._h[0])
                pos_buf[row] = s.pos
                if emb0_buf is not None:
                    emb0_buf[row] = np.asarray(s._emb0[0])
                if encl_buf is not None:
                    encl_buf[row] = s.enc_len
                mask[lo - srv.a: e_hi - srv.a, row] = True
                rows[s.sid] = row
            h_out = srv.decode_rows(
                jnp.asarray(h_buf), jnp.asarray(pos_buf),
                jnp.asarray(mask),
                None if emb0_buf is None else jnp.asarray(emb0_buf),
                None if encl_buf is None else jnp.asarray(encl_buf))
            for s in members:
                s._h = h_out[rows[s.sid]][None]

        self._traverse_core(group, process_group)

    def _traverse_fused(self, group: List[EngineSession],
                        slot: Dict[int, int], h_round, pos_round,
                        emb0_round, encl_round):
        """Device-resident traversal: the round's hidden states live in
        ``h_round`` (W, 1, d) and flow hop to hop through the fused
        gather+step+scatter dispatch (``BlockServer.round_rows``) — only
        small int32 index/mask vectors cross the host boundary, never
        activations.  Control flow is ``_traverse_core``, shared with the
        host-staged ``_traverse``."""

        def process_group(srv, members, progress):
            nonlocal h_round
            N = srv.pool.n_rows
            W = h_round.shape[0]
            slot_of_row = np.full((N,), -1, np.int32)
            row_of_slot = np.full((W,), -1, np.int32)
            mask = np.zeros((srv.m, N), bool)
            gidx = []
            for s in members:
                hop = progress[s.sid]
                row = srv.pool.rows[s.sid]
                e_lo, e_hi = self._hop_span(s, hop)
                lo = max(e_lo, self._n_enc)
                slot_of_row[row] = slot[s.sid]
                row_of_slot[slot[s.sid]] = row
                mask[lo - srv.a: e_hi - srv.a, row] = True
                gidx.append(slot[s.sid])
            # client-side failover cache: ONE device gather of the hop's
            # member rows; each member holds a lazy (buffer, index) record
            # materialized to (1, 1, d) only if a failover ever replays it
            # (_hop_record).  Retained memory per (hop, round) is
            # members x d — the serial path's footprint, not
            # round-width x d.
            h_in = h_round[jnp.asarray(gidx)]
            for i, s in enumerate(members):
                s.hop_inputs[progress[s.sid]].append((h_in, i))
            h_round = srv.round_rows(
                h_round, pos_round, encl_round,
                jnp.asarray(slot_of_row), jnp.asarray(row_of_slot),
                jnp.asarray(mask), emb0_round=emb0_round)
            self.round_stats["hop_dispatches"] += 1

        self._traverse_core(group, process_group)
        return h_round

    def _abort_session(self, sess: EngineSession, reason: str = "no_route"):
        """Mark a session unservable and free its slots; the record stays
        in ``sessions`` for the scheduler to report as dropped, with a
        machine-readable ``fail_reason`` ("no_route", "no_capacity",
        "server_lost_mid_prefill", ...)."""
        sess.state = "failed"
        if sess.fail_reason is None:
            sess.fail_reason = reason
        sess._h = None
        sess._emb0 = None
        for j in set(sess.route.servers):
            if j in self.servers:
                self.servers[j].evict(sess.sid)

    def _defer_session(self, sess: EngineSession):
        """Capacity-starved failover (:class:`NoCapacityError`): park the
        session in the resume queue instead of hard-failing it — capacity
        frees up as co-residents retire.  The in-flight round's partial
        hop records are stripped first so every decode-capable hop keeps
        exactly (prompt + one record per COMPLETED round) and a later
        replay stays position-exact.  A session that keeps bouncing
        (deferred-resumed-deferred) is failed after a bounded number of
        attempts — the livelock guard for chains that never regain
        capacity."""
        if sess.n_defer_resumes >= 8:
            self._abort_session(sess, reason="no_capacity")
            return
        sess.n_defer_resumes += 1
        e = 0
        dec_hops = []
        for hop, k in enumerate(sess.route.blocks):
            lo, hi = e, e + k
            e += k
            if max(lo, self._n_enc) < hi:
                dec_hops.append(hop)
        if dec_hops:
            n = min(len(sess.hop_inputs[hop]) for hop in dec_hops)
            for hop in dec_hops:
                del sess.hop_inputs[hop][n:]
        self.preempt_session(sess.sid)

    def _abort_stuck_head(self):
        """Fail the resume queue's head with ``"no_capacity"`` — called
        when even a forced resume could not seat anything, so waiting
        longer cannot help (nothing is left to retire)."""
        while self._preempt_order:
            sid = self._preempt_order[0]
            sess = self.sessions.get(sid)
            if (sess is None or sess.state != "preempted"
                    or sess.n_generated >= sess.n_new):
                self._preempt_order.pop(0)
                continue
            self._preempt_order.pop(0)
            self._abort_session(sess, reason="no_capacity")
            return

    def retire_session(self, sid: int) -> Optional[EngineSession]:
        """Free the session's rows/block-slots on every server; returns the
        session record (metrics live on it)."""
        sess = self.sessions.pop(sid, None)
        if sess is None:
            return None
        if sess.state == "prefilling":  # dropped mid-prompt: leave its group
            for g in self._prefill_groups:
                g.members = [s for s in g.members if s.sid != sid]
            self._prefill_groups = [g for g in self._prefill_groups
                                    if g.members]
        if sess.state != "failed":
            sess.state = "done"
        for j in set(sess.route.servers):
            if j in self.servers:
                self.servers[j].evict(sid)
        return sess

    def concurrency(self) -> int:
        """Sessions currently holding cache slots (prefilling or decoding)."""
        return sum(1 for s in self.sessions.values()
                   if s.state in ("active", "prefilling"))

    def slot_usage(self) -> Dict[int, Tuple[int, int]]:
        """{server: (used, capacity)} in the layout's eq. (5) accounting
        unit — block-slots (slab) or page-units (paged); the
        invariant-check hook."""
        return {j: srv.pool.usage() for j, srv in self.servers.items()}

    # ------------------------------------------------------------------
    # Legacy single-session API (implemented on the pooled machinery)
    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, client: int = 0, now: float = 0.0,
               frames: Optional[np.ndarray] = None,
               sampling: Optional[SamplingSpec] = None
               ) -> Tuple[int, jnp.ndarray]:
        """Start a session immediately (prefill).  Returns (sid, logits)."""
        alive = self.alive_placement()
        if self.algorithm == "proposed":
            route, _ = shortest_path_route(self.problem, alive, client)
        else:
            route = petals_route(self.problem, alive, client)
        if route is None:
            raise RuntimeError("no feasible route")
        sid = self.create_session(tokens, client, route,
                                  n_new=self.max_new_tokens, arrival=now,
                                  frames=frames, sampling=sampling)
        if not self.try_admit_session(sid, now=now):
            self.sessions.pop(sid)
            raise RuntimeError("no free cache slots for immediate admission")
        return sid, self.sessions[sid].last_logits[None]

    def decode(self, sid: int, token: int) -> jnp.ndarray:
        """One decode step through the session's chain.  The caller picks
        the token for the last position — a provisional sampled tail left by
        ``try_admit_session``/``decode_round`` is replaced, not duplicated."""
        sess = self.sessions[sid]
        if len(sess.tokens) == sess.pos + 1:
            sess.tokens[-1] = int(token)  # unprocessed provisional tail
        else:
            sess.tokens.append(int(token))
        sess.n_generated = len(sess.tokens) - sess.prompt_len
        if self.cache_layout == "paged":
            # legacy single-session semantics: growth failure propagates
            if not self._grow_session(sess,
                                      pages_for(sess.pos + 1,
                                                self.page_size),
                                      protect={sess.sid}):
                raise RuntimeError(
                    f"session {sid}: no page capacity for decode")
        tok = jnp.asarray([[int(token)]], jnp.int32)
        sess._h = self._embed(self.params["embed"], tok)
        sess._emb0 = sess._h
        self._traverse([sess])
        sess.pos += 1
        sess.virtual_time += self._route_per_token(sess)
        logits = self._lm_head(self.params["embed"], sess._h)
        sess.last_logits = logits[0, 0]
        sess._h = None
        sess._emb0 = None
        return logits[:, 0]

    def finish(self, sid: int):
        self.retire_session(sid)

    # ------------------------------------------------------------------
    # Fault tolerance
    # ------------------------------------------------------------------
    def kill_server(self, j: int):
        """ORACLE fail-stop: flip the server dead with instant, free
        detection (tests / back-compat).  For the realistic path — the
        crash is only discovered when a dispatch misses its deadline, and
        detection + backoff are billed — use :meth:`inject_crash` or a
        :class:`FaultPlan`.  Unknown or already-dead ids raise."""
        srv = self.servers.get(j)
        if srv is None or not srv.alive:
            alive = sorted(jj for jj, s in self.servers.items() if s.alive)
            raise ValueError(
                f"kill_server({j}): "
                + ("server is already dead" if srv is not None
                   else "no such server")
                + f"; alive servers: {alive}")
        srv.alive = False
        srv.crashed = False
        srv.suspected = True

    def inject_crash(self, j: int):
        """Timeout-detected crash: the server goes silent but ``alive``
        stays True — the next dispatch that misses its deadline detects
        the loss and bills detection + backoff (``_detect_crash``)."""
        srv = self.servers.get(j)
        if srv is None or not srv.alive:
            alive = sorted(jj for jj, s in self.servers.items() if s.alive)
            raise ValueError(
                f"inject_crash({j}): unknown or already-dead server; "
                f"alive servers: {alive}")
        srv.crashed = True

    def rejoin_server(self, j: int):
        """A crashed server returns — with an EMPTY pool (its RAM-resident
        caches died with it), whether or not anyone detected the outage.
        Sessions that still route through it lose their rows here and are
        spliced by the next traverse's residency failover (billed replay,
        no detection wait: the server answers promptly, just emptily).
        The ``suspected`` flag survives the rejoin so routing keeps its
        flap-avoidance penalty until the controller clears it."""
        srv = self.servers.get(j)
        if srv is None:
            raise ValueError(f"rejoin_server({j}): no such server; known "
                             f"servers: {sorted(self.servers)}")
        for sid in list(srv.pool.rows):
            srv.evict(sid)
        srv.alive = True
        srv.crashed = False
        self.round_stats["rejoins"] += 1

    def suspected_servers(self) -> List[int]:
        """Servers once declared dead by timeout (flap-avoidance input
        for the controller's suspicion-aware routing)."""
        return sorted(j for j, srv in self.servers.items() if srv.suspected)

    def apply_faults(self, now: float) -> List:
        """Apply every :class:`FaultPlan` event due by virtual time
        ``now`` (idempotent — a cursor tracks what already fired).
        Returns the events applied this call."""
        if self.fault_plan is None:
            return []
        due, self._fault_cursor = self.fault_plan.due(self._fault_cursor,
                                                      now)
        for ev in due:
            srv = self.servers.get(ev.server)
            if ev.kind == "crash":
                if srv is not None and srv.alive and not srv.crashed:
                    srv.crashed = True
            elif ev.kind == "rejoin":
                if srv is not None:
                    self.rejoin_server(ev.server)
            elif ev.kind == "straggler_start":
                self.set_slowdown(ev.server, ev.factor)
            elif ev.kind == "straggler_end":
                self.set_slowdown(ev.server, 1.0)
            elif ev.kind == "dispatch_error":
                self._dispatch_faults.add(ev.server)
        return due

    def join_server(self, spec, rtt_token_col, rtt_prefill_col):
        """Elastic scale-out: add a server and re-run placement (Alg. 2)."""
        servers = list(self.problem.servers) + [
            dataclasses.replace(spec, sid=self.problem.n_servers)]
        rtt_t = np.concatenate(
            [self.problem.rtt_token, np.asarray(rtt_token_col).reshape(-1, 1)],
            axis=1)
        rtt_p = np.concatenate(
            [self.problem.rtt_prefill,
             np.asarray(rtt_prefill_col).reshape(-1, 1)], axis=1)
        self.problem = Problem(self.problem.llm, servers,
                               self.problem.n_clients, rtt_t, rtt_p,
                               self.problem.workload)
        self._base_taus.append(float(spec.tau))
        if self.algorithm == "proposed":
            from repro.core.placement import cg_bp
            self.placement, _ = cg_bp(self.problem, self.R)
        else:
            self.placement = petals_bp(self.problem)
        # NOTE: re-placement applies to NEW sessions; running sessions keep
        # their routes and caches (slow-time-scale semantics of Alg. 2).
        self._build_servers()

    def _subchain(self, lo: int, hi: int, client: int
                  ) -> Optional[Tuple[int, ...]]:
        """Min-cost chain of ALIVE servers covering exactly blocks [lo, hi)."""
        alive = self.alive_placement()
        # clip hosted ranges into [lo, hi] and run the same DAG DP (both
        # ends clipped: a host starting past ``hi`` must not index the
        # subproblem's weight table out of range)
        a = np.clip(alive.a, lo, hi)
        end = np.clip(alive.a + alive.m, lo, hi)
        m = np.maximum(end - a, 0)
        m[alive.m <= 0] = 0
        sub = Placement(a=a - lo, m=m)
        subproblem = dataclasses.replace(self.problem)
        kw = dict(n_blocks=hi - lo)
        if self.problem.llm.block_tau is not None:
            kw["block_tau"] = self.problem.llm.block_tau[lo:hi]
        subproblem.llm = dataclasses.replace(self.problem.llm, **kw)
        route, _ = shortest_path_route(subproblem, sub, client)
        return route.servers if route is not None else None

    def _replay_prefill_range(self, sess: EngineSession, j: int, lo: int,
                              hi: int, h_full):
        """Failover replay of one hop's prompt prefill (single-phase
        stacks).  In batched mode the replay follows the session's
        deterministic chunk plan through the SAME pooled programs that
        built the original caches — zero pad columns are bit-equivalent to
        the originals because padded positions are causally masked out of
        every valid position's computation — so the rebuilt caches are
        bit-identical.  Recurrent stacks replay exact-length in one shot
        (their plan).  Serial mode keeps the legacy exact-length replay."""
        srv = self.servers[j]
        emb0_full = None
        if self._needs_emb0:
            emb0_full = self._embed(
                self.params["embed"],
                jnp.asarray([sess.tokens[: sess.prompt_len]], jnp.int32))
        if self.prefill_mode == "serial":
            return srv.prefill_range(sess.sid, h_full, lo, hi,
                                     jnp.arange(h_full.shape[1]),
                                     emb0=emb0_full)
        return self._replay_chunked(sess, srv, lo, hi, h_full, "all",
                                    emb0_full=emb0_full)

    def _replay_chunked(self, sess: EngineSession, srv: BlockServer,
                        lo: int, hi: int, h_full, phase: str,
                        enc_rows=None, emb0_full=None):
        """Replay blocks [lo, hi) of one session's prompt through the
        pooled prefill programs, following its deterministic chunk plan —
        the ONE chunk-replay loop shared by the single-phase and enc-dec
        failover paths."""
        N = srv.pool.n_rows
        d = h_full.shape[-1]
        row = srv.pool.rows[sess.sid]
        mask = np.zeros((srv.m, N), bool)
        mask[lo - srv.a: hi - srv.a, row] = True
        mask = jnp.asarray(mask)
        outs = []
        for off, span, t_pad in self._prefill_plan(h_full.shape[1]):
            chunk = h_full[:, off: off + span]
            if t_pad > span:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((1, t_pad - span, d), chunk.dtype)], 1)
            h_buf = jnp.zeros((N, t_pad, d), chunk.dtype).at[row].set(
                chunk[0])
            emb0_rows = None
            if emb0_full is not None:  # recurrent plan: one exact chunk
                emb0_rows = jnp.zeros((N, t_pad, d),
                                      emb0_full.dtype).at[row].set(
                    emb0_full[0, off: off + t_pad])
            h_out = srv.prefill_rows(h_buf, mask, offset=off, phase=phase,
                                     emb0_rows=emb0_rows, enc_rows=enc_rows)
            outs.append(h_out[row][None, :span])
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)

    def _replay_prefill_encdec(self, sess: EngineSession, j: int, lo: int,
                               hi: int, hs_enc, hs_dec):
        """Failover replay of one replacement hop of an enc-dec route: the
        encoder sub-range replays the exact-length frame activations (the
        blocks are stateless — this only threads the activations forward so
        later hop histories stay exact), the decoder sub-range replays the
        prompt per its chunk plan, rebuilding self-KV and cross-KV (from
        the session's cached ``enc_out``)."""
        srv = self.servers[j]
        n_enc = self._n_enc
        if lo < n_enc and hs_enc is not None:
            elo, ehi = lo, min(hi, n_enc)
            if self.prefill_mode == "serial":
                hs_enc = srv.prefill_range(sess.sid, hs_enc, elo, ehi,
                                           jnp.arange(hs_enc.shape[1]))
            else:
                N = srv.pool.n_rows
                row = srv.pool.rows[sess.sid]
                mask = np.zeros((srv.m, N), bool)
                mask[elo - srv.a: ehi - srv.a, row] = True
                h_buf = jnp.zeros((N,) + hs_enc.shape[1:],
                                  hs_enc.dtype).at[row].set(hs_enc[0])
                h_out = srv.prefill_rows(h_buf, jnp.asarray(mask),
                                         offset=0, phase="enc")
                hs_enc = h_out[row][None]
        if hi > n_enc and hs_dec is not None:
            dlo = max(lo, n_enc)
            if self.prefill_mode == "serial":
                hs_dec = srv.prefill_range(
                    sess.sid, hs_dec, dlo, hi,
                    jnp.arange(hs_dec.shape[1]), enc_h=sess.enc_out)
            else:
                row = srv.pool.rows[sess.sid]
                enc_rows = jnp.zeros(
                    (srv.pool.n_rows,) + sess.enc_out.shape[1:],
                    sess.enc_out.dtype).at[row].set(sess.enc_out[0])
                hs_dec = self._replay_chunked(sess, srv, dlo, hi, hs_dec,
                                              "dec", enc_rows=enc_rows)
        return hs_enc, hs_dec

    @staticmethod
    def _hop_record(rec):
        """Materialize one decode-token hop record: the fused round path
        stores lazy ((members, 1, d) hop gather, index) tuples; the
        host-staged paths store (1, 1, d) arrays directly."""
        if isinstance(rec, tuple):
            buf, g = rec
            return buf[g][None]
        return rec

    def _failover(self, sess: EngineSession, hop: int):
        """Replace the lost server at ``hop`` by a chain of alive servers
        and replay the client-side cached inputs to rebuild their caches.
        "Lost" covers dead servers AND alive ones that no longer hold the
        session's row (rejoined with an empty pool) — the latter may
        re-enter the replacement chain and simply get re-prefilled.  The
        replay is billed on the virtual clock (``recovery_replay_cost``):
        per replacement hop, one input round-trip + weighted prompt
        prefill + k·τ per replayed token."""
        dead_j = sess.route.servers[hop]
        e_lo = sum(sess.route.blocks[:hop])
        e_hi = e_lo + sess.route.blocks[hop]
        chain = self._subchain(e_lo, e_hi, sess.client)
        if chain is None:
            raise RuntimeError(
                f"no surviving servers cover blocks [{e_lo},{e_hi})")
        inputs = sess.hop_inputs[hop]
        rec = inputs[0]
        new_servers = list(sess.route.servers)
        new_blocks = list(sess.route.blocks)
        repl_routes = []
        e = e_lo
        alive = self.alive_placement()
        for j in chain:
            k = int(min(alive.a[j] + alive.m[j], e_hi) - e)
            repl_routes.append((j, e, e + k))
            e += k
        # claim slots on the replacement chain, then replay.  Paged layout:
        # the replacement hops book pages covering everything the replay
        # and the in-flight round will write ([0, pos] — the round that
        # triggered this failover writes position pos)
        n_pages = worst = None
        if self.cache_layout == "paged":
            n_pages = pages_for(min(sess.pos + 1, self.max_seq_len),
                                self.page_size)
            worst = self._worst_pages(sess)
        for j, lo, hi2 in repl_routes:
            if not self.servers[j].fits(sess.sid, hi2 - lo,
                                        n_pages or 0, worst):
                raise NoCapacityError(
                    f"failover target {j} has no free cache slots")
        for j, lo, hi2 in repl_routes:
            self.servers[j].admit(sess.sid, hi2 - lo,
                                  n_pages=n_pages or 0)
        # replay, recording each replacement hop's OWN input history so a
        # later failure of any replacement hop replays correct activations
        new_histories: List[List] = [[] for _ in repl_routes]
        if self._is_enc_dec:
            hs_enc = rec.get("enc") if isinstance(rec, dict) else None
            hs_dec = rec.get("dec") if isinstance(rec, dict) else rec
            for i, (j, lo, hi2) in enumerate(repl_routes):
                new_histories[i].append(
                    {"enc": hs_enc if lo < self._n_enc else None,
                     "dec": hs_dec if hi2 > self._n_enc else None})
                hs_enc, hs_dec = self._replay_prefill_encdec(
                    sess, j, lo, hi2, hs_enc, hs_dec)
        else:
            hs = rec
            for i, (j, lo, hi2) in enumerate(repl_routes):
                new_histories[i].append(hs)
                hs = self._replay_prefill_range(sess, j, lo, hi2, hs)
        # replay each decoded token (encoder-only replacement hops have no
        # decode-time work — and, symmetrically, an encoder-only dead hop
        # recorded no decode inputs)
        S = sess.prompt_len
        for t_idx, h_tok in enumerate(inputs[1:]):
            h_tok = self._hop_record(h_tok)
            pos = S + t_idx
            emb0 = None
            if self._needs_emb0:
                emb0 = self._embed(
                    self.params["embed"],
                    jnp.asarray([[sess.tokens[pos]]], jnp.int32))
            hh = h_tok
            for i, (j, lo, hi2) in enumerate(repl_routes):
                if hi2 <= self._n_enc:
                    continue
                new_histories[i].append(hh)
                hh = self.servers[j].decode_range(
                    sess.sid, hh, lo, hi2, pos, emb0=emb0,
                    enc_len=sess.enc_len)
        # splice the replacement chain into the route
        new_servers[hop: hop + 1] = [j for j, _, _ in repl_routes]
        new_blocks[hop: hop + 1] = [hi2 - lo for _, lo, hi2 in repl_routes]
        sess.hop_inputs[hop: hop + 1] = new_histories
        sess.route = Route(servers=tuple(new_servers),
                           blocks=tuple(new_blocks))
        # a rejoined server may sit in its own replacement chain — don't
        # evict the row the replay just rebuilt
        if dead_j in self.servers and \
                dead_j not in {j for j, _, _ in repl_routes}:
            self.servers[dead_j].evict(sess.sid)
        # bill the rebuild (eq. (1) terms): per replacement hop, one input
        # round-trip + weighted prompt prefill + k·τ per replayed token
        n_replay_tok = len(inputs) - 1
        cost = recovery_replay_cost(
            self.problem, sess.client, repl_routes, n_replay_tok,
            slowdown_of=lambda jj: self.servers[jj].slowdown)
        sess.replay_time += cost
        sess.virtual_time += cost
        sess.n_replays += 1
        self.round_stats["replays"] += 1
        self.round_stats["replay_s"] += cost
        # remaining tokens are billed at the NEW route's cost; the virtual
        # retirement time shifts accordingly
        sess.per_token_time = self._route_per_token(sess)
        sess.end = (sess.start + sess.virtual_time
                    + max(sess.n_new - sess.n_generated, 0)
                    * sess.per_token_time)

    # ------------------------------------------------------------------
    def set_slowdown(self, j: int, factor: float):
        """Straggler injection: server j runs ``factor``x its calibrated
        speed.  ``factor`` is ABSOLUTE over the construction-time tau (not
        cumulative), so ``set_slowdown(j, 1.0)`` ends a straggler interval
        cleanly.  The degraded tau lands in ``self.problem`` — routing of
        future sessions, the eq. (1) clock of new routes, and detection
        deadlines all see it."""
        servers = list(self.problem.servers)
        servers[j] = dataclasses.replace(servers[j],
                                         tau=self._base_taus[j] * factor)
        self.problem = dataclasses.replace(self.problem)
        self.problem.servers = servers
        # in-flight sessions routed through j decode at the degraded rate
        # from now on (and recover when the straggler interval ends)
        for sess in self.sessions.values():
            if (sess.state in ("active", "preempted")
                    and j in sess.route.servers):
                sess.per_token_time = self._route_per_token(sess)
                sess.end = (sess.start + sess.virtual_time
                            + max(sess.n_new - sess.n_generated, 0)
                            * sess.per_token_time)


def generate(system: GeoServingSystem, tokens: np.ndarray, n_new: int,
             client: int = 0, frames: Optional[np.ndarray] = None
             ) -> Tuple[np.ndarray, float]:
    """End-to-end greedy generation driver.  Returns (tokens, virtual_time)."""
    sid, logits = system.submit(tokens, client, frames=frames)
    out = list(np.asarray(tokens))
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[-1] if logits.ndim > 1 else logits))
        out.append(nxt)
        logits = system.decode(sid, nxt)
    vt = system.sessions[sid].virtual_time
    system.finish(sid)
    return np.asarray(out), vt
