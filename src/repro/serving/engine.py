"""Geo-distributed serving engine: the PETALS architecture natively in JAX.

Executes REAL block-level forward passes according to a BPRR placement with
client-centric (hub-spoke) communication and client-side input caches —
the paper's Fig. 1 — while a virtual clock accounts time with the validated
performance models (eq. (1)): the engine cross-validates the simulator.

Fault tolerance (DESIGN.md §7):
* client-side per-hop input caches ⇒ on server failure, the failed block
  range is re-routed over surviving servers and the cached inputs are
  replayed to rebuild attention caches (tested: post-failover logits equal
  the no-failure run bit-for-bit).
* elastic join/leave triggers CG-BP re-placement at the slow time scale.
* stragglers: per-server slowdown factors feed the routing costs, so WS-RR
  avoids slow servers; `speculative` re-dispatch duplicates a late hop.

Supported block families: "decoder" (dense / MoE / VLM / gemma-pattern) and
"rwkv" (attention-free).  Hybrid/enc-dec run through the monolithic serve
steps + simulator (same BPRR decisions; engine support is a straightforward
extension).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.perf_model import Placement, Problem, Route
from repro.core.placement import petals_bp
from repro.core.routing import petals_route, shortest_path_route
from repro.core.topology import RoutingGraph, route_blocks
from repro.models import blocks as B
from repro.models.layers import NULL_SH, embed_tokens, lm_head
from repro.models.model import stack_plan
from repro.serving.kv_cache import new_block_cache, write_prefill_kv


def _block_kind(cfg: ModelConfig) -> str:
    plan = stack_plan(cfg)
    kinds = {s.kind for s in plan}
    if kinds == {"decoder"}:
        return "decoder"
    if kinds == {"rwkv"}:
        return "rwkv"
    raise NotImplementedError(
        f"geo engine supports decoder/rwkv stacks; got {kinds}")


def _layer_params(params, layer: int):
    return jax.tree.map(lambda x: x[layer], params["segments"]["blocks"])


@dataclass
class SessionHops:
    """Client-side state for one session."""

    sid: int
    client: int
    route: Route
    pos: int = 0
    max_len: int = 0
    # per-hop input history (the PETALS fault-tolerance cache)
    hop_inputs: List[List[jnp.ndarray]] = field(default_factory=list)
    virtual_time: float = 0.0


class BlockServer:
    """One 'server': params for its block range + per-session caches."""

    def __init__(self, sid: int, cfg: ModelConfig, params, a: int, m: int,
                 slowdown: float = 1.0):
        self.sid = sid
        self.cfg = cfg
        self.kind = _block_kind(cfg)
        self.a, self.m = int(a), int(m)
        self.layers = [_layer_params(params, l) for l in range(a, a + m)]
        self.caches: Dict[Tuple[int, int], Dict] = {}  # (session, layer)
        self.alive = True
        self.slowdown = slowdown

    def evict(self, sid: int):
        for key in [k for k in self.caches if k[0] == sid]:
            del self.caches[key]

    def n_sessions(self) -> int:
        return len({k[0] for k in self.caches})

    def process_full(self, sid: int, h, lo: int, hi: int, positions,
                     max_len: int):
        """Prefill blocks [lo, hi) for a session; builds caches."""
        assert self.alive, f"server {self.sid} is dead"
        S = h.shape[1]
        for l in range(lo, hi):
            p = self.layers[l - self.a]
            if self.kind == "decoder":
                h, kv_cache, _ = B.decoder_block_full(
                    p, self.cfg, NULL_SH, h, positions, l)
                cache = new_block_cache(self.cfg, "decoder", h.shape[0],
                                        max_len)
                if self.cfg.attn_kind == "mla":
                    cache = write_prefill_kv(
                        cache, (kv_cache["latent"], kv_cache["krope"]), S)
                else:
                    cache = write_prefill_kv(
                        cache, (kv_cache["k"], kv_cache["v"]), S)
            else:  # rwkv
                h, state = B.rwkv_block_full(p, self.cfg, NULL_SH, h)
                cache = state
            self.caches[(sid, l)] = cache
        return h

    def process_decode(self, sid: int, h, lo: int, hi: int, pos: int):
        assert self.alive, f"server {self.sid} is dead"
        for l in range(lo, hi):
            p = self.layers[l - self.a]
            cache = self.caches[(sid, l)]
            if self.kind == "decoder":
                h, cache = B.decoder_block_decode(
                    p, self.cfg, NULL_SH, h, cache, pos, l)
            else:
                h, cache = B.rwkv_block_decode(p, self.cfg, NULL_SH, h, cache)
            self.caches[(sid, l)] = cache
        return h


class GeoServingSystem:
    """Client-centric distributed inference with online BPRR."""

    def __init__(self, cfg: ModelConfig, params, problem: Problem,
                 algorithm: str = "proposed", R: Optional[int] = None,
                 max_new_tokens: int = 64):
        assert problem.L == cfg.n_layers
        self.cfg = cfg
        self.params = params
        self.problem = problem
        self.algorithm = algorithm
        self.max_new_tokens = max_new_tokens
        if algorithm == "proposed":
            from repro.core.placement import auto_R, cg_bp
            self.R = R if R is not None else auto_R(problem, 0.1, 60.0)
            self.placement, _ = cg_bp(problem, self.R)
        else:
            self.R = R
            self.placement = petals_bp(problem)
        self.servers: Dict[int, BlockServer] = {}
        self._build_servers()
        self.sessions: Dict[int, SessionHops] = {}
        self._sid = 0

    # ------------------------------------------------------------------
    def _build_servers(self):
        for j in range(self.problem.n_servers):
            a, m = int(self.placement.a[j]), int(self.placement.m[j])
            if m <= 0:
                continue
            if j in self.servers:
                continue  # keep live objects (running sessions hold caches)
            self.servers[j] = BlockServer(j, self.cfg, self.params, a, m)

    def alive_placement(self) -> Placement:
        a = np.array(self.placement.a)
        m = np.array(self.placement.m)
        for j in range(len(m)):
            if j in self.servers and not self.servers[j].alive:
                m[j] = 0
            if j not in self.servers:
                m[j] = 0
        return Placement(a=a, m=m)

    # ------------------------------------------------------------------
    def submit(self, tokens: np.ndarray, client: int = 0, now: float = 0.0
               ) -> Tuple[int, jnp.ndarray]:
        """Start a session (prefill).  tokens: (S,).  Returns (sid, logits)."""
        alive = self.alive_placement()
        if self.algorithm == "proposed":
            route, _ = shortest_path_route(self.problem, alive, client)
        else:
            route = petals_route(self.problem, alive, client)
        if route is None:
            raise RuntimeError("no feasible route")
        sid = self._sid
        self._sid += 1
        S = len(tokens)
        max_len = S + self.max_new_tokens
        sess = SessionHops(sid=sid, client=client, route=route, pos=S,
                           max_len=max_len,
                           hop_inputs=[[] for _ in route.servers])
        h = embed_tokens(self.params["embed"], self.cfg, NULL_SH,
                         jnp.asarray(tokens)[None, :])
        positions = jnp.arange(S)
        e = 0
        for hop, (j, k) in enumerate(zip(route.servers, route.blocks)):
            sess.hop_inputs[hop].append(h)
            h = self.servers[j].process_full(sid, h, e, e + k, positions,
                                             max_len)
            sess.virtual_time += (self.problem.rtt_prefill[client, j]
                                  + k * self.problem.servers[j].tau_prefill(
                                      self.problem.workload.l_in)
                                  * self.servers[j].slowdown)
            e += k
        logits = lm_head(self.params["embed"], self.cfg, NULL_SH, h[:, -1:])
        self.sessions[sid] = sess
        return sid, logits[:, 0]

    def decode(self, sid: int, token: int) -> jnp.ndarray:
        """One decode step through the session's chain."""
        sess = self.sessions[sid]
        h = embed_tokens(self.params["embed"], self.cfg, NULL_SH,
                         jnp.asarray([[token]], jnp.int32))
        e = 0
        hop = 0
        while hop < len(sess.route.servers):
            j = sess.route.servers[hop]
            k = sess.route.blocks[hop]
            if not self.servers[j].alive:
                self._failover(sess, hop)  # splices the route in place
                continue  # retry the same hop with the replacement chain
            srv = self.servers[j]
            sess.hop_inputs[hop].append(h)
            h = srv.process_decode(sid, h, e, e + k, sess.pos)
            sess.virtual_time += (
                self.problem.rtt_token[sess.client, j]
                + k * self.problem.servers[j].tau * srv.slowdown)
            e += k
            hop += 1
        sess.pos += 1
        logits = lm_head(self.params["embed"], self.cfg, NULL_SH, h)
        return logits[:, 0]

    def finish(self, sid: int):
        sess = self.sessions.pop(sid, None)
        if sess is None:
            return
        for j in set(sess.route.servers):
            if j in self.servers:
                self.servers[j].evict(sid)


    # ------------------------------------------------------------------
    # Fault tolerance
    # ------------------------------------------------------------------
    def kill_server(self, j: int):
        if j in self.servers:
            self.servers[j].alive = False

    def join_server(self, spec, rtt_token_col, rtt_prefill_col):
        """Elastic scale-out: add a server and re-run placement (Alg. 2)."""
        servers = list(self.problem.servers) + [
            dataclasses.replace(spec, sid=self.problem.n_servers)]
        rtt_t = np.concatenate(
            [self.problem.rtt_token, np.asarray(rtt_token_col).reshape(-1, 1)],
            axis=1)
        rtt_p = np.concatenate(
            [self.problem.rtt_prefill,
             np.asarray(rtt_prefill_col).reshape(-1, 1)], axis=1)
        self.problem = Problem(self.problem.llm, servers,
                               self.problem.n_clients, rtt_t, rtt_p,
                               self.problem.workload)
        if self.algorithm == "proposed":
            from repro.core.placement import cg_bp
            self.placement, _ = cg_bp(self.problem, self.R)
        else:
            self.placement = petals_bp(self.problem)
        # NOTE: re-placement applies to NEW sessions; running sessions keep
        # their routes and caches (slow-time-scale semantics of Alg. 2).
        self._build_servers()

    def _subchain(self, lo: int, hi: int, client: int
                  ) -> Optional[Tuple[int, ...]]:
        """Min-cost chain of ALIVE servers covering exactly blocks [lo, hi)."""
        alive = self.alive_placement()
        # clip hosted ranges into [lo, hi) and run the same DAG DP
        a = np.maximum(alive.a, lo)
        end = np.minimum(alive.a + alive.m, hi)
        m = np.maximum(end - a, 0)
        m[alive.m <= 0] = 0
        sub = Placement(a=a - lo, m=m)
        subproblem = dataclasses.replace(self.problem)
        subproblem.llm = dataclasses.replace(self.problem.llm,
                                             n_blocks=hi - lo)
        route, _ = shortest_path_route(subproblem, sub, client)
        return route.servers if route is not None else None

    def _failover(self, sess: SessionHops, hop: int):
        """Replace the dead server at ``hop`` by a chain of alive servers and
        replay the client-side cached inputs to rebuild their caches."""
        dead_j = sess.route.servers[hop]
        e_lo = sum(sess.route.blocks[:hop])
        e_hi = e_lo + sess.route.blocks[hop]
        chain = self._subchain(e_lo, e_hi, sess.client)
        if chain is None:
            raise RuntimeError(
                f"no surviving servers cover blocks [{e_lo},{e_hi})")
        # rebuild caches on the replacement chain by replaying inputs
        inputs = sess.hop_inputs[hop]
        prompt_h = inputs[0]
        S = prompt_h.shape[1]
        new_servers = list(sess.route.servers)
        new_blocks = list(sess.route.blocks)
        repl_routes = []
        e = e_lo
        alive = self.alive_placement()
        for j in chain:
            k = int(min(alive.a[j] + alive.m[j], e_hi) - e)
            repl_routes.append((j, e, e + k))
            e += k
        # replay prefill
        hs = prompt_h
        positions = jnp.arange(S)
        for j, lo, hi2 in repl_routes:
            hs_out = self.servers[j].process_full(
                sess.sid, hs, lo, hi2, positions, sess.max_len)
            hs = hs_out
        # replay each decoded token
        for t_idx, h_tok in enumerate(inputs[1:]):
            pos = S + t_idx
            hh = h_tok
            for j, lo, hi2 in repl_routes:
                hh = self.servers[j].process_decode(sess.sid, hh, lo, hi2,
                                                    pos)
        # splice the replacement chain into the route
        new_servers[hop: hop + 1] = [j for j, _, _ in repl_routes]
        new_blocks[hop: hop + 1] = [hi2 - lo for _, lo, hi2 in repl_routes]
        # inputs history: replacement hops share the old hop's history
        sess.hop_inputs[hop: hop + 1] = [list(inputs)
                                         for _ in repl_routes]
        sess.route = Route(servers=tuple(new_servers),
                           blocks=tuple(new_blocks))
        if dead_j in self.servers:
            self.servers[dead_j].evict(sess.sid)

    # ------------------------------------------------------------------
    def set_slowdown(self, j: int, factor: float):
        """Straggler injection: server j runs `factor`x slower; routing costs
        of FUTURE sessions see the degraded tau."""
        if j in self.servers:
            self.servers[j].slowdown = factor
        servers = list(self.problem.servers)
        servers[j] = dataclasses.replace(servers[j],
                                         tau=servers[j].tau * factor)
        self.problem = dataclasses.replace(self.problem)
        self.problem.servers = servers


def generate(system: GeoServingSystem, tokens: np.ndarray, n_new: int,
             client: int = 0) -> Tuple[np.ndarray, float]:
    """End-to-end greedy generation driver.  Returns (tokens, virtual_time)."""
    sid, logits = system.submit(tokens, client)
    out = list(np.asarray(tokens))
    for _ in range(n_new):
        nxt = int(jnp.argmax(logits[-1] if logits.ndim > 1 else logits))
        out.append(nxt)
        logits = system.decode(sid, nxt)
    vt = system.sessions[sid].virtual_time
    system.finish(sid)
    return np.asarray(out), vt
