"""Admission scheduler: OnlineBPRR (Alg. 2) in front of the geo engine.

The controller decides WHEN a request may start (WS-RR waiting under the
design concurrency |R|) on the virtual clock; the engine executes the actual
block-level computation.  Used by examples/geo_serve.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.online import OnlineBPRR
from repro.core.perf_model import Problem
from repro.serving.engine import GeoServingSystem, generate


@dataclass
class ServedRequest:
    rid: int
    arrival: float
    start: float
    first_token: float
    per_token: float
    total: float
    tokens: np.ndarray


class AdmissionScheduler:
    def __init__(self, system: GeoServingSystem, R: Optional[int] = None,
                 arrival_rate: float = 0.1):
        self.system = system
        self.controller = OnlineBPRR(system.problem, R=R,
                                     arrival_rate=arrival_rate)

    def serve(self, rid: int, tokens: np.ndarray, arrival: float,
              n_new: int, client: int = 0) -> ServedRequest:
        route, start, end, sid_ctl = self.controller.admit(client, arrival)
        if route is None:
            raise RuntimeError("admission failed: no feasible route")
        out, vt = generate(self.system, tokens, n_new, client=client)
        wait = start - arrival
        prefill_share = vt / max(1, n_new + 1)
        self.controller.finish(sid_ctl)
        return ServedRequest(
            rid=rid, arrival=arrival, start=start,
            first_token=wait + prefill_share,
            per_token=vt / max(1, n_new + 1),
            total=wait + vt, tokens=out)
