"""Continuous-batching scheduler: OnlineBPRR (Alg. 2) driving the geo engine
with interleaved sessions.

The controller decides WHEN a request may start — WS-RR waiting under the
design concurrency |R| (eq. (20)) on the virtual clock — while the engine
executes the actual block-level computation with all temporally-overlapping
sessions sharing the per-server cache pools (one jitted step per server per
round).  The event loop:

  arrival  →  OnlineBPRR.admit (WS-RR route + committed start)
  start    →  same-timestamp starts are COALESCED into one batch:
              engine.try_admit_sessions claims slots and groups the
              admitted sessions by (route, prompt-length bucket) for
              batched prefill; chunk rounds then interleave with decode
              rounds so long prompts never head-of-line block resident
              sessions.  A start that would overbook cache slots is
              DEFERRED and re-admitted at the next retirement
              (no-overbooking invariant)
  end      →  co-resident sessions decode in shared batched rounds until the
              ending session has all its tokens; it then retires, frees its
              block-slots, and deferred sessions are re-admitted

Every decode round the loop drives is device-resident by default
(``GeoServingSystem.decode_round`` with ``decode_mode="fused"``): the
round costs one batched embed, one fused dispatch per (hop, server), one
fused lm_head+sample tail, and exactly one host sync — the scheduler's
per-round Python overhead is bookkeeping, not data movement
(``round_stats`` surfaces the engine's dispatch accounting).

Within a client, starts are FIFO (a later arrival never overtakes an
earlier one of the same client).  Used by examples/geo_serve.py and
benchmarks/engine_validation.py — the engine half of the simulator
cross-validation.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.online import OnlineBPRR
from repro.serving.engine import GeoServingSystem
from repro.serving.sampling import SamplingSpec


@dataclass
class ServedRequest:
    """Per-request result record: the §4.1 latency metrics on the virtual
    clock (wait, first-token, per-token) plus the generated tokens and the
    deferral/drop bookkeeping."""

    rid: int
    arrival: float
    start: float
    first_token: float  # wait + prefill (virtual)
    per_token: float  # (wait + total service) / n_new — paper's §4.1 metric
    total: float  # wait + service
    tokens: np.ndarray
    wait: float = 0.0
    per_token_rest: float = 0.0  # decode-phase per-token time
    dropped: bool = False
    # machine-readable reason when dropped ("no_route", "no_capacity",
    # "server_lost_mid_prefill", "admission_rejected", ...); None otherwise
    fail_reason: Optional[str] = None
    n_deferrals: int = 0
    # paged cache layout: times the session was swapped out under page
    # pressure mid-generation (0 on the slab layout / without pressure)
    n_preemptions: int = 0
    # failure-recovery accounting mirrored off the engine session (see
    # docs/concurrency.md "Failure model"): timeout detections, backoff
    # probes, billed cache replays, and their virtual-clock costs
    n_detections: int = 0
    n_retries: int = 0
    n_replays: int = 0
    detect_time: float = 0.0
    backoff_time: float = 0.0
    replay_time: float = 0.0

    @property
    def recovery_time(self) -> float:
        return self.detect_time + self.backoff_time + self.replay_time


@dataclass
class _Pending:
    rid: int
    tokens: np.ndarray
    arrival: float
    n_new: int
    client: int
    frames: Optional[np.ndarray] = None  # encoder input (enc-dec stacks)
    sampling: Optional[SamplingSpec] = None  # None = greedy
    sid: int = -1
    sid_ctl: int = -1
    deferrals: int = 0


def _slot_scale(system: GeoServingSystem) -> float:
    """Page-granular eq. (20) capacity multiplier for the controller.

    The slab layout books a worst-case slot of ``s_c`` bytes
    (``l_in + l_out`` tokens) per block, so the controller's
    ⌊(M_j − s_m·m_j)/s_c⌋ capacity is exact (scale 1).  Paged admission
    books only the PROMPT's pages — ``pages_for(l_in) · page_size``
    tokens — and sessions grow page-by-page afterwards, preempting under
    pressure; the controller's CG-BP reservation and eq. (20) waiting
    times should see that admission footprint, not the worst case, so
    ``s_c`` shrinks by ``total_tokens / prompt_page_tokens``."""
    if getattr(system, "cache_layout", "slab") != "paged":
        return 1.0
    from repro.serving.kv_cache import pages_for
    wl = system.problem.workload
    booked_tokens = pages_for(min(int(wl.l_in), system.max_seq_len),
                              system.page_size) * system.page_size
    return wl.total_tokens / max(1, booked_tokens)


class ContinuousBatchingScheduler:
    """Admission + continuous batching over a :class:`GeoServingSystem`."""

    # event-kind priorities at equal timestamps: retire first (freed slots
    # visible to later decisions), then ALL arrivals, then starts.  Arrivals
    # only touch controller bookkeeping — never engine slots — so admitting
    # them before same-time starts changes no decision, and it guarantees a
    # same-timestamp burst's zero-wait starts are all in the heap before the
    # first one pops: they coalesce into one bucket-group admission batch.
    _END, _ARRIVAL, _START = 0, 1, 2

    def __init__(self, system: GeoServingSystem, R: Optional[int] = None,
                 arrival_rate: float = 0.1):
        self.system = system
        self.controller = OnlineBPRR(system.problem, R=R,
                                     arrival_rate=arrival_rate,
                                     slot_scale=_slot_scale(system))
        # fault sync state: servers the controller already knows are dead /
        # suspected (diffed against the engine at every event)
        self._known_dead: frozenset = frozenset()
        self._known_suspected: frozenset = frozenset()
        self._events: List[Tuple[float, int, int, int]] = []  # (t,prio,seq,i)
        self._seq = itertools.count()
        self._requests: List[_Pending] = []
        self._deferred: List[int] = []  # indices into _requests
        self._last_start: Dict[int, float] = {}  # FIFO-within-client clamp
        self.results: Dict[int, ServedRequest] = {}
        self.max_concurrency = 0

    @property
    def round_stats(self) -> Dict[str, int]:
        """The engine's per-round dispatch accounting (rounds driven, embed
        / round-tail / fused-hop dispatches) — the device-resident round
        contract the benchmarks and tests/test_round_fusion.py assert."""
        return self.system.round_stats

    # ------------------------------------------------------------------
    def submit(self, rid: int, tokens: np.ndarray, arrival: float,
               n_new: int, client: int = 0, frames=None, sampling=None):
        """Enqueue one request (no compute until ``run``).

        ``frames``: encoder input for enc-dec stacks; ``sampling``: the
        session's ``SamplingSpec`` (None = greedy)."""
        idx = len(self._requests)
        self._requests.append(_Pending(rid, np.asarray(tokens),
                                       float(arrival), int(n_new),
                                       int(client), frames=frames,
                                       sampling=sampling))
        heapq.heappush(self._events,
                       (float(arrival), self._ARRIVAL, next(self._seq), idx))

    # ------------------------------------------------------------------
    def run(self) -> List[ServedRequest]:
        """Drive the event loop until every submitted request completes.
        Returns ServedRequests in rid order."""
        while self._events:
            t, prio, _, idx = heapq.heappop(self._events)
            self._sync_faults(t)
            if prio == self._ARRIVAL:
                self._on_arrival(t, idx)
            elif prio == self._START:
                # coalesce same-timestamp starts into one admission batch —
                # they form the engine's bucket groups for batched prefill
                idxs = [idx]
                while (self._events and self._events[0][0] == t
                       and self._events[0][1] == self._START):
                    idxs.append(heapq.heappop(self._events)[3])
                self._on_start(t, idxs)
            else:
                self._on_end(t, idx)
        # nothing left to retire: permanently-deferred sessions can never be
        # re-admitted — surface them as drops instead of vanishing
        for didx in self._deferred:
            req = self._requests[didx]
            sess = self.system.retire_session(req.sid)
            self.controller.finish(req.sid_ctl)
            self._drop(req, reason="no_capacity", sess=sess)
        self._deferred = []
        return [self.results[r.rid] for r in
                sorted(self._requests, key=lambda r: r.rid)
                if r.rid in self.results]

    def _sync_faults(self, t: float):
        """Mirror the engine's fault state into the controller: apply
        FaultPlan events due by the event clock, re-place over the
        surviving fleet when the dead set changes (``replace_servers``
        with 0-memory dead hosts — a rejoined server re-enters with an
        empty pool engine-side), and keep suspicion penalties on every
        server ever declared dead by timeout (flap-avoidance routing)."""
        system = self.system
        if (system.fault_plan is None and not self._known_dead
                and not self._known_suspected):
            return  # fault-free run: keep the hot path free of diffing
        system.apply_faults(t)
        dead = frozenset(j for j, srv in system.servers.items()
                         if not srv.alive)
        suspected = frozenset(system.suspected_servers())
        for j in suspected - self._known_suspected:
            self.controller.set_suspicion(
                j, system.detector.suspicion_penalty)
        if dead != self._known_dead:
            from repro.sim.simulator import _problem_with_dead
            self.controller.replace_servers(
                _problem_with_dead(system.problem, dead))
        self._known_dead = dead
        self._known_suspected = suspected

    def _drop(self, req: _Pending, reason: Optional[str] = None,
              sess=None):
        rec = ServedRequest(
            rid=req.rid, arrival=req.arrival, start=np.inf,
            first_token=np.inf, per_token=np.inf, total=np.inf,
            tokens=np.asarray(req.tokens), wait=np.inf, dropped=True,
            fail_reason=reason, n_deferrals=req.deferrals)
        if sess is not None:
            self._copy_failure_counters(rec, sess)
        self.results[req.rid] = rec

    @staticmethod
    def _copy_failure_counters(rec: ServedRequest, sess):
        rec.n_preemptions = sess.n_preemptions
        rec.n_detections = sess.n_detections
        rec.n_retries = sess.n_retries
        rec.n_replays = sess.n_replays
        rec.detect_time = sess.detect_time
        rec.backoff_time = sess.backoff_time
        rec.replay_time = sess.replay_time

    # ------------------------------------------------------------------
    def _on_arrival(self, t: float, idx: int):
        req = self._requests[idx]
        route, start, _end, sid_ctl = self.controller.admit(req.client, t)
        if route is None:
            self._drop(req, reason="no_route")
            return
        # FIFO within client: never overtake an earlier same-client start
        start = max(start, self._last_start.get(req.client, -np.inf))
        self._last_start[req.client] = start
        req.sid_ctl = sid_ctl
        req.sid = self.system.create_session(req.tokens, req.client, route,
                                             req.n_new, arrival=req.arrival,
                                             frames=req.frames,
                                             sampling=req.sampling)
        heapq.heappush(self._events,
                       (float(start), self._START, next(self._seq), idx))

    def _drain_prefill_interleaved(self):
        """Advance pending prompt chunks one round at a time, giving the
        resident active sessions a decode round between chunks (no
        head-of-line blocking by long prompts)."""
        while self.system.has_pending_prefill():
            self.system.prefill_round()
            if self.system.has_pending_prefill():
                self.system.decode_round()

    def _on_start(self, t: float, idxs: List[int]):
        """Admit a batch of same-timestamp starts.  The engine coalesces
        the fitting ones into (route, bucket) prefill groups."""
        cands: List[int] = []
        for idx in idxs:
            req = self._requests[idx]
            # FIFO within client is head-of-line: while an earlier
            # same-client request sits deferred, later ones queue behind it
            # instead of overtaking via a different route
            if any(self._requests[d].client == req.client
                   for d in self._deferred):
                req.deferrals += 1
                self._deferred.append(idx)
            else:
                cands.append(idx)
        if not cands:
            return
        admitted = set(self.system.try_admit_sessions(
            [self._requests[i].sid for i in cands], now=t))
        self._drain_prefill_interleaved()
        for idx in cands:
            req = self._requests[idx]
            if req.sid in admitted:
                sess = self.system.sessions[req.sid]
                heapq.heappush(
                    self._events,
                    (float(sess.end), self._END, next(self._seq), idx))
                self.max_concurrency = max(self.max_concurrency,
                                           self.system.concurrency())
            else:
                # cache-slot budget exhausted (or queued behind a same-batch
                # predecessor): defer, re-admit on retirement
                req.deferrals += 1
                self._deferred.append(idx)

    def _on_end(self, t: float, idx: int):
        req = self._requests[idx]
        sess = self.system.sessions[req.sid]
        # continuous batching: co-resident sessions share decode rounds until
        # the ending session has produced all its tokens.  A paged-layout
        # session may sit swapped out ("preempted") between rounds — keep
        # driving rounds; the engine's resume queue brings it back.
        while (sess.state in ("active", "preempted")
               and sess.n_generated < sess.n_new):
            self.system.decode_round()
        done = self.system.retire_session(req.sid)
        self.controller.finish(req.sid_ctl)
        self._sync_faults(t)  # rounds above may have detected crashes
        if done.state == "failed":  # unservable failover mid-generation
            self._drop(req, reason=done.fail_reason or "no_route",
                       sess=done)
        else:
            wait = done.start - req.arrival
            # virtual_time is the accumulated TRUE service time — equals
            # prefill + (n_new-1)*per_token on a stable route plus any
            # billed recovery (detection + backoff + replay), and stays
            # correct when failover mid-generation changes the route cost
            service = done.virtual_time
            rec = ServedRequest(
                rid=req.rid, arrival=req.arrival, start=done.start,
                first_token=wait + done.prefill_time,
                per_token=(wait + service) / max(1, done.n_new),
                total=wait + service,
                tokens=np.asarray(done.tokens), wait=wait,
                per_token_rest=done.per_token_time,
                n_deferrals=req.deferrals)
            self._copy_failure_counters(rec, done)
            self.results[req.rid] = rec
        # re-admission: retry deferred sessions in FIFO order; a client whose
        # head-of-line request stays deferred keeps its later ones queued.
        # Admission goes one session at a time (exact FIFO semantics), but
        # chunked prompts still interleave their chunks with decode rounds.
        still: List[int] = []
        blocked_clients: set = set()
        for didx in self._deferred:
            dreq = self._requests[didx]
            if dreq.client not in blocked_clients and \
                    self.system.try_admit_sessions([dreq.sid], now=t):
                self._drain_prefill_interleaved()
                dsess = self.system.sessions[dreq.sid]
                heapq.heappush(
                    self._events,
                    (float(dsess.end), self._END, next(self._seq), didx))
                self.max_concurrency = max(self.max_concurrency,
                                           self.system.concurrency())
            else:
                blocked_clients.add(dreq.client)
                still.append(didx)
        self._deferred = still


# Backwards-compatible name: the old serial AdmissionScheduler is subsumed —
# one request at a time is just the R=1 special case of the event loop.
AdmissionScheduler = ContinuousBatchingScheduler
