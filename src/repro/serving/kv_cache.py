"""Serving caches for the geo engine.

Two granularities:

* ``new_block_cache`` / ``write_prefill_kv`` — single-session per-(server,
  session, layer) caches.  Kept for API compatibility and for callers that
  manage their own cache dicts.
* ``CachePool`` — the continuous-batching layout: per server, ONE stacked
  pytree whose leaves carry ``(n_layers, n_rows, ...)`` so a single jitted
  block call (vmapped over rows, scanned over layers) serves every session
  resident on that server.  Rows are allocated/freed per session; the pool
  shape never changes, so the engine's decode step traces exactly once per
  server regardless of how sessions come and go.

Slot accounting follows eq. (5)/(20) of the paper: a server hosting ``m``
blocks has ``⌊(M_j − s_m·m_j)/s_c⌋`` cache *block-slots*; a session routed
through ``k`` of the server's blocks occupies ``k`` block-slots from start
to retirement.  ``CachePool`` enforces both the row budget (physical arrays)
and the block-slot budget (the paper's memory model) — the no-overbooking
commitment.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Single-session caches (legacy granularity, used by failover replay helpers)
# ---------------------------------------------------------------------------


def new_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    """Allocate one per-(server, session, layer) cache: KV tensors for
    ``decoder`` blocks (MLA latent/krope when ``cfg.attn_kind == 'mla'``) or
    recurrent state for ``rwkv`` blocks."""
    cdt = jnp.dtype(cfg.param_dtype)
    if kind == "decoder":
        if cfg.attn_kind == "mla":
            return {
                "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cdt),
                "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), cdt),
            }
        kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)}
    if kind == "rwkv":
        h, hd = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "shift_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "shift_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        }
    raise NotImplementedError(
        f"engine cache for block kind {kind!r}; BPRR semantics for the "
        "remaining families run through the simulator and monolithic steps")


def write_prefill_kv(cache: Dict, kv, length: int) -> Dict:
    """Insert full-sequence K/V (or MLA latent) into a preallocated cache."""
    out = dict(cache)
    if "latent" in cache:
        latent, krope = kv
        out["latent"] = cache["latent"].at[:, :length].set(
            latent.astype(cache["latent"].dtype))
        out["krope"] = cache["krope"].at[:, :length].set(
            krope.astype(cache["krope"].dtype))
    else:
        k, v = kv
        out["k"] = cache["k"].at[:, :length].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, :length].set(v.astype(cache["v"].dtype))
    return out


# ---------------------------------------------------------------------------
# Batched slot pools (continuous batching)
# ---------------------------------------------------------------------------


def new_cache_pool_tree(cfg: ModelConfig, kind: str, n_layers: int,
                        n_rows: int, max_len: int):
    """Stacked caches: leaves (n_layers, n_rows, ...)."""
    cdt = jnp.dtype(cfg.param_dtype)
    L, N, T = n_layers, n_rows, max_len
    if kind == "decoder":
        if cfg.attn_kind == "mla":
            return {
                "latent": jnp.zeros((L, N, T, cfg.kv_lora_rank), cdt),
                "krope": jnp.zeros((L, N, T, cfg.rope_head_dim), cdt),
            }
        kv = (L, N, T, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)}
    if kind == "rwkv":
        h, hd = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "wkv": jnp.zeros((L, N, h, hd, hd), jnp.float32),
            "shift_tm": jnp.zeros((L, N, cfg.d_model), jnp.float32),
            "shift_cm": jnp.zeros((L, N, cfg.d_model), jnp.float32),
        }
    raise NotImplementedError(
        f"cache pool for block kind {kind!r}; remaining families run "
        "through the simulator and monolithic serve steps")


class CachePool:
    """Row + block-slot bookkeeping around the stacked cache pytree of ONE
    server.

    * ``n_rows`` physical rows (the vmapped batch extent of the jitted step),
    * ``cap_slots`` block-slots per eq. (5): ⌊(M_j − s_m·m_j)/s_c⌋ — a
      session holding ``k`` of this server's blocks consumes ``k`` slots.
    """

    def __init__(self, cfg: ModelConfig, kind: str, n_layers: int,
                 n_rows: int, max_len: int, cap_slots: int):
        self.cfg = cfg
        self.kind = kind
        self.n_layers = n_layers
        self.n_rows = n_rows
        self.max_len = max_len
        self.cap_slots = int(cap_slots)
        self.tree = new_cache_pool_tree(cfg, kind, n_layers, n_rows, max_len)
        self._free: List[int] = list(range(n_rows))
        self.rows: Dict[int, int] = {}  # sid -> row
        self.blocks: Dict[int, int] = {}  # sid -> k block-slots held
        self.slots_used = 0

    # -- admission ----------------------------------------------------------
    def fits(self, sid: int, k_blocks: int) -> bool:
        if sid in self.rows:
            # re-entry (failover chain revisiting this server): no new row,
            # but the ADDITIONAL blocks still count against the budget
            return self.slots_used + k_blocks <= self.cap_slots
        return bool(self._free) and (self.slots_used + k_blocks
                                     <= self.cap_slots)

    def alloc(self, sid: int, k_blocks: int) -> int:
        """Claim one row + ``k_blocks`` slots.  Raises if over budget — the
        scheduler must check ``fits`` first (no-overbooking commitment)."""
        if self.slots_used + k_blocks > self.cap_slots:
            raise RuntimeError(
                f"block-slot overbooking: {self.slots_used}+{k_blocks} > "
                f"{self.cap_slots}")
        if sid in self.rows:  # re-entry: charge the extra blocks
            self.blocks[sid] += int(k_blocks)
            self.slots_used += int(k_blocks)
            return self.rows[sid]
        if not self._free:
            raise RuntimeError("cache pool rows exhausted")
        row = self._free.pop()
        self.rows[sid] = row
        self.blocks[sid] = int(k_blocks)
        self.slots_used += int(k_blocks)
        return row

    def release(self, sid: int):
        row = self.rows.pop(sid, None)
        if row is None:
            return
        self.slots_used -= self.blocks.pop(sid, 0)
        self._free.append(row)
        # stale row contents are never observable: a new occupant's prefill
        # overwrites [:prompt_len] (rwkv states entirely), and decode
        # attention masks kv_pos <= pos — so no zeroing (a full pool copy
        # per retirement) is needed.

    def n_sessions(self) -> int:
        return len(self.rows)

    # -- prefill writes -----------------------------------------------------
    def write_prefill_range(self, lo_rel: int, hi_rel: int, row: int,
                            entries: List[Dict], length: int):
        """Insert single-session per-layer cache entries (batch dim 1, one
        per layer in [lo_rel, hi_rel)) into the pool row.  Staged as ONE
        ranged update per leaf — a per-layer loop would copy the whole pool
        O(layers) times.  KV-type leaves write [:length]; state leaves
        (rwkv) overwrite whole."""
        assert len(entries) == hi_rel - lo_rel
        t = dict(self.tree)
        if self.kind == "decoder":
            keys = ("latent", "krope") if "latent" in t else ("k", "v")
        else:
            keys = ("wkv", "shift_tm", "shift_cm")
        for key in keys:
            stacked = jnp.stack([e[key][0] for e in entries]).astype(
                t[key].dtype)
            if self.kind == "decoder":
                t[key] = t[key].at[lo_rel:hi_rel, row, :length].set(stacked)
            else:
                t[key] = t[key].at[lo_rel:hi_rel, row].set(stacked)
        self.tree = t


# ---------------------------------------------------------------------------
# Prompt-length bucketing (batched prefill)
# ---------------------------------------------------------------------------


def default_prefill_buckets(max_prompt_len: int, base: int = 8
                            ) -> Tuple[int, ...]:
    """Power-of-two bucket lengths up to ``max_prompt_len``.

    The returned tuple always ends with ``max_prompt_len`` itself, so by
    default every admissible prompt fits some bucket and chunking never
    triggers; pass an explicit smaller bucket set to the engine to force
    chunked prefill for long prompts.
    """
    max_prompt_len = int(max_prompt_len)
    assert max_prompt_len >= 1
    out: List[int] = []
    b = base
    while b < max_prompt_len:
        out.append(b)
        b *= 2
    out.append(max_prompt_len)
    return tuple(out)


def bucket_for(buckets: Sequence[int], length: int) -> Optional[int]:
    """Smallest bucket >= ``length``; None when the prompt overflows every
    bucket (the engine then chunks it into max-bucket-sized pieces)."""
    for b in sorted(buckets):  # callers need not pre-sort
        if b >= length:
            return int(b)
    return None


@functools.lru_cache(maxsize=None)
def make_pool_prefill_step(cfg: ModelConfig, kind: str):
    """Build THE jitted multi-session prefill step, shared per (cfg, kind).

    pstep(stacked_params, pool_tree, h, layer_active, layer_ids, offset=0)
      -> (h, pool_tree)

    * ``h``: (n_rows, T_chunk, d_model) right-padded hidden rows — one row
      per co-admitted session of a bucket group (same row indices as the
      decode step),
    * ``offset``: STATIC chunk start position (0 for unchunked prompts);
      decoder rows attend over their pool cache [0, offset) (the previously
      prefilled chunks) plus the chunk itself, and the chunk's K/V is written
      at [offset, offset+T_chunk),
    * ``layer_active``: (n_layers, n_rows) bool — row r runs layer l iff set;
      inactive rows keep their hidden state and cache untouched,
    * ``layer_ids``: (n_layers,) int32 absolute layer indices.

    Like the decode step, the program depends only on shapes — never on
    which rows carry sessions — so per-session results are bit-for-bit
    identical between a group of one and a full bucket group.  The program
    retraces per (n_layers, n_rows, T_chunk, offset); buckets and chunk
    offsets keep that set small and bounded.

    RWKV pools must be called with ``offset == 0`` and ``T_chunk`` equal to
    the TRUE prompt length (no padding, no chunking): the state is recurrent,
    so trailing pad tokens would corrupt it.  The engine therefore groups
    rwkv sessions by exact prompt length.
    """
    from repro.models import blocks as B
    from repro.models.layers import NULL_SH

    def step(stacked_params, pool_tree, h, layer_active, layer_ids, offset):
        T = h.shape[1]
        positions = offset + jnp.arange(T)

        def body(hc, xs):
            p, cache, active, lid = xs

            if kind == "decoder":
                mla = "latent" in cache

                def one(hr, cr):
                    if mla:
                        prefix = (cr["latent"][None, :offset],
                                  cr["krope"][None, :offset])
                    else:
                        prefix = (cr["k"][None, :offset],
                                  cr["v"][None, :offset])
                    hh, cc, _ = B.decoder_block_full(
                        p, cfg, NULL_SH, hr[None], positions, lid,
                        prefix_kv=prefix)
                    return hh[0], jax.tree.map(lambda x: x[0], cc)

                h2, chunk = jax.vmap(one)(hc, cache)
                # masked ranged write of the chunk's entries at
                # [offset, offset+T) — inactive rows keep their old cache
                c2 = dict(cache)
                for key, val in chunk.items():
                    old = cache[key][:, offset:offset + T]
                    msk = active.reshape((-1,) + (1,) * (val.ndim - 1))
                    c2[key] = cache[key].at[:, offset:offset + T].set(
                        jnp.where(msk, val.astype(old.dtype), old))
            else:  # rwkv: full-sequence, exact length, whole-state write
                def one(hr):
                    hh, st = B.rwkv_block_full(p, cfg, NULL_SH, hr[None])
                    return hh[0], jax.tree.map(lambda x: x[0], st)

                h2, st = jax.vmap(one)(hc)
                c2 = jax.tree.map(
                    lambda new, old: jnp.where(
                        active.reshape((-1,) + (1,) * (new.ndim - 1)),
                        new.astype(old.dtype), old),
                    st, cache)
            h2 = jnp.where(active[:, None, None], h2, hc)
            return h2, c2

        h, new_pool = jax.lax.scan(
            body, h, (stacked_params, pool_tree, layer_active, layer_ids))
        return h, new_pool

    return jax.jit(step, static_argnums=(5,))


@functools.lru_cache(maxsize=None)
def make_prefill_block(cfg: ModelConfig, kind: str):
    """Jitted single-session per-layer prefill, shared across every server
    of the same (cfg, kind) — jax's jit cache then reuses compiled programs
    for servers with identical shapes."""
    from repro.models import blocks as B
    from repro.models.layers import NULL_SH

    if kind == "decoder":
        return jax.jit(lambda p, h, positions, lid: B.decoder_block_full(
            p, cfg, NULL_SH, h, positions, lid))
    return jax.jit(lambda p, h: B.rwkv_block_full(p, cfg, NULL_SH, h))


@functools.lru_cache(maxsize=None)
def make_pool_decode_step(cfg: ModelConfig, kind: str):
    """Build THE jitted multi-session decode step, shared per (cfg, kind) —
    each server calls it with its own (layers, rows) shapes.

    step(stacked_params, pool_tree, h, pos, layer_active, layer_ids)
      -> (h, pool_tree)

    * ``stacked_params``: per-layer block params stacked on axis 0 (n_layers),
    * ``pool_tree``: leaves (n_layers, n_rows, ...),
    * ``h``: (n_rows, 1, d_model) hidden rows,
    * ``pos``: (n_rows,) int32 cache write/attend position per row,
    * ``layer_active``: (n_layers, n_rows) bool — row r runs layer l iff set
      (a session's hop covers a contiguous sub-range of the server's blocks),
    * ``layer_ids``: (n_layers,) int32 absolute layer indices (for per-layer
      sliding-window patterns).

    The computation always spans ALL rows with fixed shapes: adding or
    removing sessions changes only the mask, never the traced program, so
    per-session results are bit-for-bit identical between a crowded pool and
    a pool with a single resident session.
    """
    from repro.models import blocks as B
    from repro.models.layers import NULL_SH

    def step(stacked_params, pool_tree, h, pos, layer_active, layer_ids):
        def body(hc, xs):
            p, cache, active, lid = xs

            if kind == "decoder":
                def one(hr, cr, pr):
                    hh, cc = B.decoder_block_decode(
                        p, cfg, NULL_SH, hr[None],
                        jax.tree.map(lambda x: x[None], cr), pr, lid)
                    return hh[0], jax.tree.map(lambda x: x[0], cc)

                h2, c2 = jax.vmap(one)(hc, cache, pos)
            else:  # rwkv
                def one(hr, cr):
                    hh, cc = B.rwkv_block_decode(
                        p, cfg, NULL_SH, hr[None],
                        jax.tree.map(lambda x: x[None], cr))
                    return hh[0], jax.tree.map(lambda x: x[0], cc)

                h2, c2 = jax.vmap(one)(hc, cache)
            # inactive rows keep their hidden state and caches untouched
            h2 = jnp.where(active[:, None, None], h2, hc)
            c2 = jax.tree.map(
                lambda new, old: jnp.where(
                    active.reshape((-1,) + (1,) * (new.ndim - 1)), new, old),
                c2, cache)
            return h2, c2

        h, new_pool = jax.lax.scan(
            body, h, (stacked_params, pool_tree, layer_active, layer_ids))
        return h, new_pool

    return jax.jit(step)
