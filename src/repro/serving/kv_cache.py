"""Family-polymorphic serving state pools for the geo engine.

Every BPRR block carries per-session serving state whose SHAPE depends on the
block's family: KV tensors (or MLA latents) for attention blocks, SSD+conv
state for mamba mixers, wkv/shift state for rwkv, self-KV plus encoder
cross-KV for enc-dec decoder blocks — and zamba2's shared-attention blocks
carry BOTH mamba state and a KV cache.  :class:`StateSpec` names that
contract per block kind; ``state_specs(cfg)`` derives the per-block spec
tuple from ``models.blocks.stack_block_kinds`` — the single dispatch point
replacing the old one-kind-per-engine restriction.

Two granularities:

* ``new_block_cache`` — single-session per-(server, session, layer) caches.
  Kept for API compatibility and for callers that manage their own cache
  dicts.
* ``CachePool`` — the continuous-batching layout: per server, ONE stacked
  state tree per *run* of same-kind hosted blocks, leaves
  ``(run_layers, n_rows, ...)``, so a single jitted step (vmapped over rows,
  scanned over each run) serves every session resident on that server.  The
  pooled step factories take the server's static per-layer kind tuple and
  dispatch each run to its family's block functions — the program still
  traces exactly once per server, heterogeneous or not.  They also take the
  engine's compute ``backend`` ("xla" oracle | "pallas" kernels with
  per-call XLA fallback, see ``repro.kernels.runtime``) and thread it into
  every block call; backend choice never changes round results
  (docs/serving.md).

Slot accounting follows eq. (5)/(20) of the paper unchanged (the memory
model is family-agnostic): a server hosting ``m`` blocks has
``⌊(M_j − s_m·m_j)/s_c⌋`` cache *block-slots*; a session routed through
``k`` of the server's blocks occupies ``k`` block-slots from start to
retirement.  ``CachePool`` enforces both the row budget (physical arrays)
and the block-slot budget — the no-overbooking commitment.

Two cache layouts share that accounting:

* ``layout="slab"`` (default, the exact reference twin): every row owns a
  fixed-width ``(max_len, ...)`` stripe of each time-indexed leaf, so one
  admitted session books worst-case memory whatever its actual length.
* ``layout="paged"``: the time axis of every *self-KV* leaf is carved into
  ``page_size``-token pages held in shared physical page arrays
  ``(layers, n_pages + 1, page_size, ...)``; a :class:`PagePool` free list
  plus one int32 page table ``(n_rows, max_pages)`` per server map row
  time-slices to physical pages (page id 0 is the reserved trash page for
  unassigned entries).  Admission books only the pages a prompt needs, and
  eq. (5)'s budget becomes page-granular: ``cap_units = cap_slots ×
  max_pages`` page-units against which a session through ``k`` blocks
  holding ``p`` pages charges ``k·p`` — the same ⌊(M_j − s_m·m_j)/s_c⌋
  bytes, metered at page rather than worst-case-sequence granularity.
  Recurrent / cross-KV leaves (wkv, ssm+conv, ck/cv) stay row-resident:
  their footprint is length-independent, so paging buys nothing there.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# StateSpec: the per-block serving-state contract
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StateSpec:
    """What one BPRR block needs from the serving layer.

    * ``kind``          — block kind (``models.blocks.stack_block_kinds``).
    * ``recurrent``     — carries order-sensitive recurrent state: prefill
      must run at the EXACT prompt length in one shot (no padding, no
      chunked resume) — rwkv and mamba mixers.
    * ``needs_emb0``    — consumes the stack's original embedding alongside
      the hidden state (zamba2's shared attention on concat(h, emb0)).
    * ``cross``         — holds encoder cross-KV (enc-dec decoder blocks);
      prefill needs the encoder output, decode needs the session's encoder
      length to mask the over-allocated cross cache.
    * ``decode_active`` — does per-token decode work at all (encoder blocks
      do not: their contribution is frozen into the cross-KV at prefill).
    """

    kind: str
    recurrent: bool = False
    needs_emb0: bool = False
    cross: bool = False
    decode_active: bool = True


_STATE_SPECS: Dict[str, StateSpec] = {
    "decoder": StateSpec("decoder"),
    "rwkv": StateSpec("rwkv", recurrent=True),
    "mamba": StateSpec("mamba", recurrent=True),
    "mamba_shared": StateSpec("mamba_shared", recurrent=True,
                              needs_emb0=True),
    "enc": StateSpec("enc", decode_active=False),
    "dec": StateSpec("dec", cross=True),
}

SUPPORTED_KINDS: Tuple[str, ...] = tuple(sorted(_STATE_SPECS))


def state_spec_for(kind: str) -> StateSpec:
    """The :class:`StateSpec` of one block kind; ``ValueError`` (naming the
    supported set) for anything else — no dead-end ``NotImplementedError``."""
    try:
        return _STATE_SPECS[kind]
    except KeyError:
        raise ValueError(
            f"no serving StateSpec for block kind {kind!r}; supported kinds: "
            + ", ".join(SUPPORTED_KINDS)) from None


def state_specs(cfg: ModelConfig) -> Tuple[StateSpec, ...]:
    """Per-block StateSpec tuple (length ``cfg.n_layers``) for a config."""
    from repro.models.blocks import stack_block_kinds

    return tuple(state_spec_for(k) for k in stack_block_kinds(cfg))


def kind_runs(kinds: Sequence[str]) -> Tuple[Tuple[str, int, int], ...]:
    """Maximal contiguous same-kind runs: ((kind, lo, hi), ...) covering
    ``range(len(kinds))``.  The pooled steps scan per run; a server's run
    structure is static, so its program still traces exactly once."""
    runs: List[Tuple[str, int, int]] = []
    for i, k in enumerate(kinds):
        if runs and runs[-1][0] == k:
            runs[-1] = (k, runs[-1][1], i + 1)
        else:
            runs.append((k, i, i + 1))
    return tuple(runs)


# ---------------------------------------------------------------------------
# Single-session caches (legacy granularity, used by failover replay helpers)
# ---------------------------------------------------------------------------


def new_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                    enc_len: int = 0):
    """Allocate one per-(server, session, layer) cache for any supported
    block kind: KV tensors for ``decoder`` (MLA latent/krope when
    ``cfg.attn_kind == 'mla'``), recurrent state for ``rwkv``/``mamba``,
    state + shared-attention KV for ``mamba_shared``, self-KV + encoder
    cross-KV for ``dec`` (``enc_len`` positions), and ``{}`` for the
    stateless ``enc`` blocks."""
    cdt = jnp.dtype(cfg.param_dtype)
    if kind == "decoder":
        if cfg.attn_kind == "mla":
            return {
                "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cdt),
                "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), cdt),
            }
        kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)}
    if kind == "rwkv":
        h, hd = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "shift_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "shift_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        }
    if kind in ("mamba", "mamba_shared"):
        h, p, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        out = {
            "ssm": jnp.zeros((batch, h, p, ns), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim),
                              jnp.float32),
        }
        if kind == "mamba_shared":
            kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            out["k"] = jnp.zeros(kv, cdt)
            out["v"] = jnp.zeros(kv, cdt)
        return out
    if kind == "enc":
        return {}  # bidirectional encoder blocks hold no serving state
    if kind == "dec":
        kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        ckv = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt),
                "ck": jnp.zeros(ckv, cdt), "cv": jnp.zeros(ckv, cdt)}
    raise ValueError(
        f"no engine cache for block kind {kind!r}; supported kinds: "
        + ", ".join(SUPPORTED_KINDS))


def write_prefill_kv(cache: Dict, kv, length: int) -> Dict:
    """Insert full-sequence K/V (or MLA latent) into a preallocated cache."""
    out = dict(cache)
    if "latent" in cache:
        latent, krope = kv
        out["latent"] = cache["latent"].at[:, :length].set(
            latent.astype(cache["latent"].dtype))
        out["krope"] = cache["krope"].at[:, :length].set(
            krope.astype(cache["krope"].dtype))
    else:
        k, v = kv
        out["k"] = cache["k"].at[:, :length].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, :length].set(v.astype(cache["v"].dtype))
    return out


# ---------------------------------------------------------------------------
# Batched slot pools (continuous batching)
# ---------------------------------------------------------------------------

# leaf names that index TIME along axis 2 of a pooled (layers, rows, T, ...)
# leaf — written per chunk at [offset, offset+T)
_SELF_KV_KEYS = frozenset({"k", "v", "latent", "krope"})
# encoder cross-KV leaves — written once, at [0, enc_len)
_CROSS_KV_KEYS = frozenset({"ck", "cv"})


def new_state_pool_tree(cfg: ModelConfig, kind: str, n_layers: int,
                        n_rows: int, max_len: int, enc_len: int = 0):
    """Stacked per-kind serving state: leaves (n_layers, n_rows, ...)."""
    cdt = jnp.dtype(cfg.param_dtype)
    L, N, T = n_layers, n_rows, max_len
    if kind == "decoder":
        if cfg.attn_kind == "mla":
            return {
                "latent": jnp.zeros((L, N, T, cfg.kv_lora_rank), cdt),
                "krope": jnp.zeros((L, N, T, cfg.rope_head_dim), cdt),
            }
        kv = (L, N, T, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)}
    if kind == "rwkv":
        h, hd = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "wkv": jnp.zeros((L, N, h, hd, hd), jnp.float32),
            "shift_tm": jnp.zeros((L, N, cfg.d_model), jnp.float32),
            "shift_cm": jnp.zeros((L, N, cfg.d_model), jnp.float32),
        }
    if kind in ("mamba", "mamba_shared"):
        h, p, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        tree = {
            "ssm": jnp.zeros((L, N, h, p, ns), jnp.float32),
            "conv": jnp.zeros((L, N, cfg.conv_width - 1, conv_dim),
                              jnp.float32),
        }
        if kind == "mamba_shared":
            kv = (L, N, T, cfg.n_kv_heads, cfg.head_dim)
            tree["k"] = jnp.zeros(kv, cdt)
            tree["v"] = jnp.zeros(kv, cdt)
        return tree
    if kind == "enc":
        return {}
    if kind == "dec":
        kv = (L, N, T, cfg.n_kv_heads, cfg.head_dim)
        ckv = (L, N, enc_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt),
                "ck": jnp.zeros(ckv, cdt), "cv": jnp.zeros(ckv, cdt)}
    raise ValueError(
        f"no state pool for block kind {kind!r}; supported kinds: "
        + ", ".join(SUPPORTED_KINDS))


def new_cache_pool_tree(cfg: ModelConfig, kind: str, n_layers: int,
                        n_rows: int, max_len: int):
    """Homogeneous-stack compatibility alias of ``new_state_pool_tree``."""
    return new_state_pool_tree(cfg, kind, n_layers, n_rows, max_len)


# ---------------------------------------------------------------------------
# Paged layout: free-list page allocator + paged state trees
# ---------------------------------------------------------------------------

TRASH_PAGE = 0  # physical page id 0: write target of every unassigned entry


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache positions (0 for 0)."""
    n_tokens = int(n_tokens)
    assert n_tokens >= 0
    return -(-n_tokens // int(page_size))


class PagePool:
    """Deterministic free-list page allocator (the vLLM block-table trick).

    Physical pages are numbered ``1..n_pages``; id ``TRASH_PAGE == 0`` is
    reserved as the write target of unassigned page-table entries, so the
    jitted gather/scatter never needs a validity branch.  ``table`` is the
    shared int32 page table ``(n_rows, max_pages_per_row)``: row ``r``'s
    time-slice ``[i*page_size, (i+1)*page_size)`` lives in physical page
    ``table[r, i]`` (0 = unassigned).  Rows grow monotonically
    (``grow_to``) and free wholesale (``free_row`` — preemption and
    retirement are the same operation to the allocator).

    The free list is LIFO and all operations are pure functions of the
    call sequence — replaying the same sequence reproduces the same
    tables bit-for-bit (the property suite in tests/test_paged_pools.py
    fuzzes exactly these invariants via ``check_invariants``).
    """

    def __init__(self, n_pages: int, n_rows: int, max_pages_per_row: int):
        self.n_pages = int(n_pages)
        self.n_rows = int(n_rows)
        self.max_pages_per_row = int(max_pages_per_row)
        assert self.n_pages >= 0 and self.n_rows >= 1
        assert self.max_pages_per_row >= 1
        self.table = np.zeros((self.n_rows, self.max_pages_per_row),
                              np.int32)
        self.count = np.zeros((self.n_rows,), np.int32)
        # LIFO free list; initialized so the first pops hand out 1, 2, 3...
        self._free: List[int] = list(range(self.n_pages, 0, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return int(self.count.sum())

    def pages_of(self, row: int) -> List[int]:
        """The live page ids of ``row`` in table order."""
        return [int(self.table[row, i])
                for i in range(int(self.count[row]))]

    def can_grow(self, row: int, n_pages: int) -> bool:
        return n_pages - int(self.count[row]) <= len(self._free)

    def grow_to(self, row: int, n_pages: int) -> List[int]:
        """Extend ``row`` to ``n_pages`` pages (no-op when already there);
        returns the newly assigned page ids.  Raises on free-list
        exhaustion — callers must check ``can_grow``."""
        have = int(self.count[row])
        if n_pages <= have:
            return []
        if n_pages > self.max_pages_per_row:
            raise RuntimeError(
                f"row {row}: {n_pages} pages exceed the per-row table "
                f"width {self.max_pages_per_row}")
        if n_pages - have > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: row {row} needs {n_pages - have} "
                f"pages, {len(self._free)} free")
        fresh = []
        for i in range(have, n_pages):
            pid = self._free.pop()
            self.table[row, i] = pid
            fresh.append(pid)
        self.count[row] = n_pages
        return fresh

    def free_row(self, row: int) -> List[int]:
        """Return every page of ``row`` to the free list (reverse order, so
        alloc→free→alloc round-trips reproduce the same page ids).
        Returns the freed page ids."""
        freed = []
        for i in reversed(range(int(self.count[row]))):
            pid = int(self.table[row, i])
            self._free.append(pid)
            freed.append(pid)
            self.table[row, i] = 0
        self.count[row] = 0
        return freed

    def check_invariants(self):
        """Allocator invariants (the property-test contract):
        * entries beyond ``count[r]`` are 0; entries below are in
          ``[1, n_pages]`` — tables only reference live pages,
        * no physical page is referenced twice (no double-booking),
        * live ∪ free is a partition of ``{1..n_pages}`` (conservation).
        """
        live: List[int] = []
        for r in range(self.n_rows):
            c = int(self.count[r])
            assert 0 <= c <= self.max_pages_per_row
            assert (self.table[r, c:] == 0).all(), f"row {r}: stale entries"
            ids = self.table[r, :c].tolist()
            assert all(1 <= p <= self.n_pages for p in ids), \
                f"row {r}: out-of-range page id"
            live.extend(ids)
        assert len(live) == len(set(live)), "double-booked page"
        free = self._free
        assert len(free) == len(set(free)), "duplicate free-list entry"
        assert not set(live) & set(free), "page both live and free"
        assert len(live) + len(free) == self.n_pages, "page leak"


def new_paged_pool_tree(cfg: ModelConfig, kind: str, n_layers: int,
                        n_rows: int, max_len: int, page_size: int,
                        n_phys: int, enc_len: int = 0):
    """Paged-layout state tree: self-KV leaves become shared physical page
    arrays ``(n_layers, n_phys, page_size, ...)`` (``n_phys`` includes the
    trash page) addressed through the pool's page table; every other leaf
    keeps its row-resident ``(n_layers, n_rows, ...)`` slab layout."""
    template = new_state_pool_tree(cfg, kind, n_layers, 1, max_len, enc_len)
    out = {}
    for key, leaf in template.items():
        if key in _SELF_KV_KEYS:
            out[key] = jnp.zeros(
                (n_layers, n_phys, page_size) + leaf.shape[3:], leaf.dtype)
        else:
            out[key] = jnp.zeros((n_layers, n_rows) + leaf.shape[2:],
                                 leaf.dtype)
    return out


class CachePool:
    """Row + block-slot bookkeeping around the stacked state trees of ONE
    server.

    * the hosted block range is described by its per-layer ``kinds``; the
      state lives in one stacked subtree per same-kind run
      (``self.tree[r]`` for ``self.runs[r]``),
    * ``n_rows`` physical rows (the vmapped batch extent of the jitted step),
    * ``cap_slots`` block-slots per eq. (5): ⌊(M_j − s_m·m_j)/s_c⌋ — a
      session holding ``k`` of this server's blocks consumes ``k`` slots.

    ``layout="paged"`` carves the self-KV time axis into ``page_size``-token
    pages (see the module docstring): the budget becomes ``cap_units =
    cap_slots × max_pages`` page-units, a session through ``k`` blocks
    holding ``p`` pages charges ``k·p`` units, and physical page arrays are
    sized to the SAME byte budget (``cap_slots × max_pages / n_layers``
    pages, clamped to what the rows could ever reference) — so both the
    accounting and the free list enforce eq. (5), just page-granular.
    """

    def __init__(self, cfg: ModelConfig, kinds: Sequence[str], n_rows: int,
                 max_len: int, cap_slots: int, enc_len: int = 0,
                 layout: str = "slab", page_size: int = 0):
        assert layout in ("slab", "paged"), layout
        self.cfg = cfg
        self.kinds = tuple(kinds)
        self.runs = kind_runs(self.kinds)
        self.n_layers = len(self.kinds)
        self.n_rows = n_rows
        self.max_len = max_len
        self.enc_len = int(enc_len)
        self.cap_slots = int(cap_slots)
        self.layout = layout
        if layout == "paged":
            page_size = int(page_size)
            if page_size < 1 or max_len % page_size != 0:
                raise ValueError(
                    f"page_size {page_size} must be >= 1 and divide "
                    f"max_len {max_len} (keeps the paged time axis "
                    "identical to the slab reference)")
            self.page_size = page_size
            self.max_pages = max_len // page_size
            self.cap_units = self.cap_slots * self.max_pages
            n_phys = max(1, min(
                self.cap_units // max(1, self.n_layers),
                n_rows * self.max_pages))
            self.pages = PagePool(n_phys, n_rows, self.max_pages)
            self.units_used = 0
            self.sid_pages: Dict[int, int] = {}  # sid -> pages held
            self.tree = tuple(
                new_paged_pool_tree(cfg, kind, hi - lo, n_rows, max_len,
                                    page_size, n_phys + 1, self.enc_len)
                for kind, lo, hi in self.runs)
        else:
            self.page_size = 0
            self.tree: Tuple[Dict, ...] = tuple(
                new_state_pool_tree(cfg, kind, hi - lo, n_rows, max_len,
                                    self.enc_len)
                for kind, lo, hi in self.runs)
        self._free: List[int] = list(range(n_rows))
        self.rows: Dict[int, int] = {}  # sid -> row
        self.blocks: Dict[int, int] = {}  # sid -> k block-slots held
        self.slots_used = 0

    # -- admission ----------------------------------------------------------
    def pages_needed(self, n_tokens: int) -> int:
        """Pages covering ``n_tokens`` cache positions (paged layout)."""
        return pages_for(n_tokens, self.page_size)

    def fits(self, sid: int, k_blocks: int, n_pages: int = 0,
             worst_pages: Optional[int] = None) -> bool:
        """No-overbooking check.  Paged layout: ``n_pages`` is the page
        count to book now (ignored on re-entry — the resident pages are
        shared across the session's hops) and ``worst_pages`` optionally
        asserts solo-completability: the fully-grown session must fit this
        server ALONE, so a preempted session can always eventually resume
        (the deadlock-freedom guarantee preemption relies on)."""
        if self.layout == "paged":
            p = self.sid_pages.get(sid, 0) if sid in self.rows \
                else int(n_pages)
            k_total = self.blocks.get(sid, 0) + k_blocks
            if worst_pages is not None:
                if (k_total * int(worst_pages) > self.cap_units
                        or int(worst_pages) > min(self.pages.n_pages,
                                                  self.max_pages)):
                    return False
            if sid in self.rows:
                return self.units_used + k_blocks * p <= self.cap_units
            return (bool(self._free)
                    and self.units_used + k_blocks * p <= self.cap_units
                    and p <= self.pages.free_pages)
        if sid in self.rows:
            # re-entry (failover chain revisiting this server): no new row,
            # but the ADDITIONAL blocks still count against the budget
            return self.slots_used + k_blocks <= self.cap_slots
        return bool(self._free) and (self.slots_used + k_blocks
                                     <= self.cap_slots)

    def alloc(self, sid: int, k_blocks: int, n_pages: int = 0) -> int:
        """Claim one row + ``k_blocks`` slots (slab) or one row +
        ``n_pages`` pages charged at ``k_blocks × n_pages`` page-units
        (paged).  Raises if over budget — the scheduler must check
        ``fits`` first (no-overbooking commitment)."""
        if self.layout == "paged":
            p = self.sid_pages[sid] if sid in self.rows else int(n_pages)
            if self.units_used + k_blocks * p > self.cap_units:
                raise RuntimeError(
                    f"page-unit overbooking: {self.units_used}+"
                    f"{k_blocks}*{p} > {self.cap_units}")
            if sid in self.rows:  # re-entry: charge the extra blocks
                self.blocks[sid] += int(k_blocks)
                self.units_used += int(k_blocks) * p
                return self.rows[sid]
            if not self._free:
                raise RuntimeError("cache pool rows exhausted")
            row = self._free.pop()
            self.pages.grow_to(row, p)
            self.rows[sid] = row
            self.blocks[sid] = int(k_blocks)
            self.sid_pages[sid] = p
            self.units_used += int(k_blocks) * p
            return row
        if self.slots_used + k_blocks > self.cap_slots:
            raise RuntimeError(
                f"block-slot overbooking: {self.slots_used}+{k_blocks} > "
                f"{self.cap_slots}")
        if sid in self.rows:  # re-entry: charge the extra blocks
            self.blocks[sid] += int(k_blocks)
            self.slots_used += int(k_blocks)
            return self.rows[sid]
        if not self._free:
            raise RuntimeError("cache pool rows exhausted")
        row = self._free.pop()
        self.rows[sid] = row
        self.blocks[sid] = int(k_blocks)
        self.slots_used += int(k_blocks)
        return row

    # -- page growth (paged layout) -----------------------------------------
    def can_grow(self, sid: int, n_pages: int) -> bool:
        """True iff ``sid`` can be extended to ``n_pages`` total pages
        within both the page-unit budget and the physical free list."""
        assert self.layout == "paged"
        extra = int(n_pages) - self.sid_pages[sid]
        if extra <= 0:
            return True
        return (self.units_used + self.blocks[sid] * extra <= self.cap_units
                and self.pages.can_grow(self.rows[sid], int(n_pages)))

    def grow_pages(self, sid: int, n_pages: int):
        """Extend ``sid`` to ``n_pages`` total pages (decode growth).
        Raises on overbooking — callers check ``can_grow`` first."""
        assert self.layout == "paged"
        extra = int(n_pages) - self.sid_pages[sid]
        if extra <= 0:
            return
        if self.units_used + self.blocks[sid] * extra > self.cap_units:
            raise RuntimeError(
                f"page-unit overbooking on grow: {self.units_used}+"
                f"{self.blocks[sid]}*{extra} > {self.cap_units}")
        self.pages.grow_to(self.rows[sid], int(n_pages))
        self.sid_pages[sid] = int(n_pages)
        self.units_used += self.blocks[sid] * extra

    def release(self, sid: int):
        row = self.rows.pop(sid, None)
        if row is None:
            return
        if self.layout == "paged":
            self.units_used -= self.blocks.pop(sid, 0) * \
                self.sid_pages.pop(sid, 0)
            self.pages.free_row(row)
        else:
            self.slots_used -= self.blocks.pop(sid, 0)
        self._free.append(row)
        # stale row contents are never observable: a new occupant's prefill
        # overwrites [:prompt_len] (recurrent states entirely), decode
        # attention masks kv_pos <= pos, and cross-attention masks
        # kv_pos < enc_len — so no zeroing (a full pool copy per retirement)
        # is needed.  The paged layout leans on the same invariant: freed
        # pages re-enter the free list with stale contents, and a reader
        # only ever sees a page through its own table entries at masked-in
        # positions it has itself written.

    def usage(self) -> Tuple[int, int]:
        """(used, capacity) in the layout's accounting unit: block-slots
        for slab, page-units (block-slots × pages) for paged."""
        if self.layout == "paged":
            return self.units_used, self.cap_units
        return self.slots_used, self.cap_slots

    def page_table(self) -> jnp.ndarray:
        """The device copy of the shared page table (paged layout)."""
        return jnp.asarray(self.pages.table)

    def n_sessions(self) -> int:
        return len(self.rows)

    # -- prefill writes -----------------------------------------------------
    def write_prefill_range(self, lo_rel: int, hi_rel: int, row: int,
                            entries: List[Dict], length: int):
        """Insert single-session per-layer cache entries (batch dim 1, one
        per layer in [lo_rel, hi_rel)) into the pool row.  Staged as ONE
        ranged update per leaf per run — a per-layer loop would copy the
        whole pool O(layers) times.  Self-KV leaves write [:length];
        cross-KV leaves write their own (encoder) length; recurrent state
        leaves overwrite whole.  Paged layout: self-KV tokens scatter into
        the row's physical pages (one ranged update per page — the serial
        reference path, so a handful of dispatches is fine)."""
        assert len(entries) == hi_rel - lo_rel
        new_tree = list(self.tree)
        for r, (kind, rlo, rhi) in enumerate(self.runs):
            lo, hi = max(lo_rel, rlo), min(hi_rel, rhi)
            if lo >= hi:
                continue
            sub = entries[lo - lo_rel: hi - lo_rel]
            t = dict(new_tree[r])
            for key in t:
                stacked = jnp.stack([e[key][0] for e in sub]).astype(
                    t[key].dtype)
                if key in _SELF_KV_KEYS:
                    if self.layout == "paged":
                        X = t[key]
                        pg = self.page_size
                        for pi in range(self.pages_needed(length)):
                            ppid = int(self.pages.table[row, pi])
                            a, b = pi * pg, min(length, (pi + 1) * pg)
                            X = X.at[lo - rlo:hi - rlo, ppid, :b - a].set(
                                stacked[:, a:b])
                        t[key] = X
                    else:
                        t[key] = t[key].at[lo - rlo:hi - rlo, row,
                                           :length].set(stacked[:, :length])
                elif key in _CROSS_KV_KEYS:
                    el = stacked.shape[1]
                    t[key] = t[key].at[lo - rlo:hi - rlo, row,
                                       :el].set(stacked)
                else:  # recurrent state: whole overwrite
                    t[key] = t[key].at[lo - rlo:hi - rlo, row].set(stacked)
            new_tree[r] = t
        self.tree = tuple(new_tree)


# ---------------------------------------------------------------------------
# Prompt-length bucketing (batched prefill)
# ---------------------------------------------------------------------------


def default_prefill_buckets(max_prompt_len: int, base: int = 8
                            ) -> Tuple[int, ...]:
    """Power-of-two bucket lengths up to ``max_prompt_len``.

    The returned tuple always ends with ``max_prompt_len`` itself, so by
    default every admissible prompt fits some bucket and chunking never
    triggers; pass an explicit smaller bucket set to the engine to force
    chunked prefill for long prompts.
    """
    max_prompt_len = int(max_prompt_len)
    assert max_prompt_len >= 1
    out: List[int] = []
    b = base
    while b < max_prompt_len:
        out.append(b)
        b *= 2
    out.append(max_prompt_len)
    return tuple(out)


def bucket_for(buckets: Sequence[int], length: int,
               specs: Optional[Sequence[StateSpec]] = None) -> Optional[int]:
    """Smallest bucket >= ``length``; None when the prompt overflows every
    bucket (the engine then chunks it into max-bucket-sized pieces).

    Family-aware rule: when ``specs`` contains any layer with RECURRENT
    state (rwkv, mamba — order-sensitive; trailing pad tokens would corrupt
    it), the bucket is the exact prompt length: grouping still batches
    equal lengths, but padding and chunking are attention-only."""
    if specs is not None and any(s.recurrent for s in specs):
        return int(length)
    for b in sorted(buckets):  # callers need not pre-sort
        if b >= length:
            return int(b)
    return None


# ---------------------------------------------------------------------------
# Kind-dispatched pooled steps (ONE jitted program per server)
# ---------------------------------------------------------------------------


def _mask_tree(new, old, active):
    """Keep ``old`` on inactive rows; leaves are (n_rows, ...)."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            active.reshape((-1,) + (1,) * (n.ndim - 1)),
            n.astype(o.dtype), o),
        new, old)


def _masked_ranged_write(cache, chunk, active, keys, lo, span):
    """Ranged [lo, lo+span) masked write of chunk leaves named ``keys``."""
    out = dict(cache)
    for key in keys:
        old = cache[key][:, lo:lo + span]
        msk = active.reshape((-1,) + (1,) * (chunk[key].ndim - 1))
        out[key] = cache[key].at[:, lo:lo + span].set(
            jnp.where(msk, chunk[key].astype(old.dtype), old))
    return out


def _prefill_step_body(cfg: ModelConfig, kinds: Tuple[str, ...],
                       backend: str):
    """The UNJITTED multi-session prefill step body shared by
    :func:`make_pool_prefill_step` (slab layout) and
    :func:`make_paged_prefill_step` (which wraps it in the page
    gather/scatter).

    pstep(run_params, shared_params, pool_trees, h, emb0, enc_rows,
          layer_active, layer_ids, offset, phase) -> (h, pool_trees)

    * ``run_params``: tuple of per-run stacked block params (axis 0 = the
      run's layers); ``shared_params``: zamba2's parameter-shared attention
      block (None otherwise),
    * ``pool_trees``: tuple of per-run state subtrees (see ``CachePool``),
    * ``h``: (n_rows, T_chunk, d) right-padded hidden rows — one row per
      co-admitted session of a bucket group (same row indices as decode),
    * ``emb0``: (n_rows, T_chunk, d) original embeddings for shared-attn
      blocks (a dummy leaf when no block needs it),
    * ``enc_rows``: (n_rows, T_enc, d) encoder outputs for cross-attention
      blocks (dummy otherwise),
    * ``offset``: STATIC chunk start (0 for unchunked prompts); attention
      rows attend over their pool cache [0, offset) plus the chunk and the
      chunk's K/V is written at [offset, offset+T_chunk),
    * ``phase``: STATIC — "all" (single-phase stacks), "enc" (run only
      encoder blocks; ``h`` carries frame embeddings) or "dec" (run only
      non-encoder blocks; ``h`` carries token embeddings),
    * ``layer_active``: (n_layers, n_rows) bool — row r runs layer l iff
      set; inactive rows keep their hidden state and state untouched.

    Like the decode step, the program depends only on shapes — never on
    which rows carry sessions — so per-session results are bit-for-bit
    identical between a group of one and a full bucket group.  Recurrent
    kinds (rwkv, mamba, mamba_shared) require ``offset == 0`` and
    ``T_chunk`` equal to the TRUE prompt length: their state is
    order-sensitive, so trailing pad tokens would corrupt it.  The engine
    therefore groups recurrent-stack sessions by exact prompt length.
    """
    from repro.kernels.runtime import resolve_backend
    from repro.models import blocks as B
    from repro.models.layers import NULL_SH

    resolve_backend(backend)
    runs = kind_runs(kinds)
    mla = cfg.attn_kind == "mla"

    def step(run_params, shared_params, pool_trees, h, emb0, enc_rows,
             layer_active, layer_ids, offset, phase):
        T = h.shape[1]
        positions = offset + jnp.arange(T)
        new_trees = list(pool_trees)
        for r, (kind, lo, hi) in enumerate(runs):
            if phase == "enc" and kind != "enc":
                continue
            if phase == "dec" and kind == "enc":
                continue
            if kind in ("rwkv", "mamba", "mamba_shared") and offset != 0:
                raise ValueError(
                    f"recurrent-state kind {kind!r} cannot resume prefill "
                    "at a nonzero chunk offset")
            p_stack, tree = run_params[r], pool_trees[r]
            act, lids = layer_active[lo:hi], layer_ids[lo:hi]

            if kind == "decoder":
                def body(hc, xs):
                    p, cache, active, lid = xs

                    def one(hr, cr):
                        if mla:
                            prefix = (cr["latent"][None, :offset],
                                      cr["krope"][None, :offset])
                        else:
                            prefix = (cr["k"][None, :offset],
                                      cr["v"][None, :offset])
                        hh, cc, _ = B.decoder_block_full(
                            p, cfg, NULL_SH, hr[None], positions, lid,
                            prefix_kv=prefix, backend=backend)
                        return hh[0], jax.tree.map(lambda x: x[0], cc)

                    h2, chunk = jax.vmap(one)(hc, cache)
                    c2 = _masked_ranged_write(cache, chunk, active,
                                              tuple(chunk), offset, T)
                    h2 = jnp.where(active[:, None, None], h2, hc)
                    return h2, c2
            elif kind in ("rwkv", "mamba"):
                blk = (B.rwkv_block_full if kind == "rwkv"
                       else B.mamba_block_full)

                def body(hc, xs, blk=blk):
                    p, cache, active, lid = xs

                    def one(hr):
                        hh, st = blk(p, cfg, NULL_SH, hr[None],
                                     backend=backend)
                        return hh[0], jax.tree.map(lambda x: x[0], st)

                    h2, st = jax.vmap(one)(hc)
                    c2 = _mask_tree(st, cache, active)
                    h2 = jnp.where(active[:, None, None], h2, hc)
                    return h2, c2
            elif kind == "mamba_shared":
                def body(hc, xs):
                    p, cache, active, lid = xs

                    def one(hr, er):
                        hh, st = B.mamba_block_full(p, cfg, NULL_SH, hr[None],
                                                    backend=backend)
                        hh, kv = B.zamba_shared_full(
                            shared_params, cfg, NULL_SH, hh, er[None],
                            positions, backend=backend)
                        return hh[0], {
                            "ssm": st["ssm"][0], "conv": st["conv"][0],
                            "k": kv["k"][0], "v": kv["v"][0]}

                    h2, st = jax.vmap(one)(hc, emb0)
                    c2 = dict(cache, **_mask_tree(
                        {"ssm": st["ssm"], "conv": st["conv"]},
                        {"ssm": cache["ssm"], "conv": cache["conv"]},
                        active))
                    c2 = _masked_ranged_write(c2, st, active, ("k", "v"),
                                              0, T)
                    h2 = jnp.where(active[:, None, None], h2, hc)
                    return h2, c2
            elif kind == "enc":
                def body(hc, xs):
                    p, cache, active, lid = xs

                    def one(hr):
                        return B.encoder_block_full(
                            p, cfg, NULL_SH, hr[None], positions,
                            backend=backend)[0]

                    h2 = jax.vmap(one)(hc)
                    h2 = jnp.where(active[:, None, None], h2, hc)
                    return h2, cache
            elif kind == "dec":
                def body(hc, xs):
                    p, cache, active, lid = xs

                    def one(hr, er, cr):
                        prefix = (cr["k"][None, :offset],
                                  cr["v"][None, :offset])
                        # cross-KV is offset-independent: computed on the
                        # first chunk, read back from the pool after
                        enc_kv = None if offset == 0 else (
                            cr["ck"][None, :er.shape[0]],
                            cr["cv"][None, :er.shape[0]])
                        hh, cc = B.cross_decoder_block_full(
                            p, cfg, NULL_SH, hr[None], positions, er[None],
                            prefix_kv=prefix, enc_kv=enc_kv,
                            backend=backend)
                        return hh[0], jax.tree.map(lambda x: x[0], cc)

                    h2, chunk = jax.vmap(one)(hc, enc_rows, cache)
                    c2 = _masked_ranged_write(cache, chunk, active,
                                              ("k", "v"), offset, T)
                    if offset == 0:  # cross-KV is chunk-independent
                        c2 = _masked_ranged_write(
                            c2, chunk, active, ("ck", "cv"), 0,
                            chunk["ck"].shape[1])
                    h2 = jnp.where(active[:, None, None], h2, hc)
                    return h2, c2
            else:
                raise ValueError(kind)

            h, new_tree = jax.lax.scan(body, h, (p_stack, tree, act, lids))
            new_trees[r] = new_tree
        return h, tuple(new_trees)

    return step


def _mesh_constraints(mesh, frozen_rules):
    """Sharding-constraint closures for a TP/EP device-group server.

    Returns ``(pools, rows, repl)``:

    * ``pools(trees)`` constrains a pool-tree tuple (slab or paged) to its
      :func:`repro.launch.sharding.pool_tree_shardings` layout,
    * ``rows(x, *logical)`` constrains one activation/vector by logical
      axes through the divisibility-guarded spec,
    * ``repl(x)`` pins per-round index vectors / masks replicated.

    Only built on the ``mesh is not None`` factory paths — the
    ``mesh=None`` twin never routes through this module's sharding code.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.sharding import (guarded_spec, pool_tree_shardings,
                                       thaw_rules)

    rules = thaw_rules(frozen_rules)

    def pools(trees):
        sh = pool_tree_shardings(mesh, rules, trees)
        return jax.tree.map(jax.lax.with_sharding_constraint, trees, sh)

    def rows(x, *logical):
        spec = guarded_spec(logical, x.shape, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    def repl(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))

    return pools, rows, repl


@functools.lru_cache(maxsize=None)
def make_pool_prefill_step(cfg: ModelConfig, kinds: Tuple[str, ...],
                           backend: str = "xla", mesh=None, rules=None):
    """THE jitted multi-session prefill step for a hosted block range,
    shared per (cfg, per-layer kind tuple, compute backend[, mesh]) — see
    :func:`_prefill_step_body` for the calling contract.

    Pool trees donated: chunk writes update the pool in place (same
    aliasing contract as make_pool_decode_step — the caller rebinds its
    pool reference to the returned tree and never reads the old one).

    ``mesh``/``rules``: optional device-group sharding (``rules`` is a
    frozen rules mapping, see ``launch.sharding.freeze_rules``).  With a
    mesh, pool trees / hidden rows / params follow the NamedShardings the
    rules derive and XLA partitions the step across the group;
    ``mesh=None`` is the byte-identical single-device reference twin."""
    if mesh is None:
        return jax.jit(_prefill_step_body(cfg, kinds, backend),
                       static_argnums=(8, 9), donate_argnums=(2,))
    body = _prefill_step_body(cfg, kinds, backend)
    pools, rows, repl = _mesh_constraints(mesh, rules)

    def step(run_params, shared_params, pool_trees, h, emb0, enc_rows,
             layer_active, layer_ids, offset, phase):
        pool_trees = pools(pool_trees)
        h = rows(h, "batch", None, None)
        emb0 = rows(emb0, "batch", None, None)
        enc_rows = rows(enc_rows, "batch", None, None)
        layer_active, layer_ids = repl(layer_active), repl(layer_ids)
        h, new_trees = body(run_params, shared_params, pool_trees, h, emb0,
                            enc_rows, layer_active, layer_ids, offset,
                            phase)
        return rows(h, "batch", None, None), pools(new_trees)

    return jax.jit(step, static_argnums=(8, 9), donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def make_prefill_block(cfg: ModelConfig, kind: str, backend: str = "xla"):
    """Jitted single-session per-layer prefill (the serial reference path),
    shared across every server of the same (cfg, kind, backend) — jax's jit
    cache then reuses compiled programs for servers with identical shapes."""
    from repro.kernels.runtime import resolve_backend
    from repro.models import blocks as B
    from repro.models.layers import NULL_SH

    resolve_backend(backend)
    if kind == "decoder":
        return jax.jit(lambda p, h, positions, lid: B.decoder_block_full(
            p, cfg, NULL_SH, h, positions, lid, backend=backend))
    if kind == "rwkv":
        return jax.jit(lambda p, h: B.rwkv_block_full(p, cfg, NULL_SH, h,
                                                      backend=backend))
    if kind == "mamba":
        return jax.jit(lambda p, h: B.mamba_block_full(p, cfg, NULL_SH, h,
                                                       backend=backend))
    if kind == "mamba_shared":
        def f(p, shared, h, emb0, positions):
            h, st = B.mamba_block_full(p, cfg, NULL_SH, h, backend=backend)
            h, kv = B.zamba_shared_full(shared, cfg, NULL_SH, h, emb0,
                                        positions, backend=backend)
            return h, {"ssm": st["ssm"], "conv": st["conv"],
                       "k": kv["k"], "v": kv["v"]}
        return jax.jit(f)
    if kind == "enc":
        return jax.jit(lambda p, h, positions: B.encoder_block_full(
            p, cfg, NULL_SH, h, positions, backend=backend))
    if kind == "dec":
        return jax.jit(lambda p, h, positions, enc_h:
                       B.cross_decoder_block_full(p, cfg, NULL_SH, h,
                                                  positions, enc_h,
                                                  backend=backend))
    raise ValueError(
        f"no prefill block for kind {kind!r}; supported kinds: "
        + ", ".join(SUPPORTED_KINDS))


def _ep_row_grid(cfg: ModelConfig, mesh, frozen_rules, p_stack,
                 n_rows: int) -> Optional[Tuple[int, int]]:
    """(B, S) factorization of the decode row grid that routes a decoder
    run's MoE FFN through the pure-EP shard_map path, or None to keep the
    per-row reference trace.

    The gate mirrors ``moe._ep_eligible`` exactly — mesh present, PADDED
    expert weights, the (data, model) extents divide the regrouped
    ``(n_data, n_rows / n_data)`` token grid, batch rule mapped — plus the
    no-drop bound ``n_rows <= 8 * n_ep``: with at most 8 local tokens per
    EP shard no expert can exceed the minimum dispatch capacity, so the
    batched all-to-all path emits exactly the per-row reference mixture
    and the engine's token-parity contract survives.  Unpadded reduced
    configs always return None (byte-identical trace to today)."""
    if mesh is None or not cfg.is_moe:
        return None
    ffn = p_stack.get("ffn") if isinstance(p_stack, dict) else None
    if not isinstance(ffn, dict) or "wg" not in ffn:
        return None
    E_alloc = int(ffn["wg"].shape[1])  # (run_layers, E_alloc, d, f)
    if E_alloc == cfg.n_experts:
        return None
    from repro.launch.sharding import thaw_rules

    if thaw_rules(frozen_rules).get("batch") is None:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    n_data = int(np.prod([sizes[a] for a in ("pod", "data") if a in sizes]))
    n_ep = n_data * model
    if (n_rows % n_data or (n_rows // n_data) % model or E_alloc % n_ep
            or n_rows > 8 * n_ep):
        return None
    return n_data, n_rows // n_data


def _decode_step_body(cfg: ModelConfig, kinds: Tuple[str, ...],
                      backend: str, mesh=None, rules=None):
    """The UNJITTED pooled decode-step body shared by
    :func:`make_pool_decode_step` (row-buffer entry point) and
    :func:`make_pool_round_step` (the fused round-resident entry point).

    step(run_params, shared_params, pool_trees, h, pos, emb0, enc_len,
         layer_active, layer_ids) -> (h, pool_trees)

    * ``run_params`` / ``shared_params`` / ``pool_trees``: as in
      :func:`make_pool_prefill_step`,
    * ``h``: (n_rows, 1, d_model) hidden rows,
    * ``pos``: (n_rows,) int32 cache write/attend position per row,
    * ``emb0``: (n_rows, 1, d_model) current-token embeddings for
      shared-attention blocks (dummy otherwise),
    * ``enc_len``: (n_rows,) int32 valid encoder length per row — masks the
      over-allocated cross-KV of enc-dec decoder blocks,
    * ``layer_active``: (n_layers, n_rows) bool — row r runs layer l iff set
      (a session's hop covers a contiguous sub-range of the server's blocks),
    * ``layer_ids``: (n_layers,) int32 absolute layer indices (for per-layer
      sliding-window patterns).

    Encoder runs are statically skipped (their StateSpec is not
    decode-active).  The computation always spans ALL rows with fixed
    shapes: adding or removing sessions changes only the mask, never the
    traced program, so per-session results are bit-for-bit identical
    between a crowded pool and a pool with a single resident session.
    """
    from repro.kernels.runtime import resolve_backend
    from repro.models import blocks as B
    from repro.models.layers import NULL_SH

    resolve_backend(backend)
    runs = kind_runs(kinds)

    ep_sh = None
    if mesh is not None:
        from repro.launch.sharding import thaw_rules
        from repro.models.layers import ShardingCtx

        ep_sh = ShardingCtx(mesh, thaw_rules(rules))

    def step(run_params, shared_params, pool_trees, h, pos, emb0, enc_len,
             layer_active, layer_ids):
        new_trees = list(pool_trees)
        for r, (kind, lo, hi) in enumerate(runs):
            if kind == "enc":  # stateless: no decode-time work
                continue
            p_stack, tree = run_params[r], pool_trees[r]
            act, lids = layer_active[lo:hi], layer_ids[lo:hi]

            if kind == "decoder":
                grid = _ep_row_grid(cfg, mesh, rules, p_stack, h.shape[0])

                if grid is None:
                    def body(hc, xs):
                        p, cache, active, lid = xs

                        def one(hr, cr, pr):
                            hh, cc = B.decoder_block_decode(
                                p, cfg, NULL_SH, hr[None],
                                jax.tree.map(lambda x: x[None], cr), pr, lid,
                                backend=backend)
                            return hh[0], jax.tree.map(lambda x: x[0], cc)

                        h2, c2 = jax.vmap(one)(hc, cache, pos)
                        return (jnp.where(active[:, None, None], h2, hc),
                                _mask_tree(c2, cache, active))
                else:
                    # Padded-MoE EP route: attention stays the per-row
                    # reference trace, the position-free FFN half regroups
                    # the rows into a (n_data, rows/n_data) token grid so
                    # apply_moe takes the pure-EP all-to-all inside the
                    # pooled step.  _ep_row_grid's no-drop bound makes this
                    # emit the reference mixture exactly (token parity).
                    n_data, rows_per = grid

                    def body(hc, xs):
                        p, cache, active, lid = xs

                        def one(hr, cr, pr):
                            hh, cc = B.decoder_block_attn_decode(
                                p, cfg, NULL_SH, hr[None],
                                jax.tree.map(lambda x: x[None], cr), pr, lid,
                                backend=backend)
                            return hh[0], jax.tree.map(lambda x: x[0], cc)

                        h2, c2 = jax.vmap(one)(hc, cache, pos)
                        hf = B.decoder_block_ffn(
                            p, cfg, ep_sh,
                            h2.reshape(n_data, rows_per, h2.shape[-1]))
                        h2 = hf.reshape(h2.shape)
                        return (jnp.where(active[:, None, None], h2, hc),
                                _mask_tree(c2, cache, active))
            elif kind in ("rwkv", "mamba"):
                blk = (B.rwkv_block_decode if kind == "rwkv"
                       else B.mamba_block_decode)

                def body(hc, xs, blk=blk):
                    p, cache, active, lid = xs

                    def one(hr, cr):
                        hh, cc = blk(p, cfg, NULL_SH, hr[None],
                                     jax.tree.map(lambda x: x[None], cr),
                                     backend=backend)
                        return hh[0], jax.tree.map(lambda x: x[0], cc)

                    h2, c2 = jax.vmap(one)(hc, cache)
                    return (jnp.where(active[:, None, None], h2, hc),
                            _mask_tree(c2, cache, active))
            elif kind == "mamba_shared":
                def body(hc, xs):
                    p, cache, active, lid = xs

                    def one(hr, er, cr, pr):
                        hh, st = B.mamba_block_decode(
                            p, cfg, NULL_SH, hr[None],
                            {"ssm": cr["ssm"][None], "conv": cr["conv"][None]},
                            backend=backend)
                        hh, kv = B.zamba_shared_decode(
                            shared_params, cfg, NULL_SH, hh, er[None],
                            {"k": cr["k"][None], "v": cr["v"][None]}, pr,
                            backend=backend)
                        return hh[0], {
                            "ssm": st["ssm"][0], "conv": st["conv"][0],
                            "k": kv["k"][0], "v": kv["v"][0]}

                    h2, c2 = jax.vmap(one)(hc, emb0, cache, pos)
                    return (jnp.where(active[:, None, None], h2, hc),
                            _mask_tree(c2, cache, active))
            elif kind == "dec":
                def body(hc, xs):
                    p, cache, active, lid = xs

                    def one(hr, cr, pr, el):
                        hh, cc = B.cross_decoder_block_decode(
                            p, cfg, NULL_SH, hr[None],
                            jax.tree.map(lambda x: x[None], cr), pr,
                            enc_len=el, backend=backend)
                        return hh[0], jax.tree.map(lambda x: x[0], cc)

                    h2, c2 = jax.vmap(one)(hc, cache, pos, enc_len)
                    return (jnp.where(active[:, None, None], h2, hc),
                            _mask_tree(c2, cache, active))
            else:
                raise ValueError(kind)

            h, new_tree = jax.lax.scan(body, h, (p_stack, tree, act, lids))
            new_trees[r] = new_tree
        return h, tuple(new_trees)

    return step


@functools.lru_cache(maxsize=None)
def make_pool_decode_step(cfg: ModelConfig, kinds: Tuple[str, ...],
                          backend: str = "xla", mesh=None, rules=None):
    """Jitted pooled decode step (see :func:`_decode_step_body` for the
    contract), shared per (cfg, per-layer kind tuple, compute backend) —
    each server calls it with its own (layers, rows) shapes.

    The pool trees (arg 2) are DONATED: the call updates each server's
    cache pool in place instead of copying every leaf per round.  Aliasing
    contract: after the call the input tree is dead — the caller MUST
    rebind its pool reference to the returned tree and never touch the old
    one (reading a donated leaf raises ``RuntimeError: Array has been
    deleted``).  ``BlockServer.decode_rows``/``round_rows`` do exactly
    that; see docs/serving.md "Round anatomy".

    ``mesh``/``rules``: optional TP/EP device-group sharding — see
    :func:`make_pool_prefill_step`.  ``mesh=None`` stays the untouched
    reference twin.
    """
    if mesh is None:
        return jax.jit(_decode_step_body(cfg, kinds, backend),
                       donate_argnums=(2,))
    body = _decode_step_body(cfg, kinds, backend, mesh, rules)
    pools, rows, repl = _mesh_constraints(mesh, rules)

    def step(run_params, shared_params, pool_trees, h, pos, emb0, enc_len,
             layer_active, layer_ids):
        pool_trees = pools(pool_trees)
        h = rows(h, "batch", None, None)
        pos = rows(pos, "batch")
        emb0 = rows(emb0, "batch", None, None)
        enc_len, layer_active, layer_ids = (repl(enc_len),
                                            repl(layer_active),
                                            repl(layer_ids))
        h, new_trees = body(run_params, shared_params, pool_trees, h, pos,
                            emb0, enc_len, layer_active, layer_ids)
        return rows(h, "batch", None, None), pools(new_trees)

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def make_pool_round_step(cfg: ModelConfig, kinds: Tuple[str, ...],
                         backend: str = "xla", mesh=None, rules=None):
    """Build THE fused per-(hop, server) dispatch of a device-resident
    decode round: gather the hop's rows out of the round buffers, run the
    pooled decode step, scatter the results back — ONE jitted call, no host
    round-trip between hops.

    hop(run_params, shared_params, pool_trees, h_round, pos_round,
        emb0_round, encl_round, slot_of_row, row_of_slot, layer_active,
        layer_ids) -> (h_round, pool_trees)

    * ``h_round``: (W, 1, d) round-resident hidden states — one slot per
      session of the round (W is the engine's fixed round width, so the
      program never re-traces as sessions come and go),
    * ``pos_round`` (W,) / ``encl_round`` (W,): per-slot cache position and
      encoder length; ``emb0_round``: (W, 1, d) round-start embeddings for
      shared-attention stacks (the engine's constant-shape dummy otherwise),
    * ``slot_of_row``: (n_rows,) int32 — for each pool row, the round slot
      feeding it this hop (-1 for rows not in the hop; they receive a
      clipped placeholder gather that ``layer_active`` masks out),
    * ``row_of_slot``: (W,) int32 — for each round slot, the pool row whose
      result it takes back (-1 keeps the slot's hidden state untouched),
    * ``layer_active`` / ``layer_ids``: as in the decode step.

    Per-slot results are bit-identical to staging the same rows through
    :func:`make_pool_decode_step`: the gather feeds each ACTIVE row exactly
    the values the host path would have scattered in, rows are computed
    independently (vmap), and inactive rows/slots are `where`-masked.  The
    pool trees (arg 2) are DONATED — same aliasing contract as
    :func:`make_pool_decode_step`.

    ``mesh``/``rules``: optional TP/EP device-group sharding.  The round
    buffers and per-round index vectors (``slot_of_row``/``row_of_slot``)
    are pinned replicated over the group; the pool trees follow the cache
    rules — the resharding between the two layouts is XLA's, still ONE
    dispatch per (hop, server).
    """
    body = _decode_step_body(cfg, kinds, backend, mesh, rules)
    cons = None if mesh is None else _mesh_constraints(mesh, rules)

    def hop(run_params, shared_params, pool_trees, h_round, pos_round,
            emb0_round, encl_round, slot_of_row, row_of_slot, layer_active,
            layer_ids):
        W = h_round.shape[0]
        n_rows = slot_of_row.shape[0]
        if cons is not None:
            pools, _rows, repl = cons
            pool_trees = pools(pool_trees)
            h_round, pos_round = repl(h_round), repl(pos_round)
            slot_of_row, row_of_slot = repl(slot_of_row), repl(row_of_slot)
        src = jnp.clip(slot_of_row, 0, W - 1)
        h = h_round[src]
        pos = pos_round[src]
        # the dummy emb0 is (1, 1, 1): clip separately so the gather stays
        # in bounds whatever the engine passed
        emb0 = emb0_round[jnp.clip(src, 0, emb0_round.shape[0] - 1)]
        enc_len = encl_round[src]
        h_out, new_trees = body(run_params, shared_params, pool_trees, h,
                                pos, emb0, enc_len, layer_active, layer_ids)
        back = h_out[jnp.clip(row_of_slot, 0, n_rows - 1)]
        keep = (row_of_slot >= 0)[:, None, None]
        out = jnp.where(keep, back, h_round)
        if cons is not None:
            out, new_trees = cons[2](out), cons[0](new_trees)
        return out, new_trees

    return jax.jit(hop, donate_argnums=(2,))


# ---------------------------------------------------------------------------
# Paged step factories: gather pages -> run the slab body -> scatter back
# ---------------------------------------------------------------------------
#
# The paged entry points do NOT reimplement any block math.  They gather
# each row's pages into a scratch tree whose self-KV leaves have the exact
# (layers, n_rows, max_len, ...) slab shape, run the UNCHANGED slab step
# body on it, and scatter the written positions back into the physical
# page arrays.  Bit-exactness vs the slab layout follows from two facts:
# positions inside a session's pages carry the same values either way, and
# positions outside (trash-page garbage where slab holds stale rows) are
# only ever read through the causal / enc-len masks, whose -1e30 logits
# underflow to EXACTLY zero probability in both layouts.  One trace per
# server is preserved: the page table is a runtime int32 operand.


def _gather_paged(runs, pool_trees, page_table, page_size: int):
    """Expand physical pages into slab-shaped scratch: self-KV leaves
    (L, n_phys, page, ...) -> (L, n_rows, max_pages*page, ...) via one
    fancy-indexed gather per leaf; row-resident leaves pass through."""
    n_rows, max_pages = page_table.shape
    scratch = []
    for r, _run in enumerate(runs):
        t = dict(pool_trees[r])
        for key in t:
            if key in _SELF_KV_KEYS:
                X = t[key]
                g = X[:, page_table]  # (L, n_rows, max_pages, page, ...)
                t[key] = g.reshape((X.shape[0], n_rows,
                                    max_pages * page_size) + X.shape[3:])
        scratch.append(t)
    return tuple(scratch)


def _scatter_paged(runs, pool_trees, scratch, page_table, page_size: int,
                   pos=None):
    """Fold the body's scratch updates back into the physical page arrays.

    ``pos is None`` (prefill): every table entry writes back its page —
    rows the body masked out write their own gathered values (a no-op).
    ``pos`` (n_rows,) (decode): only the single page containing each row's
    write position scatters back (a vmapped dynamic slice) — all other
    pages are untouched by a decode step.  Unassigned entries target the
    shared trash page 0; its content is unspecified but unobservable
    (masked-in positions always live in assigned pages).  Row-resident
    leaves take the body's output directly."""
    n_rows, max_pages = page_table.shape
    new_trees = list(pool_trees)
    for r, _run in enumerate(runs):
        t = dict(scratch[r])
        for key in pool_trees[r]:
            if key not in _SELF_KV_KEYS:
                continue
            X = pool_trees[r][key]  # (L, n_phys, page, ...) — donated
            S = scratch[r][key]     # (L, n_rows, max_len, ...)
            if pos is None:
                val = S.reshape((S.shape[0], n_rows, max_pages, page_size)
                                + S.shape[3:])
                t[key] = X.at[:, page_table].set(val)
            else:
                pidx = jnp.clip(pos // page_size, 0, max_pages - 1)
                ppid = jnp.take_along_axis(page_table, pidx[:, None],
                                           axis=1)[:, 0]

                def one(s_row, p):
                    return jax.lax.dynamic_slice_in_dim(
                        s_row, p * page_size, page_size, axis=1)

                val = jax.vmap(one, in_axes=(1, 0), out_axes=1)(S, pidx)
                t[key] = X.at[:, ppid].set(val)
        new_trees[r] = t
    return tuple(new_trees)


@functools.lru_cache(maxsize=None)
def make_paged_decode_step(cfg: ModelConfig, kinds: Tuple[str, ...],
                           backend: str = "xla", page_size: int = 16,
                           mesh=None, rules=None):
    """Paged twin of :func:`make_pool_decode_step`: same contract with one
    extra runtime operand, the int32 page table, inserted after the pool
    trees.  The pool trees (arg 2) are donated — same aliasing contract.
    ``mesh``/``rules``: optional device-group sharding (page table pinned
    replicated; physical page arrays follow the cache rules)."""
    body = _decode_step_body(cfg, kinds, backend, mesh, rules)
    runs = kind_runs(kinds)
    cons = None if mesh is None else _mesh_constraints(mesh, rules)

    def step(run_params, shared_params, pool_trees, page_table, h, pos,
             emb0, enc_len, layer_active, layer_ids):
        if cons is not None:
            pools, rows, repl = cons
            pool_trees, page_table = pools(pool_trees), repl(page_table)
            h, pos = rows(h, "batch", None, None), rows(pos, "batch")
        scratch = _gather_paged(runs, pool_trees, page_table, page_size)
        h_out, new_scratch = body(run_params, shared_params, scratch, h,
                                  pos, emb0, enc_len, layer_active,
                                  layer_ids)
        new_trees = _scatter_paged(runs, pool_trees, new_scratch,
                                   page_table, page_size, pos)
        if cons is not None:
            h_out = cons[1](h_out, "batch", None, None)
            new_trees = cons[0](new_trees)
        return h_out, new_trees

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def make_paged_prefill_step(cfg: ModelConfig, kinds: Tuple[str, ...],
                            backend: str = "xla", page_size: int = 16,
                            mesh=None, rules=None):
    """Paged twin of :func:`make_pool_prefill_step` (page table inserted
    after the pool trees; ``offset``/``phase`` stay static).
    ``mesh``/``rules``: optional device-group sharding."""
    body = _prefill_step_body(cfg, kinds, backend)
    runs = kind_runs(kinds)
    cons = None if mesh is None else _mesh_constraints(mesh, rules)

    def step(run_params, shared_params, pool_trees, page_table, h, emb0,
             enc_rows, layer_active, layer_ids, offset, phase):
        if cons is not None:
            pools, rows, repl = cons
            pool_trees, page_table = pools(pool_trees), repl(page_table)
            h = rows(h, "batch", None, None)
        scratch = _gather_paged(runs, pool_trees, page_table, page_size)
        h_out, new_scratch = body(run_params, shared_params, scratch, h,
                                  emb0, enc_rows, layer_active, layer_ids,
                                  offset, phase)
        new_trees = _scatter_paged(runs, pool_trees, new_scratch,
                                   page_table, page_size)
        if cons is not None:
            h_out = cons[1](h_out, "batch", None, None)
            new_trees = cons[0](new_trees)
        return h_out, new_trees

    return jax.jit(step, static_argnums=(9, 10), donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def make_paged_round_step(cfg: ModelConfig, kinds: Tuple[str, ...],
                          backend: str = "xla", page_size: int = 16,
                          mesh=None, rules=None):
    """Paged twin of :func:`make_pool_round_step`: the fused
    gather+step+scatter hop over the round buffers, with the page
    gather/scatter wrapped around the same decode body.  Rows outside the
    hop scatter their own gathered page back (their ``pos`` placeholder is
    arbitrary but the page it selects belongs to the row — a no-op write,
    or the trash page when unassigned).  ``mesh``/``rules``: optional
    device-group sharding (round buffers + page table replicated)."""
    body = _decode_step_body(cfg, kinds, backend, mesh, rules)
    runs = kind_runs(kinds)
    cons = None if mesh is None else _mesh_constraints(mesh, rules)

    def hop(run_params, shared_params, pool_trees, page_table, h_round,
            pos_round, emb0_round, encl_round, slot_of_row, row_of_slot,
            layer_active, layer_ids):
        W = h_round.shape[0]
        n_rows = slot_of_row.shape[0]
        if cons is not None:
            pools, _rows, repl = cons
            pool_trees, page_table = pools(pool_trees), repl(page_table)
            h_round, pos_round = repl(h_round), repl(pos_round)
            slot_of_row, row_of_slot = repl(slot_of_row), repl(row_of_slot)
        src = jnp.clip(slot_of_row, 0, W - 1)
        h = h_round[src]
        pos = pos_round[src]
        emb0 = emb0_round[jnp.clip(src, 0, emb0_round.shape[0] - 1)]
        enc_len = encl_round[src]
        scratch = _gather_paged(runs, pool_trees, page_table, page_size)
        h_out, new_scratch = body(run_params, shared_params, scratch, h,
                                  pos, emb0, enc_len, layer_active,
                                  layer_ids)
        new_trees = _scatter_paged(runs, pool_trees, new_scratch,
                                   page_table, page_size, pos)
        back = h_out[jnp.clip(row_of_slot, 0, n_rows - 1)]
        keep = (row_of_slot >= 0)[:, None, None]
        out = jnp.where(keep, back, h_round)
        if cons is not None:
            out, new_trees = cons[2](out), cons[0](new_trees)
        return out, new_trees

    return jax.jit(hop, donate_argnums=(2,))
