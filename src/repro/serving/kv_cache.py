"""Per-block serving caches for the geo engine (single-session granularity).

The engine executes one block at a time according to the BPRR placement, so
caches here are per (server, session, layer) — unlike the stacked scan
caches in repro.models.model used by the monolithic serve steps.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def new_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    cdt = jnp.dtype(cfg.param_dtype)
    if kind == "decoder":
        if cfg.attn_kind == "mla":
            return {
                "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cdt),
                "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), cdt),
            }
        kv = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(kv, cdt), "v": jnp.zeros(kv, cdt)}
    if kind == "rwkv":
        h, hd = cfg.ssm_heads, cfg.ssm_head_dim
        return {
            "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "shift_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "shift_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        }
    raise NotImplementedError(
        f"engine cache for block kind {kind!r}; BPRR semantics for the "
        "remaining families run through the simulator and monolithic steps")


def write_prefill_kv(cache: Dict, kv, length: int) -> Dict:
    """Insert full-sequence K/V (or MLA latent) into a preallocated cache."""
    out = dict(cache)
    if "latent" in cache:
        latent, krope = kv
        out["latent"] = cache["latent"].at[:, :length].set(
            latent.astype(cache["latent"].dtype))
        out["krope"] = cache["krope"].at[:, :length].set(
            krope.astype(cache["krope"].dtype))
    else:
        k, v = kv
        out["k"] = cache["k"].at[:, :length].set(k.astype(cache["k"].dtype))
        out["v"] = cache["v"].at[:, :length].set(v.astype(cache["v"].dtype))
    return out
