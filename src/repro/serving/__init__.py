from repro.serving.engine import BlockServer, GeoServingSystem, generate
from repro.serving.scheduler import AdmissionScheduler, ServedRequest

__all__ = ["AdmissionScheduler", "BlockServer", "GeoServingSystem",
           "ServedRequest", "generate"]
