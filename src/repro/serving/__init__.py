from repro.serving.engine import (BlockServer, EngineSession,
                                  GeoServingSystem, generate)
from repro.serving.kv_cache import (CachePool, make_pool_decode_step,
                                    new_block_cache, new_cache_pool_tree,
                                    write_prefill_kv)
from repro.serving.scheduler import (AdmissionScheduler,
                                     ContinuousBatchingScheduler,
                                     ServedRequest)

__all__ = ["AdmissionScheduler", "BlockServer", "CachePool",
           "ContinuousBatchingScheduler", "EngineSession", "GeoServingSystem",
           "ServedRequest", "generate", "make_pool_decode_step",
           "new_block_cache", "new_cache_pool_tree", "write_prefill_kv"]
