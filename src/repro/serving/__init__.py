"""The continuous-batching geo serving engine (see docs/serving.md):
family-polymorphic per-server state pools (StateSpec-dispatched), pooled
decode + bucketed prefill steps with a pluggable compute backend
(``GeoServingSystem(backend="xla" | "pallas")`` — oracle jnp paths vs the
``repro.kernels`` Pallas kernels with per-call XLA fallback), slab and
paged cache layouts (``cache_layout="paged"``: PagePool free-list
allocation, page-granular eq. (5)/(20) accounting, preemption/resume),
per-session sampling policies, the event-loop scheduler, and the
session/request record types."""
from repro.launch.sharding import DeviceGroup, as_device_group
from repro.serving.engine import (BlockServer, EngineSession,
                                  GeoServingSystem, generate)
from repro.serving.faults import (FailureDetector, FaultEvent, FaultPlan,
                                  NoCapacityError, recovery_replay_cost)
from repro.serving.kv_cache import (SUPPORTED_KINDS, CachePool, PagePool,
                                    StateSpec, bucket_for,
                                    default_prefill_buckets, kind_runs,
                                    make_paged_decode_step,
                                    make_paged_prefill_step,
                                    make_paged_round_step,
                                    make_pool_decode_step,
                                    make_pool_prefill_step,
                                    make_pool_round_step, new_block_cache,
                                    new_cache_pool_tree, new_paged_pool_tree,
                                    new_state_pool_tree, pages_for,
                                    state_spec_for, state_specs,
                                    write_prefill_kv)
from repro.serving.sampling import SamplingSpec, make_round_tail, make_sampler
from repro.serving.scheduler import (AdmissionScheduler,
                                     ContinuousBatchingScheduler,
                                     ServedRequest)

__all__ = ["AdmissionScheduler", "BlockServer", "CachePool",
           "ContinuousBatchingScheduler", "DeviceGroup", "EngineSession",
           "FailureDetector", "FaultEvent", "FaultPlan",
           "GeoServingSystem", "NoCapacityError", "PagePool",
           "SUPPORTED_KINDS", "SamplingSpec",
           "ServedRequest", "StateSpec", "as_device_group", "bucket_for",
           "default_prefill_buckets", "generate", "recovery_replay_cost",
           "kind_runs", "make_paged_decode_step", "make_paged_prefill_step",
           "make_paged_round_step", "make_pool_decode_step",
           "make_pool_prefill_step", "make_pool_round_step",
           "make_round_tail", "make_sampler", "new_block_cache",
           "new_cache_pool_tree", "new_paged_pool_tree",
           "new_state_pool_tree", "pages_for", "state_spec_for",
           "state_specs", "write_prefill_kv"]
