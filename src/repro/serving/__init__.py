"""The continuous-batching geo serving engine (see docs/serving.md):
per-server cache pools, pooled decode + bucketed prefill steps, the
event-loop scheduler, and the session/request record types."""
from repro.serving.engine import (BlockServer, EngineSession,
                                  GeoServingSystem, generate)
from repro.serving.kv_cache import (CachePool, bucket_for,
                                    default_prefill_buckets,
                                    make_pool_decode_step,
                                    make_pool_prefill_step, new_block_cache,
                                    new_cache_pool_tree, write_prefill_kv)
from repro.serving.scheduler import (AdmissionScheduler,
                                     ContinuousBatchingScheduler,
                                     ServedRequest)

__all__ = ["AdmissionScheduler", "BlockServer", "CachePool",
           "ContinuousBatchingScheduler", "EngineSession", "GeoServingSystem",
           "ServedRequest", "bucket_for", "default_prefill_buckets",
           "generate", "make_pool_decode_step", "make_pool_prefill_step",
           "new_block_cache", "new_cache_pool_tree", "write_prefill_kv"]
