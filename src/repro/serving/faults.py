"""Deterministic fault injection + failure-detection cost model.

PETALS-style geo-distributed serving treats server failure as a routine
event, not an exception: servers crash and rejoin, stragglers slow down,
and the client detects all of it by *timeout* — there is no oracle that
flips an ``alive`` bit the instant a machine dies (Borzunov et al.,
2209.01188; 2312.08361).  This module provides the pieces shared by the
real engine and the discrete-event simulator so both bill recovery the
same way on the virtual clock:

- :class:`FaultPlan` — a seedable, immutable schedule of fault events
  (fail-stop crashes, crash-then-rejoin transients, straggler slowdown
  intervals, admission-time dispatch errors).  The engine and the
  simulator replay the *same* plan, which is what makes the
  ``chaos.recovery`` bench row's engine-vs-sim cross-validation
  meaningful.
- :class:`FailureDetector` — the timeout/backoff policy: a hop dispatch
  that misses ``timeout_factor x`` the route's expected hop time marks
  the server *suspected*; ``max_probes`` retries follow with binary
  exponential backoff (mirroring ``sim.simulator._backoff_attempts``),
  and only then does the client splice the route.  Detection wait and
  backoff are both billed.
- :func:`recovery_replay_cost` — the eq. (1)-consistent price of
  rebuilding KV state on a replacement chain: per replaced hop, one
  input round-trip plus weighted prefill compute over the prompt, plus
  ``k*tau`` per replayed generated token.
- :class:`NoCapacityError` — typed "no free cache slots" failure so the
  scheduler can defer instead of hard-failing a session.

No jax imports here: the simulator side must stay importable without
pulling in the engine's device stack.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("crash", "rejoin", "straggler_start", "straggler_end",
               "dispatch_error")


class NoCapacityError(RuntimeError):
    """Failover/resume target set has no free cache slots right now.

    Transient by construction — capacity frees up as co-resident
    sessions retire — so callers (the scheduler, ``decode_round``'s
    resume path) should defer and retry rather than fail the session.
    """


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``factor`` is the tau multiplier for
    ``straggler_start`` events (ignored elsewhere)."""

    time: float
    kind: str
    server: int
    factor: float = 1.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}")
        if self.kind == "straggler_start" and self.factor <= 1.0:
            raise ValueError("straggler_start needs factor > 1, got "
                             f"{self.factor}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, time-sorted schedule of :class:`FaultEvent`.

    The plan itself is pure data; consumers keep their own cursor and
    call :meth:`due` to pop events, so one plan can drive the engine and
    the simulator independently.
    """

    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.time, e.server))))

    def __len__(self) -> int:
        return len(self.events)

    def due(self, cursor: int, now: float) -> Tuple[List[FaultEvent], int]:
        """Events with ``time <= now`` starting at ``cursor``; returns
        ``(events, new_cursor)``."""
        out = []
        while cursor < len(self.events) and self.events[cursor].time <= now:
            out.append(self.events[cursor])
            cursor += 1
        return out, cursor

    @property
    def affected_servers(self) -> Tuple[int, ...]:
        return tuple(sorted({e.server for e in self.events}))

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    @staticmethod
    def random(n_servers: int, seed: int, *, horizon: float = 10.0,
               n_crashes: int = 1, n_transients: int = 0,
               n_stragglers: int = 0, n_dispatch_errors: int = 0,
               rejoin_after: float = 2.0, straggler_len: float = 2.0,
               max_factor: float = 6.0,
               protect: Sequence[int] = ()) -> "FaultPlan":
        """Seedable random plan over ``n_servers`` servers.

        ``n_crashes`` fail-stop crashes, ``n_transients`` crash+rejoin
        pairs, ``n_stragglers`` slowdown intervals, and
        ``n_dispatch_errors`` one-shot admission faults, all at uniform
        times in ``[horizon/10, horizon)``.  Servers in ``protect`` are
        never touched (keeps at least one chain coverable).  Distinct
        crash victims are preferred while enough servers exist.
        """
        rng = np.random.default_rng(seed)
        pool = [j for j in range(n_servers) if j not in set(protect)]
        if not pool:
            raise ValueError("every server is protected; nothing to fault")

        def pick(n: int, distinct_from: set) -> List[int]:
            fresh = [j for j in pool if j not in distinct_from]
            src = fresh if len(fresh) >= n else pool
            return [int(j) for j in
                    rng.choice(src, size=n, replace=len(src) < n)]

        def t() -> float:
            return float(rng.uniform(horizon / 10.0, horizon))

        events: List[FaultEvent] = []
        crashed: set = set()
        for j in pick(n_crashes, crashed):
            crashed.add(j)
            events.append(FaultEvent(t(), "crash", j))
        for j in pick(n_transients, crashed):
            crashed.add(j)
            t0 = t()
            events.append(FaultEvent(t0, "crash", j))
            events.append(FaultEvent(
                t0 + float(rng.uniform(0.5, 1.0)) * rejoin_after,
                "rejoin", j))
        for j in pick(n_stragglers, crashed):
            t0 = t()
            factor = float(rng.uniform(2.0, max_factor))
            events.append(FaultEvent(t0, "straggler_start", j, factor))
            events.append(FaultEvent(
                t0 + float(rng.uniform(0.5, 1.0)) * straggler_len,
                "straggler_end", j))
        for j in pick(n_dispatch_errors, set()):
            events.append(FaultEvent(t(), "dispatch_error", j))
        return FaultPlan(tuple(events))


@dataclasses.dataclass(frozen=True)
class FailureDetector:
    """Timeout + binary-exponential-backoff failure detection policy.

    A hop whose reply misses ``timeout_factor x`` the expected hop time
    is *suspected*; the client retries ``max_probes`` times, sleeping
    ``backoff_base, 2*backoff_base, ...`` (capped at ``backoff_cap``,
    the same shape as ``sim.simulator._backoff_attempts``) between
    probes, each probe again waiting out the deadline.  Only after the
    last probe fails is the server declared dead and the route spliced.
    ``suspicion_penalty`` is the additive routing-cost penalty a
    once-suspected server keeps until it proves itself again
    (flap avoidance in :class:`repro.core.routing.RouteCostCache`).
    """

    timeout_factor: float = 3.0
    backoff_base: float = 1.0
    backoff_cap: float = 60.0
    max_probes: int = 3
    suspicion_penalty: float = 1.0

    def __post_init__(self):
        if self.timeout_factor <= 1.0:
            raise ValueError("timeout_factor must exceed 1")
        if self.max_probes < 0:
            raise ValueError("max_probes must be >= 0")

    def probe_delays(self) -> List[float]:
        """Backoff sleeps between the ``max_probes`` retries."""
        out, delay = [], self.backoff_base
        for _ in range(self.max_probes):
            out.append(delay)
            delay = min(delay * 2.0, self.backoff_cap)
        return out

    def detect_time(self, expected_hop: float) -> float:
        """Deadline waits: the initial miss plus one per probe."""
        return (1 + self.max_probes) * self.timeout_factor * expected_hop

    def backoff_time(self) -> float:
        return float(sum(self.probe_delays()))


def recovery_replay_cost(problem, client: int,
                         repl_routes: Iterable[Tuple[int, int, int]],
                         n_tokens: int,
                         slowdown_of=None,
                         l_in: Optional[int] = None) -> float:
    """Virtual-clock cost of rebuilding KV state on a replacement chain.

    ``repl_routes`` is the ``(server, lo, hi)`` block-range list a
    failover spliced in.  Per hop the client pays one input round-trip
    (``rtt_prefill``), the eq. (1)-weighted prefill compute over the
    prompt, and ``k*tau`` per replayed generated token — the same terms
    the engine bills for first-time prefill/decode, because replay *is*
    re-execution.  ``slowdown_of(j)`` supplies the live straggler
    multiplier (defaults to 1).
    """
    if l_in is None:
        l_in = problem.workload.l_in
    slow = slowdown_of if slowdown_of is not None else (lambda j: 1.0)
    cost = 0.0
    for j, lo, hi in repl_routes:
        w = problem.llm.tau_weight(lo, hi)
        s = float(slow(j))
        cost += (problem.rtt_prefill[client, j]
                 + w * problem.servers[j].tau_prefill(l_in) * s
                 + n_tokens * w * problem.servers[j].tau * s)
    return float(cost)
