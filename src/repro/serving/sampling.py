"""Token-level sampling policies for the serving engine.

A session declares its policy at admission via :class:`SamplingSpec`; the
engine threads the resolved per-row parameters (temperature, top-k, PRNG
key) through ONE jitted, vmapped sampler call per decode round — sampling
params are row INPUTS, not trace constants, so changing a session's
temperature/seed never retraces, and co-resident sessions with different
policies share the same pooled round.

Determinism contract: the key for a session's ``i``-th generated token is
``fold_in(PRNGKey(seed), i)`` — a pure function of (seed, token index).  A
session therefore samples the identical stream whether it decodes alone or
among neighbours, before or after a failover replay (replay does not
re-sample; tokens are part of the client-side history).

``greedy`` is encoded as temperature 0 and reduces to ``argmax(logits)``
bit-for-bit (the same op the engine's legacy greedy path used).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

SAMPLING_KINDS = ("greedy", "temperature", "top_k")


@dataclass(frozen=True)
class SamplingSpec:
    """Per-session token sampling policy.

    * ``greedy``       — argmax (the default; temperature/top_k ignored).
    * ``temperature``  — softmax sampling at ``temperature``.
    * ``top_k``        — restrict to the ``top_k`` highest logits, then
      sample at ``temperature``.

    ``seed`` makes the stream reproducible (see module docstring).
    """

    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in SAMPLING_KINDS:
            raise ValueError(
                f"unknown sampling kind {self.kind!r}; supported: "
                + ", ".join(SAMPLING_KINDS))
        if self.kind != "greedy" and self.temperature <= 0.0:
            raise ValueError("temperature must be > 0 for stochastic kinds")
        if self.kind == "top_k" and self.top_k <= 0:
            raise ValueError("top_k must be >= 1 for kind='top_k'")

    def row_params(self):
        """(temperature, top_k) as the vmapped row inputs: greedy is
        temperature 0; top_k 0 means 'full vocabulary'."""
        if self.kind == "greedy":
            return 0.0, 0
        if self.kind == "temperature":
            return float(self.temperature), 0
        return float(self.temperature), int(self.top_k)

    def key_for(self, token_index: int):
        """PRNG key of this session's ``token_index``-th generated token."""
        return jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  token_index)


def _sample_one(logits, temperature, top_k, key):
    """One row: logits (V,) f32, traced temperature/top_k/key.

    Branchless so one trace serves every policy: the Gumbel-max draw and the
    argmax are both computed and selected by ``temperature > 0``; ``top_k``
    masks logits below the k-th largest (k traced via a sorted gather, so
    distinct k values share the program).
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[0]
    greedy = jnp.argmax(logits)
    sorted_desc = -jnp.sort(-logits)
    kth = sorted_desc[jnp.clip(top_k - 1, 0, v - 1)]
    masked = jnp.where((top_k > 0) & (logits < kth), -jnp.inf, logits)
    gumbel = jax.random.gumbel(key, (v,), jnp.float32)
    drawn = jnp.argmax(masked / jnp.maximum(temperature, 1e-6) + gumbel)
    return jnp.where(temperature > 0.0, drawn, greedy)


@functools.lru_cache(maxsize=None)
def make_sampler():
    """THE jitted row sampler: (logits (N,V), temperature (N,), top_k (N,),
    keys (N,2)) -> (N,) int32 tokens.  vmapped over rows — the engine stacks
    one row per session of a decode round."""
    return jax.jit(jax.vmap(_sample_one))
