"""Token-level sampling policies for the serving engine.

A session declares its policy at admission via :class:`SamplingSpec`; the
engine threads the resolved per-row parameters (temperature, top-k, PRNG
key) through ONE jitted, vmapped sampler call per decode round — sampling
params are row INPUTS, not trace constants, so changing a session's
temperature/seed never retraces, and co-resident sessions with different
policies share the same pooled round.

Determinism contract: the key for a session's ``i``-th generated token is
``fold_in(PRNGKey(seed), i)`` — a pure function of (seed, token index).  A
session therefore samples the identical stream whether it decodes alone or
among neighbours, before or after a failover replay (replay does not
re-sample; tokens are part of the client-side history).

``greedy`` is encoded as temperature 0 and reduces to ``argmax(logits)``
bit-for-bit (the same op the engine's legacy greedy path used).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

SAMPLING_KINDS = ("greedy", "temperature", "top_k")


@dataclass(frozen=True)
class SamplingSpec:
    """Per-session token sampling policy.

    * ``greedy``       — argmax (the default; temperature/top_k ignored).
    * ``temperature``  — softmax sampling at ``temperature``.
    * ``top_k``        — restrict to the ``top_k`` highest logits, then
      sample at ``temperature``.

    ``seed`` makes the stream reproducible (see module docstring).
    """

    kind: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.kind not in SAMPLING_KINDS:
            raise ValueError(
                f"unknown sampling kind {self.kind!r}; supported: "
                + ", ".join(SAMPLING_KINDS))
        if self.kind != "greedy" and self.temperature <= 0.0:
            raise ValueError("temperature must be > 0 for stochastic kinds")
        if self.kind == "top_k" and self.top_k <= 0:
            raise ValueError("top_k must be >= 1 for kind='top_k'")
        if not 0 <= int(self.seed) < 2 ** 32:
            # the fused round tail ships seeds as uint32 row inputs; a
            # wider seed would silently fold differently than the host
            # key_for path — reject at construction, not mid-round
            raise ValueError("seed must be in [0, 2**32)")

    def row_params(self):
        """(temperature, top_k) as the vmapped row inputs: greedy is
        temperature 0; top_k 0 means 'full vocabulary'."""
        if self.kind == "greedy":
            return 0.0, 0
        if self.kind == "temperature":
            return float(self.temperature), 0
        return float(self.temperature), int(self.top_k)

    def key_for(self, token_index: int):
        """PRNG key of this session's ``token_index``-th generated token.

        The fused round tail derives the SAME key on device from the raw
        ``(seed, token_index)`` row inputs (``_key_for_row`` — identical
        integer computation, identical bits), so the two paths draw
        identical streams."""
        return _key_for_row(self.seed, token_index)


def _key_for_row(seed, token_index):
    """fold_in(PRNGKey(seed), token_index) — THE key derivation, shared by
    the host path (``SamplingSpec.key_for``) and the fused round tail
    (traced seeds/indices); a pure integer function either way, so both
    produce bit-identical keys."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), token_index)


def _sample_one(logits, temperature, top_k, key):
    """One row: logits (V,) f32, traced temperature/top_k/key.

    Branchless so one trace serves every policy: the Gumbel-max draw and the
    argmax are both computed and selected by ``temperature > 0``; ``top_k``
    masks logits below the k-th largest (k traced via a sorted gather, so
    distinct k values share the program).
    """
    logits = logits.astype(jnp.float32)
    v = logits.shape[0]
    greedy = jnp.argmax(logits)
    sorted_desc = -jnp.sort(-logits)
    kth = sorted_desc[jnp.clip(top_k - 1, 0, v - 1)]
    masked = jnp.where((top_k > 0) & (logits < kth), -jnp.inf, logits)
    gumbel = jax.random.gumbel(key, (v,), jnp.float32)
    drawn = jnp.argmax(masked / jnp.maximum(temperature, 1e-6) + gumbel)
    return jnp.where(temperature > 0.0, drawn, greedy)


@functools.lru_cache(maxsize=None)
def make_sampler():
    """THE jitted row sampler: (logits (N,V), temperature (N,), top_k (N,),
    keys (N,2)) -> (N,) int32 tokens.  vmapped over rows — the engine stacks
    one row per session of a decode round."""
    return jax.jit(jax.vmap(_sample_one))


@functools.lru_cache(maxsize=None)
def make_round_tail(cfg):
    """THE fused decode-round tail: ONE jitted dispatch folding the lm_head
    projection and the vmapped row sampler over the round's device-resident
    hidden states.

    tail(embed_params, h_round (W, 1, d), temperature (W,), top_k (W,),
         seeds (W,), token_index (W,)) -> (tokens (W,), logits (W, V))

    Per-row PRNG keys are derived ON DEVICE inside the dispatch
    (``_key_for_row`` vmapped over the raw seed/index rows) — the host
    never builds per-session key arrays in the round hot path, and the
    keys are bit-identical to ``SamplingSpec.key_for``.

    ``W`` is the engine's fixed round width: unused slots carry dummy
    inputs (temperature 0 → a discarded argmax), so the program never
    re-traces as round membership changes, and — rows being independent
    throughout (row-wise norm/einsum, vmapped sampler) — per-slot results
    are bit-identical however many neighbours share the round.  Against
    the per-session (width-1) ``lm_head`` of the serial reference path,
    tokens are identical and logits agree to float-ulp: XLA may order the
    projection's per-row reduction differently at different GEMM widths,
    which cannot flip the sampler unless two logits already tie within one
    ulp.  The engine issues its single host sync per round on the returned
    ``tokens``; ``logits`` rows stay on device behind each session's
    ``last_logits``.
    """
    from repro.models.layers import NULL_SH, lm_head

    def tail(embed_params, h_round, temperature, top_k, seeds, token_index):
        logits = lm_head(embed_params, cfg, NULL_SH, h_round)[:, 0]
        keys = jax.vmap(_key_for_row)(seeds, token_index)
        toks = jax.vmap(_sample_one)(logits, temperature, top_k, keys)
        return toks, logits

    return jax.jit(tail)
