"""Jit'd wrapper for the WKV6 Pallas kernel (model layout (B,S,H,hd)).

Carries recurrent state in/out so the kernel can serve the pooled
recurrent serving state (per-session wkv carries), not just full
sequences from a zero state.  ``wkv6_unsupported`` is the backend layer's
dispatch predicate (currently no residual gaps — it validates only)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.runtime import default_interpret
from repro.kernels.wkv6.wkv6 import wkv6_bh


def wkv6_unsupported(*, state=None) -> Optional[str]:
    """Reason this kernel cannot serve a WKV6 call, else None (carried
    state in/out is supported natively)."""
    return None


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, state=None, *, chunk: int = 16,
         interpret: Optional[bool] = None):
    """r/k/v/lw (B,S,H,hd); u (H,hd); state optional (B,H,hd,hd) f32 carry
    -> (out (B,S,H,hd), state_out (B,H,hd,hd) f32)."""
    reason = wkv6_unsupported(state=state)
    if reason is not None:
        raise ValueError(f"wkv6 (pallas) does not support {reason}")
    interpret = default_interpret() if interpret is None else interpret
    B, S, H, hd = r.shape
    to = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    sf = None if state is None else state.reshape(B * H, hd, hd)
    out, state_out = wkv6_bh(to(r), to(k), to(v), to(lw), uf, sf,
                             chunk=chunk, interpret=interpret)
    return (out.reshape(B, H, S, hd).transpose(0, 2, 1, 3),
            state_out.reshape(B, H, hd, hd))
