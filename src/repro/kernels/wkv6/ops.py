"""Jit'd wrapper for the WKV6 Pallas kernel (model layout (B,S,H,hd))."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.wkv6 import wkv6_bh


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, lw, u, *, chunk: int = 16,
         interpret: Optional[bool] = None):
    """r/k/v/lw (B,S,H,hd); u (H,hd) -> out (B,S,H,hd)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, S, H, hd = r.shape
    to = lambda x: x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    uf = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    out = wkv6_bh(to(r), to(k), to(v), to(lw), uf, chunk=chunk,
                  interpret=interpret)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
