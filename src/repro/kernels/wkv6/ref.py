"""Sequential-recurrence oracle for WKV6 (the literal definition)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def wkv6_ref(r, k, v, lw, u, state=None):
    """r/k/v/lw (BH, S, hd) f32; u (BH, hd); state optional (BH, hd, hd)
    carry.  Literal step-by-step scan; returns (out, final state)."""
    BH, S, hd = r.shape
    r = np.asarray(r, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    w = np.exp(np.asarray(lw, np.float64))
    u = np.asarray(u, np.float64)
    out = np.zeros_like(r)
    state = (np.zeros((BH, hd, hd)) if state is None
             else np.asarray(state, np.float64).copy())
    for t in range(S):
        kv = k[:, t, :, None] * v[:, t, None, :]  # (BH, hd, hd)
        att = state + u[:, :, None] * kv
        out[:, t] = np.einsum("bd,bde->be", r[:, t], att)
        state = w[:, t, :, None] * state + kv
    return jnp.asarray(out, jnp.float32), jnp.asarray(state, jnp.float32)
