from repro.kernels.wkv6.ops import wkv6, wkv6_unsupported
from repro.kernels.wkv6.ref import wkv6_ref

__all__ = ["wkv6", "wkv6_ref", "wkv6_unsupported"]
