"""Pallas TPU kernel for the RWKV6 (Finch) recurrence, chunked.

    out_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);   S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t

Grid = (B*H, n_chunks) with the chunk axis innermost; the (hd, hd) f32 state
lives in VMEM scratch and carries across chunk steps (sequential TPU grid
execution).  Intra-chunk terms use the explicit masked decay tensor — the
numerically-safe formulation shared with the jnp path
(repro.models.ssm._wkv6_chunked, incl. the RWKV_MIN_LOG_W clamp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state, *, chunk, hd):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    r = r_ref[0].astype(jnp.float32)  # (Q, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # log decay, clamped <= 0
    u = u_ref[0].astype(jnp.float32)  # (hd,)

    seg = jnp.cumsum(lw, axis=0)  # inclusive (Q, hd)
    segx = seg - lw  # exclusive
    # intra-chunk: A[t,i] = sum_c r[t,c] k[i,c] exp(segx[t,c]-seg[i,c]), i<t
    # exponents clamped <= 0 (masked upper-triangle entries would be inf)
    decay = jnp.exp(jnp.minimum(segx[:, None, :] - seg[None, :, :], 0.0))
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.einsum("tc,ic,tic->ti", r, k, decay)
    A = jnp.where(mask, A, 0.0)
    out = A @ v
    # bonus (current token): (r_t · (u ⊙ k_t)) v_t
    bonus = jnp.sum(r * u[None, :] * k, axis=1)  # (Q,)
    out = out + bonus[:, None] * v
    # inter-chunk: r_t ⊙ exp(segx_t) against the carried state
    out = out + (r * jnp.exp(segx)) @ state[...]
    o_ref[0] = out.astype(o_ref.dtype)
    # state update: S <- diag(prod w) S + sum_i (k_i ⊙ exp(seg_end - seg_i)) v_i^T
    decay_to_end = jnp.exp(seg[-1][None, :] - seg)  # (Q, hd)
    state[...] = (jnp.exp(seg[-1])[:, None] * state[...]
                  + jax.lax.dot_general(
                      (k * decay_to_end), v, (((0,), (0,)), ((), ())),
                      preferred_element_type=jnp.float32))


def wkv6_bh(r, k, v, lw, u, *, chunk: int = 16, interpret: bool = False):
    """r/k/v/lw: (BH, S, hd); u: (BH, hd).  Returns out (BH, S, hd)."""
    BH, S, hd = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        lw = jnp.pad(lw, padw)
    n_chunks = r.shape[1] // chunk
    kern = functools.partial(_kernel, chunk=chunk, hd=hd)
    out = pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd), lambda b, ci: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
        out_shape=jax.ShapeDtypeStruct(r.shape, r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
    return out[:, :S]
