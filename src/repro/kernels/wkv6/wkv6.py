"""Pallas TPU kernel for the RWKV6 (Finch) recurrence, chunked.

    out_t = r_t · (S_{t-1} + u ⊙ k_t ⊗ v_t);   S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t

Grid = (B*H, n_chunks) with the chunk axis innermost; the (hd, hd) f32 state
lives in VMEM scratch and carries across chunk steps (sequential TPU grid
execution).  Intra-chunk terms use the explicit masked decay tensor — the
numerically-safe formulation shared with the jnp path
(repro.models.ssm._wkv6_chunked, incl. the RWKV_MIN_LOG_W clamp).

State is carried IN and OUT: the scratch initialises from ``state_in``
(zeros for a fresh sequence) and the final carry is written to a second
output — what the recurrent serving pools store per session row, so the
kernel can serve pooled prefill (and chunked resume), not just full
sequences from scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sout_ref,
            state, *, chunk, hd):
    ci = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (Q, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)  # log decay, clamped <= 0
    u = u_ref[0].astype(jnp.float32)  # (hd,)

    seg = jnp.cumsum(lw, axis=0)  # inclusive (Q, hd)
    segx = seg - lw  # exclusive
    # intra-chunk: A[t,i] = sum_c r[t,c] k[i,c] exp(segx[t,c]-seg[i,c]), i<t
    # exponents clamped <= 0 (masked upper-triangle entries would be inf)
    decay = jnp.exp(jnp.minimum(segx[:, None, :] - seg[None, :, :], 0.0))
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.einsum("tc,ic,tic->ti", r, k, decay)
    A = jnp.where(mask, A, 0.0)
    out = A @ v
    # bonus (current token): (r_t · (u ⊙ k_t)) v_t
    bonus = jnp.sum(r * u[None, :] * k, axis=1)  # (Q,)
    out = out + bonus[:, None] * v
    # inter-chunk: r_t ⊙ exp(segx_t) against the carried state
    out = out + (r * jnp.exp(segx)) @ state[...]
    o_ref[0] = out.astype(o_ref.dtype)
    # state update: S <- diag(prod w) S + sum_i (k_i ⊙ exp(seg_end - seg_i)) v_i^T
    decay_to_end = jnp.exp(seg[-1][None, :] - seg)  # (Q, hd)
    state[...] = (jnp.exp(seg[-1])[:, None] * state[...]
                  + jax.lax.dot_general(
                      (k * decay_to_end), v, (((0,), (0,)), ((), ())),
                      preferred_element_type=jnp.float32))

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sout_ref[0] = state[...]


def wkv6_bh(r, k, v, lw, u, state_in=None, *, chunk: int = 16,
            interpret: bool = False):
    """r/k/v/lw: (BH, S, hd); u: (BH, hd); state_in: optional (BH, hd, hd)
    f32 carry.  Returns (out (BH, S, hd), state_out (BH, hd, hd) f32).

    NOTE: trailing pad positions (S not a multiple of ``chunk``) are padded
    with zeros, which leave the state invariant (k=0 contributes nothing
    and lw=0 means decay exp(0)=1), so ``state_out`` is the state after
    exactly the S real steps."""
    BH, S, hd = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        padw = ((0, 0), (0, pad), (0, 0))
        r = jnp.pad(r, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        lw = jnp.pad(lw, padw)
    if state_in is None:
        state_in = jnp.zeros((BH, hd, hd), jnp.float32)
    n_chunks = r.shape[1] // chunk
    kern = functools.partial(_kernel, chunk=chunk, hd=hd)
    out, state_out = pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd), lambda b, ci: (b, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, state_in.astype(jnp.float32))
    return out[:, :S], state_out
