"""Pallas TPU flash attention (prefill): online-softmax over KV blocks.

TPU-native design (DESIGN.md §3): q/k/v tiles live in VMEM via BlockSpecs,
MXU-aligned block sizes (multiples of 128 for full-size configs), f32
accumulators in VMEM scratch, grid = (batch*q_heads, q_blocks, kv_blocks)
with the kv axis innermost so the scratch carries across kv steps.  Causal
blocks above the diagonal are skipped with ``pl.when``.  GQA is handled by
index-mapping the kv block to ``head // group`` — no KV head expansion copy.

Masking matches ``models.attention.attention_core`` (the XLA oracle the
pooled serving steps dispatch against):

* ``q_start`` (static) offsets the queries — chunked prefill runs a suffix
  of ``Sq`` queries over ``Skv = q_start + Sq`` keys, so query ``i`` sits
  at absolute position ``q_start + i`` for the causal/window/ALiBi masks.
* sliding ``window`` is a DYNAMIC scalar (gemma3's local:global pattern
  makes it a traced per-layer value inside the scanned pooled steps);
  causal diagonal block-skipping stays static (a window only masks more).
* ALiBi ``slopes`` (one per flattened batch*head row) add
  ``slope * -|q_pos - kv_pos|`` before masking (bloom).
* non-causal mode (encoder self-attention, cross-attention) masks only
  ``kv_pos < seq_kv`` and supports ``Sq != Skv`` and ``Dv != Dk``.

All-masked KV blocks contribute exact zeros (masked probabilities are
zeroed explicitly; ``NEG_INF`` is finite, so ``exp(s - m)`` of a fully
window-masked block would otherwise be 1 and corrupt the denominator).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import NO_WINDOW

NEG_INF = -1e30


def _kernel(win_ref, *rest, block_q, block_kv, seq_q, seq_kv, causal,
            has_slopes, q_start, scale):
    if has_slopes:
        slopes_ref, q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr = rest
    else:
        q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr = rest
    bh = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_blk = qi * block_q
    kv_start = ki * block_kv
    if causal:  # skip blocks strictly above the causal diagonal (static)
        run = kv_start <= q_start + q_blk + block_q - 1
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)  # (block_kv, d_v)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        q_pos = q_start + q_blk + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        diff = q_pos - kv_pos
        if has_slopes:
            s = s + slopes_ref[bh] * (-jnp.abs(diff).astype(jnp.float32))
        ok = kv_pos < seq_kv
        if causal:
            ok &= (diff >= 0) & (diff < win_ref[0])
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        # NEG_INF is finite: zero masked probabilities explicitly so an
        # all-masked block (small dynamic window) adds nothing
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True,
                         window=None, slopes=None, q_start: int = 0,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False):
    """q: (BH, Sq, Dk); k: (BKv, Skv, Dk); v: (BKv, Skv, Dv) with
    BH = BKv * group.  ``q_start``: static absolute position of query 0
    (chunked prefill).  ``window``: dynamic scalar sliding window.
    ``slopes``: optional (BH,) f32 ALiBi slopes."""
    BH, Sq, Dk = q.shape
    BKv, Skv, _ = k.shape
    Dv = v.shape[-1]
    group = BH // BKv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0))) if pad_kv else v
    n_q = qp.shape[1] // block_q
    n_kv = kp.shape[1] // block_kv
    grid = (BH, n_q, n_kv)
    kern = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, seq_q=Sq, seq_kv=Skv,
        causal=causal, has_slopes=slopes is not None, q_start=int(q_start),
        scale=1.0 / np.sqrt(Dk))
    win_arr = jnp.asarray(NO_WINDOW if window is None else window,
                          jnp.int32).reshape(1)
    inputs = [win_arr]
    extra_specs = [pl.BlockSpec(memory_space=pltpu.SMEM)]
    if slopes is not None:
        inputs.append(jnp.asarray(slopes, jnp.float32))
        extra_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=extra_specs + [
            pl.BlockSpec((1, block_q, Dk), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, Dk),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, Dv),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, qp.shape[1], Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs, qp, kp, vp)
    return out[:, :Sq]
