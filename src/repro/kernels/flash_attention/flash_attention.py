"""Pallas TPU flash attention (prefill): online-softmax over KV blocks.

TPU-native design (DESIGN.md §3): q/k/v tiles live in VMEM via BlockSpecs,
MXU-aligned block sizes (multiples of 128 for full-size configs), f32
accumulators in VMEM scratch, grid = (batch*q_heads, q_blocks, kv_blocks)
with the kv axis innermost so the scratch carries across kv steps.  Causal
blocks above the diagonal are skipped with ``pl.when``.  GQA is handled by
index-mapping the kv block to ``head // group`` — no KV head expansion copy.
Supports sliding-window masking (static window).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *, block_q,
            block_kv, seq_q, seq_kv, causal, window, scale):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    q_start = qi * block_q
    kv_start = ki * block_kv
    if causal:  # skip blocks strictly above the causal diagonal
        run = kv_start <= q_start + block_q - 1
    else:
        run = jnp.bool_(True)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        kv_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
        ok = kv_pos < seq_kv
        if causal:
            diff = q_pos - kv_pos
            ok &= diff >= 0
            if window is not None:
                ok &= diff < window
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True,
                         window: Optional[int] = None,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False):
    """q: (BH, Sq, D); k/v: (BKv, Skv, D) with BH = BKv * group."""
    BH, Sq, D = q.shape
    BKv, Skv, _ = k.shape
    group = BH // BKv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0))) if pad_kv else v
    n_q = qp.shape[1] // block_q
    n_kv = kp.shape[1] // block_kv
    grid = (BH, n_q, n_kv)
    kern = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, seq_q=Sq, seq_kv=Skv,
        causal=causal, window=window, scale=1.0 / np.sqrt(D))
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, D),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, D),
                         lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
