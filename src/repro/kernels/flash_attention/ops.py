"""Jit'd public wrapper for the flash-attention Pallas kernel.

On CPU (this container) the kernel body executes in interpret mode; on TPU
it compiles through Mosaic.  ``flash_attention`` takes model-layout tensors
(B, Sq, H, Dk) + unexpanded KV (B, Skv, Kv, Dk/Dv) and the full masking
surface of the XLA oracle's prefill path (``models.attention
.attention_core``): causal/non-causal, dynamic sliding ``window``, ALiBi
``slopes``, and the static chunked-prefill ``q_start`` offset.

``flash_attention_unsupported`` is the dispatch predicate of the serving
backend layer: it names the feature (if any) this kernel cannot yet serve,
in which case the backend layer falls back to the XLA oracle and a direct
kernel call raises ``ValueError`` instead of returning wrong numbers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.runtime import default_interpret


def flash_attention_unsupported(*, causal: bool = True, window=None,
                                slopes=None, q_start: int = 0
                                ) -> Optional[str]:
    """Reason this kernel cannot serve a prefill-attention call, else None.

    The kernel assumes aligned-arange positions (queries at
    ``q_start + arange(Sq)``, keys at ``arange(Skv)``) — the same contract
    as the oracle's flash path.  Residual gaps:
    """
    if not causal:
        if window is not None:
            return "sliding-window masking on non-causal attention"
        if q_start:
            return "chunked-prefill q_start offsets on non-causal attention"
        if slopes is not None:
            # the ALiBi bias needs the caller's TRUE query positions; the
            # non-causal (cross) call sites pass q_start=0 with offset
            # positions, so the kernel would bias from arange(Sq) while
            # the oracle uses the real offsets — reject rather than
            # silently diverge across backends
            return "ALiBi slopes on non-causal attention"
    return None


@functools.partial(jax.jit, static_argnames=("causal", "q_start", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    slopes=None, q_start: int = 0, block_q: int = 128,
                    block_kv: int = 128, interpret: Optional[bool] = None):
    """q (B,Sq,H,Dk); k (B,Skv,Kv,Dk); v (B,Skv,Kv,Dv) -> (B,Sq,H,Dv).

    ``window``: optional sliding window (scalar, may be traced).
    ``slopes``: optional (H,) f32 ALiBi slopes.  ``q_start``: static
    absolute position of the first query (chunked prefill: queries
    [q_start, q_start+Sq) over keys [0, Skv))."""
    reason = flash_attention_unsupported(causal=causal, window=window,
                                         slopes=slopes, q_start=q_start)
    if reason is not None:
        raise ValueError(f"flash_attention (pallas) does not support "
                         f"{reason}")
    interpret = default_interpret() if interpret is None else interpret
    B, Sq, H, Dk = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, Dk)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, Skv, Dk)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, Skv, Dv)
    slopes_bh = None
    if slopes is not None:  # (H,) -> (B*H,)
        slopes_bh = jnp.broadcast_to(
            jnp.asarray(slopes, jnp.float32)[None], (B, H)).reshape(B * H)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               slopes=slopes_bh, q_start=q_start,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
