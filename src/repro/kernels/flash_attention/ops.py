"""Jit'd public wrapper for the flash-attention Pallas kernel.

On CPU (this container) the kernel body executes in interpret mode; on TPU
it compiles through Mosaic.  ``flash_attention`` takes model-layout tensors
(B, S, H, D) + unexpanded KV (B, S, Kv, D).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_kv: int = 128, interpret: Optional[bool] = None):
    """q (B,S,H,D); k/v (B,S,Kv,D) -> (B,S,H,D)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, S, H, D = q.shape
    Kv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, D)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
