from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_unsupported)
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention_ref", "flash_attention", "flash_attention_unsupported"]
