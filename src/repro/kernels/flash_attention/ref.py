"""Pure-jnp oracle for the flash-attention kernel (shares the model's
attention_core math exactly), over the full masking surface: causal /
sliding window / ALiBi slopes / chunked-prefill q_start."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                  slopes=None, q_start: int = 0):
    """q (BH, Sq, Dk), k/v (BKv, Skv, Dk/Dv); GQA via head-group repetition.

    ``slopes``: optional (BH,) ALiBi slopes; ``q_start``: absolute position
    of query 0 (queries [q_start, q_start+Sq) over keys [0, Skv))."""
    BH, Sq, Dk = q.shape
    BKv = k.shape[0]
    group = BH // BKv
    if group > 1:
        k = jnp.repeat(k, group, axis=0)
        v = jnp.repeat(v, group, axis=0)
    logits = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(Dk)
    q_pos = q_start + jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(k.shape[1])[None, :]
    diff = q_pos - kv_pos
    if slopes is not None:
        logits = logits + (jnp.asarray(slopes, jnp.float32)[:, None, None]
                           * (-jnp.abs(diff))[None].astype(jnp.float32))
    ok = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        ok = diff >= 0
        if window is not None:
            ok &= diff < window
    logits = jnp.where(ok[None], logits, -1e30)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
