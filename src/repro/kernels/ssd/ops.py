"""Jit'd wrapper for the SSD Pallas kernel (model layout).

Carries recurrent state in/out so the kernel can serve the pooled
recurrent serving state (per-session SSD carries), not just full
sequences from a zero state.  ``ssd_unsupported`` is the backend layer's
dispatch predicate (currently no residual gaps — it validates only)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.runtime import default_interpret
from repro.kernels.ssd.ssd import ssd_bh


def ssd_unsupported(*, state=None) -> Optional[str]:
    """Reason this kernel cannot serve an SSD call, else None (carried
    state in/out is supported natively)."""
    return None


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, Bm, Cm, dt, A, D, state=None, *, chunk: int = 64,
        interpret: Optional[bool] = None):
    """x (B,S,H,p); Bm/Cm (B,S,n); dt (B,S,H); A/D (H,); state optional
    (B,H,p,n) f32 carry -> (out (B,S,H,p), state_out (B,H,p,n) f32)."""
    reason = ssd_unsupported(state=state)
    if reason is not None:
        raise ValueError(f"ssd (pallas) does not support {reason}")
    interpret = default_interpret() if interpret is None else interpret
    B, S, H, p = x.shape
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, p)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.broadcast_to(A[None], (B, H)).reshape(B * H)
    Df = jnp.broadcast_to(D[None], (B, H)).reshape(B * H)
    sf = None if state is None else state.reshape(B * H, p, state.shape[-1])
    out, state_out = ssd_bh(xf, Bm, Cm, dtf, Af, Df, sf, chunk=chunk,
                            interpret=interpret)
    return (out.reshape(B, H, S, p).transpose(0, 2, 1, 3),
            state_out.reshape(B, H, p, state_out.shape[-1]))
