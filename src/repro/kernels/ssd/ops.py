"""Jit'd wrapper for the SSD Pallas kernel (model layout)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import ssd_bh


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, Bm, Cm, dt, A, D, *, chunk: int = 64,
        interpret: Optional[bool] = None):
    """x (B,S,H,p); Bm/Cm (B,S,n); dt (B,S,H); A/D (H,) -> (B,S,H,p)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, S, H, p = x.shape
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, p)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    Af = jnp.broadcast_to(A[None], (B, H)).reshape(B * H)
    Df = jnp.broadcast_to(D[None], (B, H)).reshape(B * H)
    out = ssd_bh(xf, Bm, Cm, dtf, Af, Df, chunk=chunk, interpret=interpret)
    return out.reshape(B, H, S, p).transpose(0, 2, 1, 3)
