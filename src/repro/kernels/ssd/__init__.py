from repro.kernels.ssd.ops import ssd, ssd_unsupported
from repro.kernels.ssd.ref import ssd_ref

__all__ = ["ssd", "ssd_ref", "ssd_unsupported"]
