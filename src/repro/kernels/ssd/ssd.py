"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Per (batch, head): state (p, n) carried in VMEM scratch across sequence
chunks (grid = (B*H, n_chunks), chunk axis innermost):

    state_t = exp(dt_t A_h) state_{t-1} + dt_t x_t ⊗ B_t
    y_t     = C_t · state_t + D_h x_t

Intra-chunk uses the dense (Q, Q) decay matrix (MXU-friendly) exactly as the
jnp path in repro.models.ssm.apply_mamba_full.  B/C are head-shared
(ngroups=1) and index-mapped without replication.

State is carried IN and OUT: the scratch initialises from ``state_in``
(zeros for a fresh sequence) and the final carry is written to a second
output — what the recurrent serving pools store per session row, so the
kernel can serve pooled prefill (and chunked resume), not just full
sequences from scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, d_ref, s0_ref, o_ref,
            sout_ref, state, *, chunk, p, n):
    ci = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)  # (Q, p)
    Bm = b_ref[0].astype(jnp.float32)  # (Q, n)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, n)
    dt = dt_ref[0].astype(jnp.float32)  # (Q,)
    A = a_ref[0]  # scalar (negative)
    D = d_ref[0]

    la = dt * A  # (Q,) log decay per step
    seg = jnp.cumsum(la)  # inclusive
    # intra-chunk: Y[t] = sum_{i<=t} exp(seg[t]-seg[i]) (C_t·B_i) dt_i x_i
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    # exponents clamped <= 0 (masked upper-triangle entries would be inf)
    decay = jnp.exp(jnp.minimum(seg[:, None] - seg[None, :], 0.0))
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) \
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(mask, G * decay, 0.0)
    xb = x * dt[:, None]
    y = jax.lax.dot_general(M, xb, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, p)
    # inter-chunk: Y[t] += C_t · (exp(seg[t]) state_in)   (state is (p, n))
    y = y + jnp.exp(seg)[:, None] * jax.lax.dot_general(
        Cm, state[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = y + x * D
    o_ref[0] = y.astype(o_ref.dtype)
    # state update
    decay_to_end = jnp.exp(seg[-1] - seg)  # (Q,)
    contrib = jax.lax.dot_general(
        (xb * decay_to_end[:, None]), Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (p, n)
    state[...] = jnp.exp(seg[-1]) * state[...] + contrib

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sout_ref[0] = state[...]


def ssd_bh(x, Bm, Cm, dt, A, D, state_in=None, *, chunk: int = 64,
           interpret: bool = False):
    """x (BH, S, p); Bm/Cm (B, S, n) head-shared; dt (BH, S); A/D (BH,);
    state_in optional (BH, p, n) f32 carry.

    BH = B * H with head-major flattening (bh // H = batch).  Returns
    (out (BH, S, p), state_out (BH, p, n) f32).  Trailing pad positions
    (dt=0 ⇒ decay exp(0)=1, contribution 0) leave the state invariant, so
    ``state_out`` is the state after exactly the S real steps.
    """
    BH, S, p = x.shape
    B, _, n = Bm.shape
    H = BH // B
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
    if state_in is None:
        state_in = jnp.zeros((BH, p, n), jnp.float32)
    n_chunks = x.shape[1] // chunk
    kern = functools.partial(_kernel, chunk=chunk, p=p, n=n)
    out, state_out = pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci, H=H: (bh // H, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci, H=H: (bh // H, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((BH, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, Bm, Cm, dt, A, D, state_in.astype(jnp.float32))
    return out[:, :S], state_out
