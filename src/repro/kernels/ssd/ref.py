"""Sequential-recurrence oracle for the Mamba2 SSD scan."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def ssd_ref(x, Bm, Cm, dt, A, D, state=None):
    """x (BH,S,p); Bm/Cm (B,S,n); dt (BH,S); A/D (BH,); state optional
    (BH,p,n) carry.  Literal scan; returns (out, final state)."""
    BH, S, p = x.shape
    B, _, n = Bm.shape
    H = BH // B
    x = np.asarray(x, np.float64)
    Bm = np.asarray(Bm, np.float64)
    Cm = np.asarray(Cm, np.float64)
    dt = np.asarray(dt, np.float64)
    A = np.asarray(A, np.float64)
    D = np.asarray(D, np.float64)
    out = np.zeros_like(x)
    state = (np.zeros((BH, p, n)) if state is None
             else np.asarray(state, np.float64).copy())
    for t in range(S):
        a = np.exp(dt[:, t] * A)  # (BH,)
        bvec = Bm[:, t]  # (B, n)
        cvec = Cm[:, t]
        bfull = np.repeat(bvec, H, axis=0)  # (BH, n) head-major batch
        cfull = np.repeat(cvec, H, axis=0)
        state = (a[:, None, None] * state
                 + dt[:, t, None, None] * x[:, t, :, None] * bfull[:, None, :])
        out[:, t] = np.einsum("bn,bpn->bp", cfull, state) \
            + x[:, t] * D[:, None]
    return jnp.asarray(out, jnp.float32), jnp.asarray(state, jnp.float32)
