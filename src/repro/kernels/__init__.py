"""Pallas TPU kernels for the serving hot paths (validated in interpret
mode on CPU; compiled through Mosaic on real TPUs):

* flash_attention — prefill attention (causal / sliding-window / ALiBi /
  chunked-prefill ``q_start`` / GQA / cross)
* decode_attention — single-token attention over long KV caches (per-row
  ``pos``, window, ALiBi, cross ``kv_len``, GQA + MLA faithful scale)
* wkv6 — RWKV6 chunked recurrence (carried state in/out)
* ssd — Mamba2 state-space-dual chunked scan (carried state in/out)

Each wrapper ships a ``*_unsupported(**features) -> Optional[str]``
predicate naming the feature it cannot serve (the serving backend layer's
XLA-fallback dispatch test); calling a wrapper with an unsupported feature
raises ``ValueError`` instead of returning wrong numbers.  Shared runtime
knobs (interpret-mode default incl. the ``REPRO_PALLAS_INTERPRET``
override, backend-name validation) live in ``repro.kernels.runtime``.
"""
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref,
                                            decode_attention_unsupported)
from repro.kernels.flash_attention import (attention_ref, flash_attention,
                                           flash_attention_unsupported)
from repro.kernels.runtime import BACKENDS, default_interpret, resolve_backend
from repro.kernels.ssd import ssd, ssd_ref, ssd_unsupported
from repro.kernels.wkv6 import wkv6, wkv6_ref, wkv6_unsupported

__all__ = ["BACKENDS", "attention_ref", "decode_attention",
           "decode_attention_ref", "decode_attention_unsupported",
           "default_interpret", "flash_attention",
           "flash_attention_unsupported", "resolve_backend", "ssd",
           "ssd_ref", "ssd_unsupported", "wkv6", "wkv6_ref",
           "wkv6_unsupported"]
