"""Pallas TPU kernels for the serving hot paths (validated in interpret
mode on CPU; compiled through Mosaic on real TPUs):

* flash_attention — prefill attention (causal / sliding-window / GQA)
* decode_attention — single-token attention over long KV caches (GQA + MLA)
* wkv6 — RWKV6 chunked recurrence
* ssd — Mamba2 state-space-dual chunked scan
"""
from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.ssd import ssd, ssd_ref
from repro.kernels.wkv6 import wkv6, wkv6_ref

__all__ = ["attention_ref", "decode_attention", "decode_attention_ref",
           "flash_attention", "ssd", "ssd_ref", "wkv6", "wkv6_ref"]
