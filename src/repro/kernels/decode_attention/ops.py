"""Jit'd wrapper: model-layout decode attention via the Pallas kernel.

Covers GQA ((B,1,H,D) queries over (B,T,Kv,D) caches) and MLA absorbed
decode (Kv=1, Dk = kv_lora+rope, Dv = kv_lora), with the full masking
surface of the XLA oracle (``models.attention.decode_attention_xla``):
per-row ``pos``, sliding ``window``, ALiBi ``slopes``, cross-attention
``kv_len``, and a caller-supplied faithful ``scale`` for MLA.

``decode_attention_unsupported`` is the dispatch predicate of the serving
backend layer: it names the feature (if any) this kernel cannot yet serve
for a given call, in which case the backend layer falls back to the XLA
oracle and a direct kernel call raises ``ValueError`` instead of
returning wrong numbers.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_bkv)
from repro.kernels.runtime import default_interpret


def decode_attention_unsupported(*, causal: bool = True, window=None,
                                 slopes=None, kv_len=None,
                                 scale=None) -> Optional[str]:
    """Reason this kernel cannot serve a decode-attention call, else None.

    Per-row ``pos``, ``window``, ``slopes``, ``kv_len`` and ``scale`` are
    all supported natively; the residual gap is the combination the XLA
    oracle defines but no call site produces:
    """
    if window is not None and not causal:
        return ("sliding-window masking on non-causal (cross) decode "
                "attention")
    return None


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_kv",
                                             "interpret"))
def decode_attention(q, ck, cv, pos, *, window=None, slopes=None,
                     kv_len=None, causal: bool = True,
                     scale: Optional[float] = None, block_kv: int = 256,
                     interpret: Optional[bool] = None):
    """q (B,1,H,Dk); ck (B,T,Kv,Dk); cv (B,T,Kv,Dv) -> (B,1,H,Dv).

    ``pos``: scalar or (B,) int32 per-row position.  ``window``: optional
    sliding window (scalar, may be traced).  ``slopes``: optional (H,) f32
    ALiBi slopes.  ``kv_len``: optional scalar or (B,) valid cache length
    (cross attention over an over-allocated cache).  ``scale``: optional
    softmax scale override (MLA faithful scale).
    """
    reason = decode_attention_unsupported(causal=causal, window=window,
                                          slopes=slopes, kv_len=kv_len,
                                          scale=scale)
    if reason is not None:
        raise ValueError(f"decode_attention (pallas) does not support "
                         f"{reason}")
    interpret = default_interpret() if interpret is None else interpret
    B, _, H, Dk = q.shape
    T, Kv = ck.shape[1], ck.shape[2]
    Dv = cv.shape[-1]
    G = H // Kv
    qf = q.reshape(B, Kv, G, Dk).reshape(B * Kv, G, Dk)
    kf = ck.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dk)
    vf = cv.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dv)

    def per_row(x):  # (,) or (B,) -> (B*Kv,)
        if x is None:
            return None
        x = jnp.broadcast_to(jnp.asarray(x, jnp.int32).reshape(-1), (B,))
        return jnp.repeat(x, Kv)

    slopes_bkv = None
    if slopes is not None:  # (H,) -> (B*Kv, G), matching the (Kv, G) split
        slopes_bkv = jnp.broadcast_to(
            jnp.asarray(slopes, jnp.float32).reshape(Kv, G)[None],
            (B, Kv, G)).reshape(B * Kv, G)
    out = decode_attention_bkv(qf, kf, vf, per_row(pos),
                               kv_len=per_row(kv_len), window=window,
                               slopes=slopes_bkv, causal=causal, scale=scale,
                               block_kv=block_kv, interpret=interpret)
    return out.reshape(B, 1, H, Dv)
