"""Jit'd wrapper: model-layout decode attention via the Pallas kernel.

Covers GQA ((B,1,H,D) queries over (B,T,Kv,D) caches) and MLA absorbed
decode (Kv=1, Dk = kv_lora+rope, Dv = kv_lora).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_bkv)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, ck, cv, pos, *, block_kv: int = 256,
                     interpret: Optional[bool] = None):
    """q (B,1,H,Dk); ck (B,T,Kv,Dk); cv (B,T,Kv,Dv) -> (B,1,H,Dv)."""
    interpret = _default_interpret() if interpret is None else interpret
    B, _, H, Dk = q.shape
    T, Kv = ck.shape[1], ck.shape[2]
    Dv = cv.shape[-1]
    G = H // Kv
    qf = q.reshape(B, Kv, G, Dk).reshape(B * Kv, G, Dk)
    kf = ck.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dk)
    vf = cv.transpose(0, 2, 1, 3).reshape(B * Kv, T, Dv)
    out = decode_attention_bkv(qf, kf, vf, pos, block_kv=block_kv,
                               interpret=interpret)
    return out.reshape(B, 1, H, Dv)
