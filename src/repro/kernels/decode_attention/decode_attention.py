"""Pallas TPU decode attention: one query token over a long KV cache.

Grid = (B*Kv, kv_blocks); the per-(batch, kv-head) query group (G = H/Kv
rows) stays resident in VMEM while KV blocks stream through — the memory-
bound regime the Pallas kernel exists for (reads the cache exactly once at
bf16, vs the XLA path's f32 upcasts).  Handles GQA groups natively and MLA
absorbed decode as the Kv=1 special case with asymmetric K/V widths and a
caller-supplied faithful softmax scale.

The masking semantics mirror ``models.attention.decode_attention_xla``
exactly — the contract the pooled serving steps dispatch on:

* ``pos`` is PER ROW (shape ``(BKv,)`` in SMEM, indexed by
  ``program_id(0)``): pooled cache rows decode at different positions.
* causal + sliding-window: valid iff ``0 <= pos - kv_pos < window``
  (``window`` is a dynamic scalar — gemma3's local:global pattern makes it
  a traced per-layer value inside the scanned pooled step).
* ``kv_len`` masks ``kv_pos >= kv_len`` per row — the enc-dec cross-
  attention case where the pooled cross-KV cache is allocated longer than
  the session's encoder output (``causal=False``).
* ALiBi: ``slopes (BKv, G)`` adds ``slope * -|pos - kv_pos|`` to the
  logits before masking (bloom).

KV blocks with no valid position still contribute exact zeros: masked
probabilities are zeroed explicitly (``NEG_INF`` is finite, so the naive
``exp(s - m)`` of an all-masked block would be ``exp(0) = 1`` and corrupt
the softmax denominator — the window/kv_len regression this file's tests
pin down).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import NO_WINDOW

NEG_INF = -1e30


def _kernel(pos_ref, kvl_ref, win_ref, *rest, block_kv, group, causal,
            has_slopes, scale):
    if has_slopes:
        slopes_ref, q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr = rest
    else:
        q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr = rest
    b = pl.program_id(0)
    ki = pl.program_id(1)
    n_kv = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    pos = pos_ref[b]
    kvl = kvl_ref[b]
    win = win_ref[0]
    kv_start = ki * block_kv

    if causal:
        # skip blocks wholly past pos, wholly before the window, or wholly
        # past the valid cache prefix
        run = ((kv_start <= pos) & (kv_start + block_kv - 1 > pos - win)
               & (kv_start < kvl))
    else:
        run = kv_start < kvl

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (G, d_k)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d_k)
        v = v_ref[0].astype(jnp.float32)  # (block_kv, d_v)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, block_kv)
        kv_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (group, block_kv), 1)
        diff = pos - kv_pos
        if has_slopes:
            s = s + slopes_ref[0][:, None] * (
                -jnp.abs(diff).astype(jnp.float32))
        ok = kv_pos < kvl
        if causal:
            ok &= (diff >= 0) & (diff < win)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        # NEG_INF is finite: an all-masked block has m_new == NEG_INF and
        # exp(s - m_new) == 1 on masked entries — zero them explicitly
        p = jnp.where(ok, jnp.exp(s - m_new[:, None]), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc[...] / denom).astype(o_ref.dtype)


def decode_attention_bkv(q, k, v, pos, *, kv_len=None, window=None,
                         slopes=None, causal: bool = True,
                         scale: Optional[float] = None, block_kv: int = 256,
                         interpret: bool = False):
    """q (BKv, G, Dk); k (BKv, T, Dk); v (BKv, T, Dv).

    ``pos``: scalar or (BKv,) int32 — per-row current position.
    ``kv_len``: optional scalar or (BKv,) int32 valid-cache length.
    ``window``: optional scalar (python int or traced) sliding window.
    ``slopes``: optional (BKv, G) f32 ALiBi slopes.
    ``scale``: softmax scale; defaults to 1/sqrt(Dk) (MLA absorbed decode
    passes its faithful 1/sqrt(nope+rope) here).
    """
    BKv, G, Dk = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    block_kv = min(block_kv, T)
    pad = (-T) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    n_kv = k.shape[1] // block_kv
    kern = functools.partial(
        _kernel, block_kv=block_kv, group=G, causal=causal,
        has_slopes=slopes is not None,
        scale=float(scale) if scale is not None else 1.0 / np.sqrt(Dk))
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                               (BKv,))
    kvl_arr = jnp.broadcast_to(
        jnp.asarray(T if kv_len is None else kv_len, jnp.int32).reshape(-1),
        (BKv,))
    win_arr = jnp.asarray(NO_WINDOW if window is None else window,
                          jnp.int32).reshape(1)
    scalar_specs = [pl.BlockSpec(memory_space=pltpu.SMEM)] * 3
    inputs = [pos_arr, kvl_arr, win_arr]
    slope_specs = []
    if slopes is not None:
        slope_specs = [pl.BlockSpec((1, G), lambda b, ki: (b, 0))]
        inputs.append(jnp.asarray(slopes, jnp.float32))
    out = pl.pallas_call(
        kern,
        grid=(BKv, n_kv),
        in_specs=scalar_specs + slope_specs + [
            pl.BlockSpec((1, G, Dk), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, Dk), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, Dv), lambda b, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(*inputs, q, k, v)
    return out
