"""Pallas TPU decode attention: one query token over a long KV cache.

Grid = (B*Kv, kv_blocks); the per-(batch, kv-head) query group (G = H/Kv
rows) stays resident in VMEM while KV blocks stream through — the memory-
bound regime the Pallas kernel exists for (reads the cache exactly once at
bf16, vs the XLA path's f32 upcasts).  Handles GQA groups natively and MLA
absorbed decode as the Kv=1 special case with asymmetric K/V widths.
Length masking uses the current position (cache slots beyond ``pos`` are
invalid).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *,
            block_kv, group, d_v, scale):
    ki = pl.program_id(1)
    n_kv = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    pos = pos_ref[0]
    kv_start = ki * block_kv

    @pl.when(kv_start <= pos)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (G, d_k)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d_k)
        v = v_ref[0].astype(jnp.float32)  # (block_kv, d_v)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, block_kv)
        kv_pos = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (group, block_kv), 1)
        s = jnp.where(kv_pos <= pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc[...] / denom).astype(o_ref.dtype)


def decode_attention_bkv(q, k, v, pos, *, block_kv: int = 256,
                         interpret: bool = False):
    """q (BKv, G, Dk); k (BKv, T, Dk); v (BKv, T, Dv); pos scalar int32."""
    BKv, G, Dk = q.shape
    T = k.shape[1]
    Dv = v.shape[-1]
    block_kv = min(block_kv, T)
    pad = (-T) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    n_kv = k.shape[1] // block_kv
    kern = functools.partial(_kernel, block_kv=block_kv, group=G, d_v=Dv,
                             scale=1.0 / np.sqrt(Dk))
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    out = pl.pallas_call(
        kern,
        grid=(BKv, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, Dk), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, Dk), lambda b, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_kv, Dv), lambda b, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BKv, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, Dv), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k, v)
    return out
