from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_unsupported)
from repro.kernels.decode_attention.ref import decode_attention_ref

__all__ = ["decode_attention", "decode_attention_ref",
           "decode_attention_unsupported"]
