"""Pure-jnp oracle for decode attention (mirrors models.attention
decode_attention_xla semantics for a (BKv, G) query layout)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, pos):
    """q (BKv, G, Dk); k (BKv, T, Dk); v (BKv, T, Dv)."""
    Dk = q.shape[-1]
    logits = jnp.einsum("bgd,btd->bgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(Dk)
    kv_pos = jnp.arange(k.shape[1])
    logits = jnp.where(kv_pos[None, None, :] <= pos, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bgt,btd->bgd", p, v.astype(jnp.float32)).astype(q.dtype)
