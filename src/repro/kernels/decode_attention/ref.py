"""Pure-jnp oracle for decode attention (mirrors models.attention
decode_attention_xla semantics for a (BKv, G) query layout), over the full
masking surface: per-row pos, sliding window, ALiBi slopes, kv_len."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q, k, v, pos, *, kv_len=None, window=None,
                         slopes=None, causal=True, scale=None):
    """q (BKv, G, Dk); k (BKv, T, Dk); v (BKv, T, Dv).

    ``pos``/``kv_len``: scalar or (BKv,); ``window``: optional scalar;
    ``slopes``: optional (BKv, G); ``scale``: optional softmax scale.
    """
    Dk = q.shape[-1]
    T = k.shape[1]
    scale = (1.0 / np.sqrt(Dk)) if scale is None else scale
    logits = jnp.einsum("bgd,btd->bgt", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    kv_pos = jnp.arange(T)
    pos = jnp.broadcast_to(jnp.asarray(pos), (q.shape[0],))
    diff = pos[:, None] - kv_pos[None, :]  # (BKv, T)
    if slopes is not None:
        logits = logits + (jnp.asarray(slopes, jnp.float32)[:, :, None]
                           * (-jnp.abs(diff))[:, None, :])
    if causal:
        ok = diff >= 0
        if window is not None:
            ok &= diff < window
    else:
        ok = jnp.ones_like(diff, bool)
    if kv_len is not None:
        kvl = jnp.broadcast_to(jnp.asarray(kv_len), (q.shape[0],))
        ok &= kv_pos[None, :] < kvl[:, None]
    logits = jnp.where(ok[:, None, :], logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bgt,btd->bgd", p, v.astype(jnp.float32)).astype(q.dtype)
