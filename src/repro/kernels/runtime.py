"""Shared runtime knobs for the Pallas serving kernels.

One module owns the two decisions every kernel wrapper used to make for
itself (four copy-pasted ``_default_interpret`` helpers before this file
existed — a backend change could silently drift per kernel):

* ``default_interpret()`` — whether ``pallas_call`` should run in interpret
  mode.  Off-TPU backends (the CPU CI/container) must interpret; real TPUs
  compile through Mosaic.  The ``REPRO_PALLAS_INTERPRET`` environment
  variable overrides the platform probe (``1``/``true`` forces interpret,
  ``0``/``false`` forces compiled) so CI jobs pin a deterministic mode
  regardless of the host.
* ``resolve_backend()`` — validation for the engine-facing compute-backend
  switch (``backend="xla" | "pallas"``) threaded from
  ``serving.GeoServingSystem`` through the pooled step factories down to
  the per-kind block functions.  ``"xla"`` is the oracle path (pure jnp,
  runs everywhere); ``"pallas"`` routes supported block computations
  through ``repro.kernels`` and falls back to the oracle per call site via
  the kernels' own ``*_unsupported`` dispatch predicates.
"""
from __future__ import annotations

import os

import jax

# The engine-facing compute backends.  "xla" is the default/oracle path;
# "pallas" dispatches supported calls to the kernels in this package.
BACKENDS = ("xla", "pallas")

# "no sliding window" sentinel shared by every masking path (both Pallas
# kernels and the XLA oracle in models/attention.py): int32-safe and larger
# than any position, so `diff < NO_WINDOW` never masks.  One definition —
# per-kernel copies could drift and silently change window semantics.
NO_WINDOW = 1 << 30

_INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def resolve_backend(backend: str) -> str:
    """Validate a compute-backend name; ``ValueError`` names the options."""
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown compute backend {backend!r}; supported backends: "
            + ", ".join(BACKENDS))
    return backend


def default_interpret() -> bool:
    """Interpret-mode default for ``pallas_call``.

    ``REPRO_PALLAS_INTERPRET`` (when set and non-empty) wins: ``0``/
    ``false`` force compiled execution, anything else forces interpret —
    the CI determinism hook.  Otherwise interpret iff the default jax
    backend is not a TPU (Pallas TPU kernels cannot lower elsewhere).
    """
    env = os.environ.get(_INTERPRET_ENV)
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() != "tpu"
