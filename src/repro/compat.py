"""Version-compatibility shims for the jax API churn this repo straddles.

* ``shard_map``: jax >= 0.6 exposes ``jax.shard_map`` with ``check_vma``;
  0.4.x only has ``jax.experimental.shard_map.shard_map`` with the older
  ``check_rep`` spelling of the same knob.
* ``jax.sharding.AxisType`` (used by ``repro.launch.mesh.compat_make_mesh``)
  only exists on newer versions; ``jax.make_mesh`` grew the ``axis_types``
  kwarg at the same time.

Keeping the adapters in one module means every caller (models, launch,
tests) stays version-agnostic.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` adapter; ``check`` maps to check_vma/check_rep."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        try:
            return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        except TypeError:  # transitional versions spell it check_rep
            return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
