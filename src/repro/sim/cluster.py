"""Evaluation scenarios (paper §4.1).

* ``clustered_scenario`` — Table 2: Cluster0 (clients only), Cluster1
  (2 A100-class servers), Cluster2 (7 MIG-class servers); intra-cluster
  5 ms RTT / 1 Gbit/s, inter-cluster 100 ms / 100 Mbit/s.
* server profiles calibrated to the paper's PETALS/BLOOM-176B numbers
  (NF4 blocks s_m ≈ 1.4 GB; PETALS places 53 blocks on an A100 and 4 on a
  MIG; our CG-BP places ~41 / 3 — §4.2 Remark).  τ values are fit to the
  Table 8 per-token times; they are *configurable*, the algorithms never
  depend on the constants.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.perf_model import (GB, LLMSpec, BLOOM_PETALS, Problem,
                                   ServerSpec, Workload)

# profiled per-block times (s) — calibration targets in benchmarks/README
A100 = dict(tau=0.011, tau_p0=0.030, tau_p1=0.001, mem=78 * GB)
MIG = dict(tau=0.030, tau_p0=0.080, tau_p1=0.003, mem=8 * GB)

EMBED_BYTES = 2 * 14336  # one bf16 embedding per token (BLOOM)


@dataclass
class NetParams:
    rtt_s: float  # propagation round trip
    bandwidth_bps: float

    def token_rtt(self) -> float:
        return self.rtt_s + 2 * 8 * EMBED_BYTES / self.bandwidth_bps

    def prefill_rtt(self, l_in: int) -> float:
        return self.rtt_s + 2 * 8 * EMBED_BYTES * l_in / self.bandwidth_bps


INTRA = NetParams(0.005, 1e9)
INTER = NetParams(0.100, 100e6)


def make_server(sid: int, profile: dict) -> ServerSpec:
    return ServerSpec(sid=sid, mem_bytes=profile["mem"], tau=profile["tau"],
                      tau_prefill_base=profile["tau_p0"],
                      tau_prefill_per_token=profile["tau_p1"])


def clustered_scenario(client_cluster: int = 0,
                       workload: Workload = Workload(20, 128),
                       llm: LLMSpec = BLOOM_PETALS
                       ) -> Tuple[Problem, List[int]]:
    """Table 2 deployment.  Servers: ids 0–1 = A100s (cluster1),
    2–8 = MIGs (cluster2).  One client in ``client_cluster``.

    Returns (problem, server_cluster_of) for inspection.
    """
    servers = [make_server(0, A100), make_server(1, A100)]
    servers += [make_server(2 + i, MIG) for i in range(7)]
    cluster_of = [1, 1] + [2] * 7
    n = len(servers)
    rtt_tok = np.zeros((1, n))
    rtt_pre = np.zeros((1, n))
    for j in range(n):
        net = INTRA if cluster_of[j] == client_cluster else INTER
        rtt_tok[0, j] = net.token_rtt()
        rtt_pre[0, j] = net.prefill_rtt(workload.l_in)
    return (Problem(llm, servers, 1, rtt_tok, rtt_pre, workload),
            cluster_of)


def scattered_scenario(rtt_matrix_s: np.ndarray, server_nodes: List[int],
                       client_node: int, high_perf: List[bool],
                       workload: Workload = Workload(20, 128),
                       llm: LLMSpec = BLOOM_PETALS,
                       bandwidth_bps: float = 1e9) -> Problem:
    """Build a Problem from a topology RTT matrix (see sim.topologies)."""
    servers = []
    n = len(server_nodes)
    rtt_tok = np.zeros((1, n))
    rtt_pre = np.zeros((1, n))
    for j, node in enumerate(server_nodes):
        servers.append(make_server(j, A100 if high_perf[j] else MIG))
        ser_tok = 2 * 8 * EMBED_BYTES / bandwidth_bps
        ser_pre = ser_tok * workload.l_in
        rtt_tok[0, j] = rtt_matrix_s[client_node, node] + ser_tok
        rtt_pre[0, j] = rtt_matrix_s[client_node, node] + ser_pre
    return Problem(llm, servers, 1, rtt_tok, rtt_pre, workload)
