from repro.sim.cluster import (A100, MIG, clustered_scenario,
                               scattered_scenario)
from repro.sim.simulator import (ALGORITHMS, SIM_MODES, ChurnResult,
                                 FaultSimResult, SimConfig, SimResult,
                                 run_comparison, simulate, simulate_churn,
                                 simulate_faults, subchain_route)
from repro.sim.topologies import (TOPOLOGY_SPECS, Topology, make_topology,
                                  place_servers)
from repro.sim.workload import (ChurnEvent, Request, RequestBatch,
                                burst_requests, bursty_requests,
                                churn_schedule, diurnal_rate,
                                diurnal_requests, fault_schedule,
                                poisson_requests, prompts_for)

__all__ = [
    "A100", "ALGORITHMS", "MIG", "ChurnEvent", "ChurnResult",
    "FaultSimResult", "Request", "RequestBatch", "SIM_MODES", "SimConfig",
    "SimResult", "TOPOLOGY_SPECS", "Topology", "burst_requests",
    "bursty_requests", "churn_schedule", "clustered_scenario",
    "diurnal_rate", "diurnal_requests", "fault_schedule", "make_topology",
    "place_servers", "poisson_requests", "prompts_for", "run_comparison",
    "scattered_scenario", "simulate", "simulate_churn", "simulate_faults",
    "subchain_route",
]
