from repro.sim.cluster import (A100, MIG, clustered_scenario,
                               scattered_scenario)
from repro.sim.simulator import (ALGORITHMS, SimConfig, SimResult,
                                 run_comparison, simulate)
from repro.sim.topologies import (TOPOLOGY_SPECS, Topology, make_topology,
                                  place_servers)
from repro.sim.workload import (Request, burst_requests, poisson_requests,
                                prompts_for)

__all__ = [
    "A100", "ALGORITHMS", "MIG", "Request", "SimConfig", "SimResult",
    "TOPOLOGY_SPECS", "Topology", "burst_requests", "clustered_scenario",
    "make_topology", "place_servers", "poisson_requests", "prompts_for",
    "run_comparison", "scattered_scenario", "simulate",
]
