"""Internet-Topology-Zoo-style topologies (paper Table 3).

The Zoo's GraphML files are not redistributable here, so we *synthesise*
seeded random geometric graphs matching each topology's published node
count, link count, and link-delay range (AboveNet 23/62/[0.1,13.8] ms,
BellCanada 48/130/[0.078,6.16] ms, GTS-CE 149/386/[0.005,1.081] ms) with
1 Gbit/s links, and compute node-pair RTTs along delay-shortest paths as in
§4.1.  The generator is deterministic per (name, seed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

TOPOLOGY_SPECS = {
    "abovenet": dict(n=23, links=62, delay_ms=(0.100, 13.800)),
    "bellcanada": dict(n=48, links=130, delay_ms=(0.078, 6.160)),
    "gts_ce": dict(n=149, links=386, delay_ms=(0.005, 1.081)),
}


@dataclass
class Topology:
    name: str
    n: int
    edges: List[Tuple[int, int, float]]  # (u, v, one-way delay seconds)
    rtt: np.ndarray  # (n, n) round-trip seconds via shortest delay paths


def _geometric_graph(n: int, links: int, delay_range, seed: int):
    rng = np.random.default_rng(seed)
    pos = rng.random((n, 2))
    d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    # spanning tree first (connectivity), then shortest remaining pairs
    edges = set()
    in_tree = {0}
    out = set(range(1, n))
    while out:
        best = None
        for u in in_tree:
            for v in out:
                if best is None or d[u, v] < d[best[0], best[1]]:
                    best = (u, v)
        edges.add(tuple(sorted(best)))
        in_tree.add(best[1])
        out.remove(best[1])
    pairs = [(d[u, v], u, v) for u in range(n) for v in range(u + 1, n)
             if (u, v) not in edges]
    pairs.sort()
    for _, u, v in pairs:
        if len(edges) >= links:
            break
        edges.add((u, v))
    lo, hi = delay_range
    dmax = max(d[u, v] for u, v in edges)
    out_edges = []
    for u, v in sorted(edges):
        delay_ms = lo + (hi - lo) * (d[u, v] / dmax)
        out_edges.append((u, v, delay_ms / 1e3))
    return out_edges


def _all_pairs_rtt(n: int, edges) -> np.ndarray:
    INF = np.inf
    dist = np.full((n, n), INF)
    np.fill_diagonal(dist, 0.0)
    for u, v, w in edges:
        dist[u, v] = min(dist[u, v], w)
        dist[v, u] = min(dist[v, u], w)
    for k in range(n):  # Floyd–Warshall (n <= 149)
        dist = np.minimum(dist, dist[:, k: k + 1] + dist[k: k + 1, :])
    return 2.0 * dist  # RTT


def make_topology(name: str, seed: int = 0) -> Topology:
    spec = TOPOLOGY_SPECS[name]
    edges = _geometric_graph(spec["n"], spec["links"], spec["delay_ms"],
                             seed=hash((name, seed)) % (1 << 31))
    rtt = _all_pairs_rtt(spec["n"], edges)
    return Topology(name, spec["n"], edges, rtt)


def place_servers(topo: Topology, n_servers: int, eta: float, seed: int = 0
                  ) -> Tuple[List[int], List[bool], int]:
    """Random server nodes, high-perf fraction η, plus a non-server client
    node (the proxy of §4.1)."""
    rng = np.random.default_rng(seed)
    nodes = rng.permutation(topo.n)
    server_nodes = nodes[:n_servers].tolist()
    client_node = int(nodes[n_servers % topo.n])
    n_high = int(round(eta * n_servers))
    flags = [True] * n_high + [False] * (n_servers - n_high)
    rng.shuffle(flags)
    return server_nodes, flags, client_node
