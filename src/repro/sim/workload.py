"""Poisson request workload (paper §4.1: N_R requests at rate λ from a
proxy client)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    client: int
    arrival: float


def poisson_requests(n_requests: int, rate: float, client: int = 0,
                     seed: int = 0) -> List[Request]:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    times = np.cumsum(gaps)
    return [Request(rid=i, client=client, arrival=float(t))
            for i, t in enumerate(times)]
