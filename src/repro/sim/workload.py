"""Poisson request workload (paper §4.1: N_R requests at rate λ from a
proxy client).

The same trace feeds BOTH the discrete-event simulator
(``repro.sim.simulator.simulate(..., requests=...)``) and the real engine
(``repro.serving.ContinuousBatchingScheduler``) — the cross-validation in
``benchmarks/engine_validation.py`` relies on byte-identical arrival
processes on the two paths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    client: int
    arrival: float


def poisson_requests(n_requests: int, rate: float, client: int = 0,
                     seed: int = 0,
                     n_clients: Optional[int] = None) -> List[Request]:
    """Poisson arrivals; with ``n_clients`` the issuing client is drawn
    uniformly per request (multi-client traffic), otherwise all requests
    come from ``client`` (the paper's proxy-client setup)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    times = np.cumsum(gaps)
    if n_clients is not None:
        clients = rng.integers(0, n_clients, size=n_requests)
    else:
        clients = np.full(n_requests, client)
    return [Request(rid=i, client=int(c), arrival=float(t))
            for i, (t, c) in enumerate(zip(times, clients))]


def burst_requests(n_requests: int, at: float = 0.0, client: int = 0
                   ) -> List[Request]:
    """All requests arrive at once — the max-concurrency stress trace."""
    return [Request(rid=i, client=client, arrival=float(at))
            for i in range(n_requests)]


def prompts_for(requests: Sequence[Request], l_in: int, vocab_size: int,
                seed: int = 0) -> List[np.ndarray]:
    """Deterministic per-request prompt tokens (ids >= 2) of length l_in."""
    rng = np.random.default_rng(seed + 7)
    return [rng.integers(2, vocab_size, size=l_in) for _ in requests]
