"""Poisson request workload (paper §4.1: N_R requests at rate λ from a
proxy client).

The same trace feeds BOTH the discrete-event simulator
(``repro.sim.simulator.simulate(..., requests=...)``) and the real engine
(``repro.serving.ContinuousBatchingScheduler``) — the cross-validation in
``benchmarks/engine_validation.py`` relies on byte-identical arrival
processes on the two paths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    client: int
    arrival: float


@dataclass(frozen=True)
class RequestBatch:
    """Array-backed request trace — the SoA twin of ``List[Request]``.

    The fast simulator loop (``SimConfig(sim_mode="fast")``) reads the
    ``arrival``/``client`` arrays directly; iterating a batch yields plain
    :class:`Request` objects with the identical float arrivals, so the
    reference loop (and the serving engine's trace replay) consumes the
    same batch unchanged — one trace object, two execution paths."""

    arrival: np.ndarray
    client: np.ndarray
    rid: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "arrival", np.asarray(self.arrival, float))
        object.__setattr__(self, "client", np.asarray(self.client, np.int64))
        object.__setattr__(self, "rid", np.asarray(self.rid, np.int64))
        if not (self.arrival.shape == self.client.shape == self.rid.shape
                and self.arrival.ndim == 1):
            raise ValueError("RequestBatch arrays must be 1-D of equal length")

    def __len__(self) -> int:
        return int(self.arrival.shape[0])

    def __iter__(self):
        for rid, c, t in zip(self.rid.tolist(), self.client.tolist(),
                             self.arrival.tolist()):
            yield Request(rid=rid, client=c, arrival=t)

    def to_requests(self) -> List[Request]:
        return list(self)

    @staticmethod
    def from_requests(requests: Sequence[Request]) -> "RequestBatch":
        return RequestBatch(
            arrival=np.asarray([r.arrival for r in requests], float),
            client=np.asarray([r.client for r in requests], np.int64),
            rid=np.asarray([r.rid for r in requests], np.int64))


def poisson_requests(n_requests: int, rate: float, client: int = 0,
                     seed: int = 0,
                     n_clients: Optional[int] = None) -> List[Request]:
    """Poisson arrivals; with ``n_clients`` the issuing client is drawn
    uniformly per request (multi-client traffic), otherwise all requests
    come from ``client`` (the paper's proxy-client setup)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    times = np.cumsum(gaps)
    if n_clients is not None:
        clients = rng.integers(0, n_clients, size=n_requests)
    else:
        clients = np.full(n_requests, client)
    return [Request(rid=i, client=int(c), arrival=float(t))
            for i, (t, c) in enumerate(zip(times, clients))]


def burst_requests(n_requests: int, at: float = 0.0, client: int = 0
                   ) -> List[Request]:
    """All requests arrive at once — the max-concurrency stress trace."""
    return [Request(rid=i, client=client, arrival=float(at))
            for i in range(n_requests)]


def bursty_requests(n_bursts: int, burst_size: int, spacing: float,
                    client: int = 0, start: float = 0.0,
                    jitter: float = 0.0, seed: int = 0) -> List[Request]:
    """Bursty arrivals: ``burst_size`` same-timestamp requests every
    ``spacing`` seconds — the trace shape that produces coalescable prefill
    groups in the engine (same-time starts admit together and share one
    pooled bucket-group prefill).  ``jitter > 0`` adds an exponential
    within-burst offset (mean ``jitter`` seconds) to each arrival, breaking
    exact simultaneity for robustness studies."""
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    rid = 0
    for b in range(n_bursts):
        t0 = start + b * spacing
        for _ in range(burst_size):
            t = t0 + (float(rng.exponential(jitter)) if jitter > 0 else 0.0)
            out.append(Request(rid=rid, client=client, arrival=t))
            rid += 1
    return out


def diurnal_rate(t, base_rate: float, peak_rate: float,
                 period: float, t0: float = 0.0):
    """λ(t) of the diurnal arrival process: a sinusoidal day curve with
    valley ``base_rate`` at ``t0`` and peak ``peak_rate`` half a period
    later (the planet-scale load shape: overnight trough, midday rush)."""
    x = 2.0 * np.pi * (np.asarray(t, float) - t0) / period
    return base_rate + (peak_rate - base_rate) * 0.5 * (1.0 - np.cos(x))


def diurnal_requests(n_requests: int, base_rate: float, peak_rate: float,
                     period: float = 86400.0, client: int = 0, seed: int = 0,
                     n_clients: Optional[int] = None,
                     t0: float = 0.0) -> RequestBatch:
    """Nonhomogeneous Poisson arrivals with the :func:`diurnal_rate` curve,
    sampled by thinning (Lewis–Shedler): candidate arrivals from a
    homogeneous process at ``peak_rate`` are kept with probability
    λ(t)/peak_rate.  Generated fully vectorized in chunks, so 1M-request
    traces are cheap; returns a :class:`RequestBatch`."""
    if not (0.0 <= base_rate <= peak_rate) or peak_rate <= 0.0:
        raise ValueError("need 0 <= base_rate <= peak_rate, peak_rate > 0")
    rng = np.random.default_rng(seed)
    lam_max = float(peak_rate)
    chunk = int(min(max(1024, 2 * n_requests), 1 << 20))
    kept: List[np.ndarray] = []
    total = 0
    t_cur = float(t0)
    while total < n_requests:
        ts = t_cur + np.cumsum(rng.exponential(1.0 / lam_max, size=chunk))
        t_cur = float(ts[-1])
        accept = (rng.uniform(size=chunk) * lam_max
                  < diurnal_rate(ts, base_rate, peak_rate, period, t0))
        keep = ts[accept]
        kept.append(keep)
        total += len(keep)
    times = np.concatenate(kept)[:n_requests]
    if n_clients is not None:
        clients = rng.integers(0, n_clients, size=n_requests)
    else:
        clients = np.full(n_requests, client)
    return RequestBatch(arrival=times, client=clients,
                        rid=np.arange(n_requests))


@dataclass(frozen=True)
class ChurnEvent:
    """One churn storm: at ``time``, servers in ``join`` come back online
    and servers in ``leave`` drop out (applied join-first, so a server may
    rejoin and immediately leave again in the same storm)."""

    time: float
    leave: Tuple[int, ...] = ()
    join: Tuple[int, ...] = ()


def churn_schedule(n_servers: int, n_storms: int, storm_size: int,
                   first: float = 60.0, spacing: float = 60.0, seed: int = 0,
                   protect: Sequence[int] = ()) -> List[ChurnEvent]:
    """Timed join/leave storms for elastic-fleet studies: each storm
    revives the previous storm's victims and knocks out ``storm_size``
    fresh random servers (never those in ``protect``), keeping the fleet
    size roughly constant between storms.  Feed the schedule to
    ``repro.sim.simulate_churn``, which maps each storm onto
    ``OnlineBPRR.replace_servers`` (the ``RouteCostCache`` invalidation
    path)."""
    rng = np.random.default_rng(seed)
    pool = np.asarray([j for j in range(n_servers) if j not in set(protect)])
    if storm_size > len(pool):
        raise ValueError("storm_size exceeds the non-protected fleet")
    events: List[ChurnEvent] = []
    down: Tuple[int, ...] = ()
    for s in range(n_storms):
        leave = tuple(sorted(int(j) for j in
                             rng.choice(pool, size=storm_size, replace=False)))
        events.append(ChurnEvent(time=first + s * spacing,
                                 leave=leave, join=down))
        down = leave
    return events


def fault_schedule(n_servers: int, seed: int = 0, *, horizon: float = 10.0,
                   n_crashes: int = 1, n_transients: int = 0,
                   n_stragglers: int = 0, n_dispatch_errors: int = 0,
                   rejoin_after: float = 2.0, straggler_len: float = 2.0,
                   max_factor: float = 6.0, protect: Sequence[int] = ()):
    """Deterministic randomized fault plan for chaos studies — the fault
    analogue of :func:`churn_schedule`.  Returns a
    :class:`repro.serving.faults.FaultPlan` drawing fail-stop crashes,
    crash-then-rejoin transients, straggler slowdown intervals, and
    admission-time dispatch errors from ``seed``.  The same plan drives
    the engine (``GeoServingSystem(fault_plan=...)``) and the analytic
    reference (``repro.sim.simulate_faults``), so chaos tests can assert
    engine/simulator agreement under identical fault timelines."""
    from repro.serving.faults import FaultPlan  # lazy: keep sim jax-free
    return FaultPlan.random(
        n_servers, seed, horizon=horizon, n_crashes=n_crashes,
        n_transients=n_transients, n_stragglers=n_stragglers,
        n_dispatch_errors=n_dispatch_errors, rejoin_after=rejoin_after,
        straggler_len=straggler_len, max_factor=max_factor,
        protect=protect)


def prompts_for(requests: Sequence[Request], l_in: int, vocab_size: int,
                seed: int = 0) -> List[np.ndarray]:
    """Deterministic per-request prompt tokens (ids >= 2) of length l_in."""
    return prompts_for_lengths(requests, [l_in], vocab_size, seed=seed)


def prompts_for_lengths(requests: Sequence[Request], lengths: Sequence[int],
                        vocab_size: int, seed: int = 0) -> List[np.ndarray]:
    """Deterministic per-request prompts cycling through ``lengths`` —
    mixed-length traffic that exercises multi-bucket prefill groups."""
    rng = np.random.default_rng(seed + 7)
    return [rng.integers(2, vocab_size, size=int(lengths[i % len(lengths)]))
            for i in range(len(requests))]
