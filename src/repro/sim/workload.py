"""Poisson request workload (paper §4.1: N_R requests at rate λ from a
proxy client).

The same trace feeds BOTH the discrete-event simulator
(``repro.sim.simulator.simulate(..., requests=...)``) and the real engine
(``repro.serving.ContinuousBatchingScheduler``) — the cross-validation in
``benchmarks/engine_validation.py`` relies on byte-identical arrival
processes on the two paths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    client: int
    arrival: float


def poisson_requests(n_requests: int, rate: float, client: int = 0,
                     seed: int = 0,
                     n_clients: Optional[int] = None) -> List[Request]:
    """Poisson arrivals; with ``n_clients`` the issuing client is drawn
    uniformly per request (multi-client traffic), otherwise all requests
    come from ``client`` (the paper's proxy-client setup)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    times = np.cumsum(gaps)
    if n_clients is not None:
        clients = rng.integers(0, n_clients, size=n_requests)
    else:
        clients = np.full(n_requests, client)
    return [Request(rid=i, client=int(c), arrival=float(t))
            for i, (t, c) in enumerate(zip(times, clients))]


def burst_requests(n_requests: int, at: float = 0.0, client: int = 0
                   ) -> List[Request]:
    """All requests arrive at once — the max-concurrency stress trace."""
    return [Request(rid=i, client=client, arrival=float(at))
            for i in range(n_requests)]


def bursty_requests(n_bursts: int, burst_size: int, spacing: float,
                    client: int = 0, start: float = 0.0,
                    jitter: float = 0.0, seed: int = 0) -> List[Request]:
    """Bursty arrivals: ``burst_size`` same-timestamp requests every
    ``spacing`` seconds — the trace shape that produces coalescable prefill
    groups in the engine (same-time starts admit together and share one
    pooled bucket-group prefill).  ``jitter > 0`` adds an exponential
    within-burst offset (mean ``jitter`` seconds) to each arrival, breaking
    exact simultaneity for robustness studies."""
    rng = np.random.default_rng(seed)
    out: List[Request] = []
    rid = 0
    for b in range(n_bursts):
        t0 = start + b * spacing
        for _ in range(burst_size):
            t = t0 + (float(rng.exponential(jitter)) if jitter > 0 else 0.0)
            out.append(Request(rid=rid, client=client, arrival=t))
            rid += 1
    return out


def prompts_for(requests: Sequence[Request], l_in: int, vocab_size: int,
                seed: int = 0) -> List[np.ndarray]:
    """Deterministic per-request prompt tokens (ids >= 2) of length l_in."""
    return prompts_for_lengths(requests, [l_in], vocab_size, seed=seed)


def prompts_for_lengths(requests: Sequence[Request], lengths: Sequence[int],
                        vocab_size: int, seed: int = 0) -> List[np.ndarray]:
    """Deterministic per-request prompts cycling through ``lengths`` —
    mixed-length traffic that exercises multi-bucket prefill groups."""
    rng = np.random.default_rng(seed + 7)
    return [rng.integers(2, vocab_size, size=int(lengths[i % len(lengths)]))
            for i in range(len(requests))]
