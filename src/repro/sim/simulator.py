"""Discrete-event simulator for distributed LLM inference (paper §4 byproduct).

Replicates the *decision logic* of both the PETALS baseline and the proposed
two-time-scale BPRR under the validated performance models:

* session duration from eq (1) (prefill + (l_out−1) per-token),
* cache-slot accounting per server:  ⌊(M_j − s_m m_j)/s_c⌋ block-slots,
  sessions occupy k_j slots from start to completion (eq (5)/(20)),
* proposed: WS-RR waiting via eq (20) + no-overbooking commitment,
* PETALS:  memory-oblivious routing + binary-exponential-backoff retries
  (1,2,4,...s, 60 s cap — §3.3.2 footnote / §4.1),
* ablations: 'optimized_order', 'optimized_number', 'optimized_rr' (§4.3).

Metrics follow §4.1: average per-token time over ALL tokens
(= total completion / l_out, waiting included), first-token time, and
per-remaining-token time.

Heterogeneous stacks: session durations come from
``route_prefill_time``/``route_per_token_time``, which apply the optional
per-family block weights ``LLMSpec.block_tau`` (zamba2 hybrids, enc-dec) —
the same weighted eq. (1) the engine's virtual clock uses, so
engine-vs-simulator cross-validation holds on hybrid topologies
(``benchmarks/engine_validation.py`` ``xval.hybrid.R{4,8}``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.milp import solve_online_routing
from repro.core.perf_model import (Placement, Problem, Route,
                                   route_per_token_time, route_prefill_time)
from repro.core.placement import (auto_R, cg_bp, max_feasible_R,
                                  optimized_number_bp, optimized_order_bp,
                                  petals_bp, petals_m)
from repro.core.routing import (RouteCostCache, ServerState,
                                edge_waiting_times, petals_route,
                                shortest_path_route, ws_rr)
from repro.sim.workload import Request, poisson_requests

ALGORITHMS = ("petals", "proposed", "optimized_order", "optimized_number",
              "optimized_rr")


@dataclass
class SimConfig:
    algorithm: str = "proposed"
    n_requests: int = 100
    rate: float = 0.1
    seed: int = 0
    R: Optional[int] = None  # design concurrency (None = auto rule)
    backoff_max: float = 60.0
    client: int = 0


@dataclass
class SimResult:
    algorithm: str
    per_token_all: float  # mean total/l_out  (paper's primary metric)
    first_token: float  # mean wait + prefill
    per_token_rest: float  # mean decode per-token
    wait: float
    drop_rate: float
    decision_time_s: float  # algorithm running time (Table 6)
    placement: Placement = None
    requests: List[Dict] = field(default_factory=list)


class _Timeline:
    """Per-server cache-slot commitments, stored as flat numpy event arrays
    (start, end, k_blocks) with amortized-doubling growth.

    ``usage_max`` — the inner loop of every ``fits()`` probe — is a fully
    vectorized sweep: clip the overlapping intervals to the window, lexsort
    the ±k events by (time, delta) exactly like the old per-tuple sort, and
    take the max of the running ``cumsum``.  The old implementation built
    and re-sorted a Python event list per call, which made admission
    quadratic in the number of committed sessions — this keeps the
    "light-weight CPU-only simulator for large deployments" claim honest at
    thousands of requests (``BENCH_engine.json`` ``sim.tput``).
    """

    def __init__(self, problem: Problem, placement: Placement):
        self.problem = problem
        self.placement = placement
        m = placement.m
        self.cap = np.floor((problem.mem() - problem.s_m * m)
                            / problem.s_c).astype(np.int64)
        self.cap = np.maximum(self.cap, 0)
        n = problem.n_servers
        self._start = [np.empty(8) for _ in range(n)]
        self._end = [np.empty(8) for _ in range(n)]
        self._k = [np.empty(8, np.int64) for _ in range(n)]
        self._n = [0] * n

    @property
    def commits(self) -> List[List[Tuple[float, float, int]]]:
        """Per-server [(start, end, k_blocks)] view of the event arrays."""
        return [list(zip(self._start[j][: self._n[j]].tolist(),
                         self._end[j][: self._n[j]].tolist(),
                         self._k[j][: self._n[j]].tolist()))
                for j in range(self.problem.n_servers)]

    def usage_max(self, j: int, t0: float, t1: float) -> int:
        """Max concurrent slot usage on server j over [t0, t1)."""
        n = self._n[j]
        if n == 0:
            return 0
        s, e, k = self._start[j][:n], self._end[j][:n], self._k[j][:n]
        live = (s < t1) & (e > t0)
        if not live.any():
            return 0
        ks = k[live]
        times = np.concatenate([np.maximum(s[live], t0),
                                np.minimum(e[live], t1)])
        deltas = np.concatenate([ks, -ks])
        order = np.lexsort((deltas, times))  # == sorted (time, ±k) tuples
        return int(np.cumsum(deltas[order]).max())

    def fits(self, route: Route, t: float, dur: float) -> bool:
        for j, k in zip(route.servers, route.blocks):
            if self.usage_max(j, t, t + dur) + k > self.cap[j]:
                return False
        return True

    def earliest_start(self, route: Route, t: float, dur: float) -> float:
        cands = {t}
        for j in route.servers:
            n = self._n[j]
            s, e = self._start[j][:n], self._end[j][:n]
            cands.update(e[e > t].tolist())
            cands.update(s[s > t].tolist())
        for u in sorted(cands):
            if self.fits(route, u, dur):
                return u
        return np.inf

    def commit(self, route: Route, start: float, dur: float):
        for j, k in zip(route.servers, route.blocks):
            n = self._n[j]
            if n == len(self._start[j]):  # amortized growth
                self._start[j] = np.concatenate(
                    [self._start[j], np.empty_like(self._start[j])])
                self._end[j] = np.concatenate(
                    [self._end[j], np.empty_like(self._end[j])])
                self._k[j] = np.concatenate(
                    [self._k[j], np.empty_like(self._k[j])])
            self._start[j][n] = start
            self._end[j][n] = start + dur
            self._k[j][n] = k
            self._n[j] = n + 1

    def states_at(self, t: float) -> Dict[int, ServerState]:
        """eq (20) view: active-or-committed sessions as (remaining, k)."""
        states: Dict[int, ServerState] = {}
        for j in range(self.problem.n_servers):
            n = self._n[j]
            live = self._end[j][:n] > t
            if live.any():
                states[j] = ServerState(
                    (self._end[j][:n][live] - t).tolist(),
                    self._k[j][:n][live].tolist())
        return states


def _backoff_attempts(t: float, horizon: float, cap: float):
    yield t
    delay = 1.0
    u = t
    while u < t + horizon:
        u += delay
        yield u
        delay = min(delay * 2, cap)


def _make_placement(problem: Problem, cfg: SimConfig, join_order
                    ) -> Tuple[Placement, int]:
    import time as _time

    t0 = _time.perf_counter()
    if cfg.R is not None:
        R = cfg.R
    else:
        # auto rule (after Cor. 3.6): arrivals during an expected session
        rough = 1.5 * problem.workload.l_out  # ~1.5 s/token prior estimate
        R = auto_R(problem, cfg.rate, rough)
    if cfg.algorithm == "petals":
        placement = petals_bp(problem, join_order=join_order)
    elif cfg.algorithm == "proposed":
        placement, _ = cg_bp(problem, R)
    elif cfg.algorithm == "optimized_order":
        placement = optimized_order_bp(problem, R)
    elif cfg.algorithm == "optimized_number":
        placement = optimized_number_bp(problem, R)
    elif cfg.algorithm == "optimized_rr":
        placement = petals_bp(problem, join_order=join_order)
    else:
        raise ValueError(cfg.algorithm)
    dt = _time.perf_counter() - t0
    return placement, R, dt


def simulate(problem: Problem, cfg: SimConfig,
             requests: Optional[List[Request]] = None) -> SimResult:
    import time as _time

    rng = np.random.default_rng(cfg.seed + 1)
    join_order = rng.permutation(problem.n_servers)  # random join (§4.1)
    placement, R, place_time = _make_placement(problem, cfg, join_order)
    if requests is None:
        requests = poisson_requests(cfg.n_requests, cfg.rate,
                                    client=cfg.client, seed=cfg.seed)
    tl = _Timeline(problem, placement)
    rows = []
    decision_time = place_time
    lw = problem.workload
    # placement is fixed for the whole trace: memoize the routing graph /
    # edge costs / slot capacities across arrivals (same cache the online
    # controller uses)
    route_cache = RouteCostCache(problem, placement)
    for req in requests:
        t = req.arrival
        t0 = _time.perf_counter()
        wait_est = 0.0
        if cfg.algorithm in ("proposed",):
            route, _, wait_est = ws_rr(problem, placement, req.client,
                                       tl.states_at(t), cache=route_cache)
        elif cfg.algorithm == "optimized_rr":
            waiting = edge_waiting_times(problem, placement, tl.states_at(t))
            route, _ = solve_online_routing(problem, placement, req.client,
                                            waiting)
            if route is None:
                route = petals_route(problem, placement, req.client)
        elif cfg.algorithm in ("optimized_order", "optimized_number"):
            route = petals_route(problem, placement, req.client)
        else:  # petals
            route = petals_route(problem, placement, req.client)
        decision_time += _time.perf_counter() - t0
        if route is None:
            rows.append(dict(drop=True))
            continue

        prefill = route_prefill_time(problem, route, req.client)
        per_tok = route_per_token_time(problem, route, req.client)
        dur = prefill + (lw.l_out - 1) * per_tok
        earliest = tl.earliest_start(route, t, dur)
        if not np.isfinite(earliest):
            rows.append(dict(drop=True))
            continue
        if cfg.algorithm == "proposed":
            start = earliest
        else:
            # PETALS-style exponential-backoff retry until memory frees
            start = np.inf
            for u in _backoff_attempts(t, horizon=earliest - t + 130.0,
                                       cap=cfg.backoff_max):
                if u >= earliest and tl.fits(route, u, dur):
                    start = u
                    break
            if not np.isfinite(start):
                start = earliest
        tl.commit(route, start, dur)
        wait = start - t
        rows.append(dict(
            drop=False, wait=wait, first_token=wait + prefill,
            per_token_rest=per_tok, total=wait + dur,
            per_token_all=(wait + dur) / lw.l_out,
            hops=len(route.servers)))

    ok = [r for r in rows if not r.get("drop")]
    drop_rate = 1.0 - len(ok) / max(1, len(rows))
    mean = lambda k: float(np.mean([r[k] for r in ok])) if ok else np.inf
    return SimResult(
        algorithm=cfg.algorithm,
        per_token_all=mean("per_token_all"),
        first_token=mean("first_token"),
        per_token_rest=mean("per_token_rest"),
        wait=mean("wait"),
        drop_rate=drop_rate,
        decision_time_s=decision_time / max(1, len(requests)),
        placement=placement,
        requests=rows,
    )


def run_comparison(problem: Problem, algorithms=("petals", "proposed"),
                   n_requests: int = 100, rate: float = 0.1,
                   seeds=(0, 1, 2, 3, 4), R: Optional[int] = None
                   ) -> Dict[str, Dict[str, float]]:
    """Monte-Carlo comparison (paper: 5 experiment / 20 sim runs)."""
    out = {}
    for alg in algorithms:
        metrics = []
        for seed in seeds:
            res = simulate(problem, SimConfig(
                algorithm=alg, n_requests=n_requests, rate=rate, seed=seed,
                R=R))
            metrics.append(res)
        out[alg] = {
            "per_token_all": float(np.mean([m.per_token_all for m in metrics])),
            "first_token": float(np.mean([m.first_token for m in metrics])),
            "per_token_rest": float(np.mean([m.per_token_rest
                                             for m in metrics])),
            "wait": float(np.mean([m.wait for m in metrics])),
            "decision_time_s": float(np.mean([m.decision_time_s
                                              for m in metrics])),
            "drop_rate": float(np.mean([m.drop_rate for m in metrics])),
        }
    return out
