"""Discrete-event simulator for distributed LLM inference (paper §4 byproduct).

Replicates the *decision logic* of both the PETALS baseline and the proposed
two-time-scale BPRR under the validated performance models:

* session duration from eq (1) (prefill + (l_out−1) per-token),
* cache-slot accounting per server:  ⌊(M_j − s_m m_j)/s_c⌋ block-slots,
  sessions occupy k_j slots from start to completion (eq (5)/(20)),
* proposed: WS-RR waiting via eq (20) + no-overbooking commitment,
* PETALS:  memory-oblivious routing + binary-exponential-backoff retries
  (1,2,4,...s, 60 s cap — §3.3.2 footnote / §4.1),
* ablations: 'optimized_order', 'optimized_number', 'optimized_rr' (§4.3).

Metrics follow §4.1: average per-token time over ALL tokens
(= total completion / l_out, waiting included), first-token time, and
per-remaining-token time.

Heterogeneous stacks: session durations come from
``route_prefill_time``/``route_per_token_time``, which apply the optional
per-family block weights ``LLMSpec.block_tau`` (zamba2 hybrids, enc-dec) —
the same weighted eq. (1) the engine's virtual clock uses, so
engine-vs-simulator cross-validation holds on hybrid topologies
(``benchmarks/engine_validation.py`` ``xval.hybrid.R{4,8}``).

Two execution modes (``SimConfig.sim_mode``), same results:

* ``"reference"`` — the original per-request loop, kept verbatim as the
  bit-exact twin (the ``decode_mode="serial"`` pattern).
* ``"fast"`` — the array-native event engine for planet-scale traces
  (``sim.tput.1M`` in BENCH_engine.json): a retirement heap + per-server
  running usage counters keep a contention-free O(1) fast path per
  arrival, the ``_Timeline`` prunes dead intervals behind the trace
  frontier, and eq. (20) state is consumed as :class:`ServerStateArrays`
  instead of per-arrival dict rebuilds.  Per-request rows, routes, start
  times, drops and every ``SimResult`` metric are EXACTLY equal to the
  reference mode (tests/test_simulator.py parity matrix); only
  ``decision_time_s`` (wall clock) differs.  See docs/concurrency.md
  "Planet-scale simulation".
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.milp import solve_online_routing
from repro.core.perf_model import (Placement, Problem, Route,
                                   route_per_token_time, route_prefill_time)
from repro.core.placement import (auto_R, cg_bp, max_feasible_R,
                                  optimized_number_bp, optimized_order_bp,
                                  petals_bp, petals_m)
from repro.core.routing import (RouteCostCache, ServerState,
                                ServerStateArrays, edge_waiting_times,
                                petals_route, shortest_path_route, ws_rr)
from repro.sim.workload import ChurnEvent, Request, RequestBatch, \
    poisson_requests

ALGORITHMS = ("petals", "proposed", "optimized_order", "optimized_number",
              "optimized_rr")

SIM_MODES = ("reference", "fast")

Trace = Union[Sequence[Request], RequestBatch]


@dataclass
class SimConfig:
    algorithm: str = "proposed"
    n_requests: int = 100
    rate: float = 0.1
    seed: int = 0
    R: Optional[int] = None  # design concurrency (None = auto rule)
    backoff_max: float = 60.0
    client: int = 0
    # multi-client auto-generated traces: draw the issuing client uniformly
    # from range(n_clients) per request (None = all from ``client``)
    n_clients: Optional[int] = None
    # "reference" = original per-request loop (bit-exact twin);
    # "fast" = array-native event engine, identical rows/metrics
    sim_mode: str = "reference"
    # False skips per-request row dicts (fast mode's 1M-request traces):
    # metrics are computed from preallocated arrays with the same np.mean
    # reduction, SimResult.requests comes back empty
    collect_rows: bool = True


@dataclass
class SimResult:
    algorithm: str
    per_token_all: float  # mean total/l_out  (paper's primary metric)
    first_token: float  # mean wait + prefill
    per_token_rest: float  # mean decode per-token
    wait: float
    drop_rate: float
    decision_time_s: float  # algorithm running time (Table 6)
    placement: Optional[Placement] = None
    requests: List[Dict] = field(default_factory=list)
    sim_mode: str = "reference"
    # fast mode only: contention-free vs re-decided arrivals etc.
    fast_stats: Optional[Dict[str, int]] = None


class _Timeline:
    """Per-server cache-slot commitments, stored as flat numpy event arrays
    (start, end, k_blocks) with amortized-doubling growth.

    ``usage_max`` — the inner loop of every ``fits()`` probe — is a fully
    vectorized sweep: clip the overlapping intervals to the window, lexsort
    the ±k events by (time, delta) exactly like the old per-tuple sort, and
    take the max of the running ``cumsum``.

    Two event-engine refinements keep probes O(live intervals) instead of
    O(trace) on long runs:

    * **Buffered commits** — ``commit`` appends to per-server Python lists
      and probes flush them into the numpy arrays in bulk, so the fast
      loop's contention-free arrivals never pay per-element numpy writes.
    * **Frontier pruning** — the driver advances ``frontier`` to the
      current arrival time; once every future probe window starts at or
      after the frontier (arrivals nondecreasing — the fast loop checks),
      intervals with ``end <= frontier`` can never overlap a probe window,
      appear among ``earliest_start`` candidates, or survive a
      ``states_at`` view, so ``_flush`` compacts them away instead of
      growing.  With ``frontier = -inf`` (reference mode) nothing is ever
      pruned and behavior is the original amortized doubling.
    """

    def __init__(self, problem: Problem, placement: Placement):
        self.problem = problem
        self.placement = placement
        m = placement.m
        self.cap = np.floor((problem.mem() - problem.s_m * m)
                            / problem.s_c).astype(np.int64)
        self.cap = np.maximum(self.cap, 0)
        n = problem.n_servers
        self._start = [np.empty(8) for _ in range(n)]
        self._end = [np.empty(8) for _ in range(n)]
        self._k = [np.empty(8, np.int64) for _ in range(n)]
        self._n = [0] * n
        self._pend: List[List[Tuple[float, float, int]]] = \
            [[] for _ in range(n)]
        self.frontier = -np.inf
        self.compactions = 0

    def _flush(self, j: int):
        pend = self._pend[j]
        if not pend:
            return
        nj = self._n[j]
        p = len(pend)
        if nj + p > len(self._start[j]):
            live = self._end[j][:nj] > self.frontier
            nl = int(live.sum())
            if nl < nj:  # compact dead intervals behind the frontier
                self._start[j][:nl] = self._start[j][:nj][live]
                self._end[j][:nl] = self._end[j][:nj][live]
                self._k[j][:nl] = self._k[j][:nj][live]
                nj = nl
                self.compactions += 1
            if nj + p > len(self._start[j]):  # amortized growth
                new_cap = max(8, len(self._start[j]))
                while new_cap < nj + p:
                    new_cap *= 2
                for arrs in (self._start, self._end, self._k):
                    new = np.empty(new_cap, arrs[j].dtype)
                    new[:nj] = arrs[j][:nj]
                    arrs[j] = new
        cols = np.array(pend)  # (p, 3); k column is exact small ints
        self._start[j][nj:nj + p] = cols[:, 0]
        self._end[j][nj:nj + p] = cols[:, 1]
        self._k[j][nj:nj + p] = cols[:, 2]
        self._n[j] = nj + p
        pend.clear()

    @property
    def commits(self) -> List[List[Tuple[float, float, int]]]:
        """Per-server [(start, end, k_blocks)] view of the event arrays."""
        for j in range(self.problem.n_servers):
            self._flush(j)
        return [list(zip(self._start[j][: self._n[j]].tolist(),
                         self._end[j][: self._n[j]].tolist(),
                         self._k[j][: self._n[j]].tolist()))
                for j in range(self.problem.n_servers)]

    def usage_max(self, j: int, t0: float, t1: float) -> int:
        """Max concurrent slot usage on server j over [t0, t1)."""
        self._flush(j)
        n = self._n[j]
        if n == 0:
            return 0
        s, e, k = self._start[j][:n], self._end[j][:n], self._k[j][:n]
        live = (s < t1) & (e > t0)
        if not live.any():
            return 0
        ks = k[live]
        times = np.concatenate([np.maximum(s[live], t0),
                                np.minimum(e[live], t1)])
        deltas = np.concatenate([ks, -ks])
        order = np.lexsort((deltas, times))  # == sorted (time, ±k) tuples
        return int(np.cumsum(deltas[order]).max())

    def fits(self, route: Route, t: float, dur: float) -> bool:
        for j, k in zip(route.servers, route.blocks):
            if self.usage_max(j, t, t + dur) + k > self.cap[j]:
                return False
        return True

    def earliest_start(self, route: Route, t: float, dur: float) -> float:
        cands = {t}
        for j in route.servers:
            self._flush(j)
            n = self._n[j]
            s, e = self._start[j][:n], self._end[j][:n]
            cands.update(e[e > t].tolist())
            cands.update(s[s > t].tolist())
        for u in sorted(cands):
            if self.fits(route, u, dur):
                return u
        return np.inf

    def commit(self, route: Route, start: float, dur: float):
        end = start + dur
        for j, k in zip(route.servers, route.blocks):
            self._pend[j].append((start, end, k))

    def states_at(self, t: float) -> Dict[int, ServerState]:
        """eq (20) view: active-or-committed sessions as (remaining, k)."""
        states: Dict[int, ServerState] = {}
        for j in range(self.problem.n_servers):
            self._flush(j)
            n = self._n[j]
            live = self._end[j][:n] > t
            if live.any():
                states[j] = ServerState(
                    (self._end[j][:n][live] - t).tolist(),
                    self._k[j][:n][live].tolist())
        return states

    def states_arrays_at(self, t: float) -> ServerStateArrays:
        """``states_at`` in SoA form — same live sessions, same float
        remainings, consumed by the vectorized ``edge_waiting_times``."""
        out = ServerStateArrays(self.problem.n_servers)
        for j in range(self.problem.n_servers):
            self._flush(j)
            n = self._n[j]
            if n == 0:
                continue
            ends = self._end[j][:n]
            live = ends > t
            if live.any():
                out.set(j, ends[live] - t, self._k[j][:n][live])
        return out


def _backoff_attempts(t: float, horizon: float, cap: float):
    yield t
    delay = 1.0
    u = t
    while u < t + horizon:
        u += delay
        yield u
        delay = min(delay * 2, cap)


def _make_placement(problem: Problem, cfg: SimConfig, join_order
                    ) -> Tuple[Placement, int, float]:
    import time as _time

    t0 = _time.perf_counter()
    if cfg.R is not None:
        R = cfg.R
    else:
        # auto rule (after Cor. 3.6): arrivals during an expected session
        rough = 1.5 * problem.workload.l_out  # ~1.5 s/token prior estimate
        R = auto_R(problem, cfg.rate, rough)
    if cfg.algorithm == "petals":
        placement = petals_bp(problem, join_order=join_order)
    elif cfg.algorithm == "proposed":
        placement, _ = cg_bp(problem, R)
    elif cfg.algorithm == "optimized_order":
        placement = optimized_order_bp(problem, R)
    elif cfg.algorithm == "optimized_number":
        placement = optimized_number_bp(problem, R)
    elif cfg.algorithm == "optimized_rr":
        placement = petals_bp(problem, join_order=join_order)
    else:
        raise ValueError(cfg.algorithm)
    dt = _time.perf_counter() - t0
    return placement, R, dt


def _reference_loop(problem: Problem, cfg: SimConfig, placement: Placement,
                    requests: Trace, tl: _Timeline,
                    route_cache: RouteCostCache) -> Tuple[List[Dict], float]:
    """The original per-request admission loop, verbatim — the bit-exact
    twin every fast-path decision is tested against."""
    import time as _time

    rows: List[Dict] = []
    decision_time = 0.0
    lw = problem.workload
    for req in requests:
        t = req.arrival
        t0 = _time.perf_counter()
        wait_est = 0.0
        if cfg.algorithm in ("proposed",):
            route, _, wait_est = ws_rr(problem, placement, req.client,
                                       tl.states_at(t), cache=route_cache)
        elif cfg.algorithm == "optimized_rr":
            waiting = edge_waiting_times(problem, placement, tl.states_at(t))
            route, _ = solve_online_routing(problem, placement, req.client,
                                            waiting)
            if route is None:
                route = petals_route(problem, placement, req.client)
        elif cfg.algorithm in ("optimized_order", "optimized_number"):
            route = petals_route(problem, placement, req.client)
        else:  # petals
            route = petals_route(problem, placement, req.client)
        decision_time += _time.perf_counter() - t0
        if route is None:
            rows.append(dict(drop=True))
            continue

        prefill = route_prefill_time(problem, route, req.client)
        per_tok = route_per_token_time(problem, route, req.client)
        dur = prefill + (lw.l_out - 1) * per_tok
        earliest = tl.earliest_start(route, t, dur)
        if not np.isfinite(earliest):
            rows.append(dict(drop=True))
            continue
        if cfg.algorithm == "proposed":
            start = earliest
        else:
            # PETALS-style exponential-backoff retry until memory frees
            start = np.inf
            for u in _backoff_attempts(t, horizon=earliest - t + 130.0,
                                       cap=cfg.backoff_max):
                if u >= earliest and tl.fits(route, u, dur):
                    start = u
                    break
            if not np.isfinite(start):
                start = earliest
        tl.commit(route, start, dur)
        wait = start - t
        rows.append(dict(
            drop=False, wait=wait, first_token=wait + prefill,
            per_token_rest=per_tok, total=wait + dur,
            per_token_all=(wait + dur) / lw.l_out,
            hops=len(route.servers)))
    return rows, decision_time


def _fast_loop(problem: Problem, cfg: SimConfig, placement: Placement,
               requests: Trace, tl: _Timeline, route_cache: RouteCostCache):
    """Array-native event engine.  Exactness argument, hop by hop:

    * **Retirement heap + usage counters.**  ``used[j]`` tracks the summed
      blocks of committed sessions with ``end > t`` (lazy retirement off a
      global ``(end, j, k)`` heap) — exactly the sessions ``states_at(t)``
      reports, including not-yet-started commitments.

    * **Contention-free routing.**  ``free_j >= zero_wait_kthr[j]`` on
      every server makes the full eq. (20) wait matrix equal the
      empty-system matrix elementwise (``RouteCostCache.zero_wait_kthr``),
      so the reference's per-arrival WS-RR DP (or online MILP) would
      receive numerically identical inputs — its decision is the memoized
      per-client base decision.  Any tight server drops to the slow path,
      which runs the decision on ``states_arrays_at(t)`` (bit-identical
      wait matrices vs the dict view).

    * **Admission.**  ``used[j] + k <= cap[j]`` on every hop implies the
      reference's ``usage_max(j, t, t+dur) + k <= cap[j]`` (usage over any
      window is at most the live total), and since ``t`` is the first
      ``earliest_start`` candidate, ``earliest = t`` and backoff's first
      attempt ``u = t`` succeeds — ``start = t`` on both paths.  Otherwise
      the exact (pruned) ``earliest_start``/``fits`` probes run.

    Requires nondecreasing arrivals (needed for frontier pruning and lazy
    retirement); returns None to fall back to the reference loop if the
    trace is unsorted.
    """
    import time as _time

    if isinstance(requests, RequestBatch):
        arr_t, arr_c = requests.arrival, requests.client
    else:
        arr_t = np.asarray([r.arrival for r in requests], float)
        arr_c = np.asarray([r.client for r in requests], np.int64)
    N = int(len(arr_t))
    if N and bool(np.any(np.diff(arr_t) < 0)):
        return None

    t_loop = _time.perf_counter()
    alg = cfg.algorithm
    l_out = problem.workload.l_out
    l_out_m1 = l_out - 1
    n = problem.n_servers
    cap = tl.cap.tolist()
    slots = route_cache.total_slots.tolist()
    kthr = route_cache.zero_wait_kthr.tolist()
    # state-oblivious algorithms never re-decide under contention
    state_free = alg not in ("proposed", "optimized_rr")
    used = [0] * n
    tight = [False] * n
    n_tight = 0
    heap: List[Tuple[float, int, int]] = []
    heappush, heappop = heapq.heappush, heapq.heappop
    inf = np.inf
    isfinite = np.isfinite

    # memoized per-client base decisions and per-(client, route) timings;
    # False marks a memoized drop (no feasible route)
    base_dec: Dict[int, object] = {}
    route_info: Dict[Tuple[int, Tuple[int, ...]], tuple] = {}

    def _route_info(c: int, route: Route):
        key = (c, route.servers)
        info = route_info.get(key)
        if info is None:
            prefill, per_tok = route_cache.route_times(c, route)
            dur = prefill + l_out_m1 * per_tok
            info = (route, list(zip(route.servers, route.blocks)),
                    prefill, per_tok, dur, len(route.servers))
            route_info[key] = info
        return info

    def _base_decision(c: int):
        info = base_dec.get(c)
        if info is None:
            if alg == "proposed":
                route, _ = route_cache.base_ws_rr(c)
            elif alg == "optimized_rr":
                route, _ = solve_online_routing(
                    problem, placement, c, route_cache.empty_waiting())
                if route is None:
                    route = route_cache.petals(c)
            else:
                route = route_cache.petals(c)
            info = _route_info(c, route) if route is not None else False
            base_dec[c] = info
        return info

    collect = cfg.collect_rows
    rows: Optional[List[Dict]] = [] if collect else None
    if not collect:
        m_wait = np.empty(N)
        m_ft = np.empty(N)
        m_ptr = np.empty(N)
        m_pta = np.empty(N)
    n_ok = 0
    n_fast = 0
    n_slow = 0
    n_drop = 0

    ts = arr_t.tolist()
    cs = arr_c.tolist()
    for i in range(N):
        t = ts[i]
        c = cs[i]
        tl.frontier = t
        while heap and heap[0][0] <= t:
            _, j, k = heappop(heap)
            u = used[j] - k
            used[j] = u
            if tight[j] and slots[j] - u >= kthr[j]:
                tight[j] = False
                n_tight -= 1
        if state_free or n_tight == 0:
            info = _base_decision(c)
            n_fast += 1
        else:
            n_slow += 1
            if alg == "proposed":
                route, _, _ = ws_rr(problem, placement, c,
                                    tl.states_arrays_at(t), cache=route_cache)
            else:  # optimized_rr
                waiting = edge_waiting_times(
                    problem, placement, tl.states_arrays_at(t),
                    cache=route_cache)
                route, _ = solve_online_routing(problem, placement, c,
                                                waiting)
                if route is None:
                    route = route_cache.petals(c)
            info = _route_info(c, route) if route is not None else False
        if info is False:
            n_drop += 1
            if collect:
                rows.append(dict(drop=True))
            continue
        route, hops, prefill, per_tok, dur, n_hops = info
        fits_now = True
        for j, k in hops:
            if used[j] + k > cap[j]:
                fits_now = False
                break
        if fits_now:
            start = t
        else:
            earliest = tl.earliest_start(route, t, dur)
            if not isfinite(earliest):
                n_drop += 1
                if collect:
                    rows.append(dict(drop=True))
                continue
            if alg == "proposed":
                start = earliest
            else:
                start = inf
                for u in _backoff_attempts(t, horizon=earliest - t + 130.0,
                                           cap=cfg.backoff_max):
                    if u >= earliest and tl.fits(route, u, dur):
                        start = u
                        break
                if not isfinite(start):
                    start = earliest
        end = start + dur
        tl.commit(route, start, dur)
        for j, k in hops:
            u = used[j] + k
            used[j] = u
            if not tight[j] and slots[j] - u < kthr[j]:
                tight[j] = True
                n_tight += 1
            heappush(heap, (end, j, k))
        wait = start - t
        if collect:
            rows.append(dict(
                drop=False, wait=wait, first_token=wait + prefill,
                per_token_rest=per_tok, total=wait + dur,
                per_token_all=(wait + dur) / l_out,
                hops=n_hops))
        else:
            m_wait[n_ok] = wait
            m_ft[n_ok] = wait + prefill
            m_ptr[n_ok] = per_tok
            m_pta[n_ok] = (wait + dur) / l_out
        n_ok += 1

    decision_time = _time.perf_counter() - t_loop
    stats = dict(fast_routes=n_fast, slow_routes=n_slow, drops=n_drop,
                 compactions=tl.compactions)
    if collect:
        return rows, None, decision_time, stats
    arrays = (n_ok, N, m_wait, m_ft, m_ptr, m_pta)
    return None, arrays, decision_time, stats


def simulate(problem: Problem, cfg: SimConfig,
             requests: Optional[Trace] = None) -> SimResult:
    if cfg.sim_mode not in SIM_MODES:
        raise ValueError(f"sim_mode must be one of {SIM_MODES}, "
                         f"got {cfg.sim_mode!r}")
    rng = np.random.default_rng(cfg.seed + 1)
    join_order = rng.permutation(problem.n_servers)  # random join (§4.1)
    placement, R, place_time = _make_placement(problem, cfg, join_order)
    if requests is None:
        requests = poisson_requests(cfg.n_requests, cfg.rate,
                                    client=cfg.client, seed=cfg.seed,
                                    n_clients=cfg.n_clients)
    tl = _Timeline(problem, placement)
    # placement is fixed for the whole trace: memoize the routing graph /
    # edge costs / slot capacities across arrivals (same cache the online
    # controller uses)
    route_cache = RouteCostCache(problem, placement)

    out = None
    if cfg.sim_mode == "fast":
        out = _fast_loop(problem, cfg, placement, requests, tl, route_cache)
    fast_stats = None
    arrays = None
    if out is None:  # reference mode, or fast fell back (unsorted trace)
        rows, decision_time = _reference_loop(problem, cfg, placement,
                                              requests, tl, route_cache)
    else:
        rows, arrays, decision_time, fast_stats = out
    decision_time += place_time

    if rows is not None:
        ok = [r for r in rows if not r.get("drop")]
        drop_rate = 1.0 - len(ok) / max(1, len(rows))
        mean = lambda k: float(np.mean([r[k] for r in ok])) if ok else np.inf
        per_token_all = mean("per_token_all")
        first_token = mean("first_token")
        per_token_rest = mean("per_token_rest")
        wait = mean("wait")
    else:
        n_ok, n_total, m_wait, m_ft, m_ptr, m_pta = arrays
        drop_rate = 1.0 - n_ok / max(1, n_total)
        # identical reduction to the rows path: np.mean over the same
        # float sequence (pairwise summation depends only on the values)
        mean = lambda a: float(np.mean(a[:n_ok])) if n_ok else np.inf
        per_token_all = mean(m_pta)
        first_token = mean(m_ft)
        per_token_rest = mean(m_ptr)
        wait = mean(m_wait)
        rows = []
    return SimResult(
        algorithm=cfg.algorithm,
        per_token_all=per_token_all,
        first_token=first_token,
        per_token_rest=per_token_rest,
        wait=wait,
        drop_rate=drop_rate,
        decision_time_s=decision_time / max(1, len(requests)),
        placement=placement,
        requests=rows,
        # the EXECUTED mode: "reference" when fast fell back (unsorted)
        sim_mode="fast" if out is not None else "reference",
        fast_stats=fast_stats,
    )


def run_comparison(problem: Problem, algorithms=("petals", "proposed"),
                   n_requests: int = 100, rate: float = 0.1,
                   seeds=(0, 1, 2, 3, 4), R: Optional[int] = None,
                   n_clients: Optional[int] = None,
                   sim_mode: str = "reference"
                   ) -> Dict[str, Dict[str, float]]:
    """Monte-Carlo comparison (paper: 5 experiment / 20 sim runs).

    Every metric column comes with a ``<metric>_std`` companion — the
    across-seed standard deviation matching the paper's reported
    Monte-Carlo spreads.  ``n_clients`` draws each request's issuing
    client uniformly (multi-client traces in one call); ``sim_mode``
    selects the event engine (results are identical, see ``SimConfig``).
    """
    out = {}
    metric_names = ("per_token_all", "first_token", "per_token_rest",
                    "wait", "decision_time_s", "drop_rate")
    for alg in algorithms:
        metrics = []
        for seed in seeds:
            res = simulate(problem, SimConfig(
                algorithm=alg, n_requests=n_requests, rate=rate, seed=seed,
                R=R, n_clients=n_clients, sim_mode=sim_mode))
            metrics.append(res)
        row: Dict[str, float] = {}
        for name in metric_names:
            vals = [getattr(m, name) for m in metrics]
            row[name] = float(np.mean(vals))
            row[name + "_std"] = float(np.std(vals))
        out[alg] = row
    return out


# ---------------------------------------------------------------------------
# Churn studies: join/leave storms through the online controller
# ---------------------------------------------------------------------------


@dataclass
class ChurnResult:
    """Outcome of :func:`simulate_churn` — fleet-health metrics for the
    join/leave-storm studies (``sim.churn`` in BENCH_engine.json)."""

    n_requests: int
    n_storms: int
    n_replacements: int  # CG-BP re-runs == RouteCostCache invalidations
    drop_rate: float
    wait: float
    per_token_all: float
    alive_min: int  # smallest fleet the controller placed over
    # per-storm recovery metrics (index-aligned with the sorted schedule):
    # time from the storm to the first successfully routed admission after
    # it (inf when the trace ends first), and the controller's in-flight
    # session count at the instant the storm lands
    time_to_reroute: Tuple[float, ...] = ()
    in_flight_at_kill: Tuple[int, ...] = ()


def _problem_with_dead(problem: Problem, dead) -> Problem:
    """Model departed servers as 0-memory hosts: CG-BP then places no
    blocks on them (the same modeling tests/test_routing_online.py uses
    for elastic replacement)."""
    import dataclasses

    servers = [dataclasses.replace(s, mem_bytes=0.0) if j in dead else s
               for j, s in enumerate(problem.servers)]
    return Problem(problem.llm, servers, problem.n_clients,
                   problem.rtt_token, problem.rtt_prefill, problem.workload)


def simulate_churn(problem: Problem, requests: Trace,
                   schedule: Sequence[ChurnEvent], R: Optional[int] = None,
                   reopt_min_interval: float = 0.0) -> ChurnResult:
    """Drive :class:`repro.core.OnlineBPRR` through a request trace while
    ``schedule``'s join/leave storms mutate the fleet.

    Each storm marks the fleet dirty; at the next arrival at least
    ``reopt_min_interval`` after the previous re-optimization, the
    controller re-runs CG-BP over the surviving servers via
    ``replace_servers`` — which REPLACES its ``RouteCostCache``, the
    cache-invalidation path this study exists to exercise (storms landing
    within the cadence window coalesce into one re-placement).  Requests
    the WS-RR DP cannot route on the current placement are drops.
    """
    from repro.core.online import OnlineBPRR

    ctl = OnlineBPRR(problem, R=R)
    events = sorted(schedule, key=lambda ev: ev.time)
    l_out = problem.workload.l_out
    dead: set = set()
    ei = 0
    dirty = False
    last_reopt = -np.inf
    n_repl = 0
    alive_min = problem.n_servers
    n_total = 0
    n_ok = 0
    sum_wait = 0.0
    sum_pta = 0.0
    storm_t: List[float] = []
    storm_inflight: List[int] = []
    reroute: List[float] = []
    rerouted = 0  # storms whose first post-storm success has been seen
    for req in requests:
        t = req.arrival
        n_total += 1
        while ei < len(events) and events[ei].time <= t:
            ev = events[ei]
            ei += 1
            dead.difference_update(ev.join)
            dead.update(ev.leave)
            dirty = True
            ctl.gc(ev.time)
            storm_t.append(ev.time)
            storm_inflight.append(ctl.concurrency())
            reroute.append(np.inf)
        if dirty and t - last_reopt >= reopt_min_interval:
            ctl.replace_servers(_problem_with_dead(problem, dead))
            n_repl += 1
            last_reopt = t
            dirty = False
            alive_min = min(alive_min, problem.n_servers - len(dead))
        ctl.gc(t)
        route, start, end, _ = ctl.admit(req.client, t)
        if route is None or not np.isfinite(start):
            continue
        n_ok += 1
        sum_wait += start - t
        sum_pta += (end - t) / l_out
        while rerouted < len(storm_t):
            reroute[rerouted] = t - storm_t[rerouted]
            rerouted += 1
    return ChurnResult(
        n_requests=n_total,
        n_storms=ei,
        n_replacements=n_repl,
        drop_rate=1.0 - n_ok / max(1, n_total),
        wait=sum_wait / n_ok if n_ok else np.inf,
        per_token_all=sum_pta / n_ok if n_ok else np.inf,
        alive_min=alive_min,
        time_to_reroute=tuple(reroute),
        in_flight_at_kill=tuple(storm_inflight),
    )


# ---------------------------------------------------------------------------
# Chaos studies: fault plans through the analytic reference loop
# ---------------------------------------------------------------------------


@dataclass
class FaultSimResult:
    """Outcome of :func:`simulate_faults` — the analytic twin of the
    engine's chaos accounting (``chaos.recovery`` in BENCH_engine.json)."""

    n_requests: int
    n_served: int
    n_failed: int
    n_detections: int
    n_replays: int
    detect_time: float
    backoff_time: float
    replay_time: float
    fail_reasons: Dict[str, int]
    wait: float
    per_token_all: float

    @property
    def recovery_time(self) -> float:
        """Total billed recovery: detection + backoff + replay."""
        return self.detect_time + self.backoff_time + self.replay_time

    @property
    def goodput(self) -> float:
        return self.n_served / max(1, self.n_requests)


def _problem_with_faults(problem: Problem, dead, slow) -> Problem:
    """Dead servers become 0-memory hosts; stragglers carry scaled taus —
    the same single-carrier slowdown model as the engine's
    ``set_slowdown`` (the problem tau is the one source of truth)."""
    import dataclasses

    servers = []
    for j, s in enumerate(problem.servers):
        if j in dead:
            s = dataclasses.replace(s, mem_bytes=0.0)
        f = slow.get(j)
        if f is not None and f != 1.0:
            s = dataclasses.replace(s, tau=s.tau * f)
        servers.append(s)
    return Problem(problem.llm, servers, problem.n_clients,
                   problem.rtt_token, problem.rtt_prefill, problem.workload)


def subchain_route(problem: Problem, placement: Placement, dead,
                   lo: int, hi: int, client: int) -> Optional[Route]:
    """Min-cost chain of alive servers covering exactly blocks
    ``[lo, hi)`` — the simulator-side mirror of the engine's
    ``GeoServingSystem._subchain`` splice DP (same clipped subproblem,
    same ``shortest_path_route``), used to price failover replay."""
    import dataclasses

    a = np.clip(placement.a, lo, hi)
    end = np.clip(placement.a + placement.m, lo, hi)
    m = np.maximum(end - a, 0)
    m = np.where(placement.m <= 0, 0, m)
    if dead:
        m = m.copy()
        m[np.asarray(sorted(dead), int)] = 0
    sub = Placement(a=a - lo, m=m)
    kw = dict(n_blocks=hi - lo)
    if problem.llm.block_tau is not None:
        kw["block_tau"] = problem.llm.block_tau[lo:hi]
    subproblem = dataclasses.replace(
        problem, llm=dataclasses.replace(problem.llm, **kw))
    route, _ = shortest_path_route(subproblem, sub, client)
    return route


def simulate_faults(problem: Problem, requests: Trace, plan,
                    R: Optional[int] = None, detector=None) -> FaultSimResult:
    """Analytic fault-aware admission loop: drive :class:`OnlineBPRR`
    through a request trace while a :class:`repro.serving.faults.FaultPlan`
    injects crashes, rejoins, stragglers, and dispatch errors — billing
    recovery with the SAME shared pricing the engine uses
    (``FailureDetector.detect_time`` / ``backoff_time`` +
    :func:`recovery_replay_cost` over the :func:`subchain_route` splice).

    Per crash, every in-flight session routed through the victim pays the
    missed deadline (``timeout_factor x`` the eq. (1) expected hop time,
    once per probe), the exponential-backoff sleeps, and the replay of its
    prompt prefill plus generated-so-far tokens on the replacement chain;
    its remaining tokens then run at the spliced route's per-token time.
    Sessions caught mid-prefill fail with ``server_lost_mid_prefill``;
    sessions with no alive replacement chain fail with ``no_route`` —
    every admitted request ends served or failed-with-reason, the same
    conservation law the chaos tests assert on the engine."""
    from repro.core.online import OnlineBPRR
    from repro.serving.faults import FailureDetector, recovery_replay_cost

    det = detector if detector is not None else FailureDetector()
    ctl = OnlineBPRR(problem, R=R)
    lw = problem.workload
    dead: set = set()
    slow: Dict[int, float] = {}
    dispatch_faults: set = set()
    cursor = 0
    live: Dict[int, dict] = {}
    n_total = n_served = n_failed = 0
    n_detections = n_replays = 0
    detect_s = backoff_s = replay_s = 0.0
    fail_reasons: Dict[str, int] = {}
    sum_wait = sum_pta = 0.0

    def _fail(rec: dict, reason: str):
        nonlocal n_failed
        n_failed += 1
        fail_reasons[reason] = fail_reasons.get(reason, 0) + 1
        live.pop(rec["sid"], None)
        ctl.finish(rec["sid"])

    def _retire(now: float):
        nonlocal n_served, sum_wait, sum_pta
        for sid in [sid for sid, r in live.items() if r["end"] <= now]:
            r = live.pop(sid)
            n_served += 1
            sum_wait += r["wait"]
            sum_pta += (r["end"] - r["arrival"]) / lw.l_out

    def _crash(ev):
        nonlocal n_detections, n_replays, detect_s, backoff_s, replay_s
        j = ev.server
        if j in dead:
            return
        _retire(ev.time)
        dead.add(j)
        cur = _problem_with_faults(problem, dead, slow)
        backoff = det.backoff_time()
        for rec in list(live.values()):
            if rec["start"] > ev.time or j not in rec["route"].servers:
                continue
            if ev.time < rec["start"] + rec["prefill"]:
                _fail(rec, "server_lost_mid_prefill")
                continue
            h = rec["route"].servers.index(j)
            lo = int(sum(rec["route"].blocks[:h]))
            hi = lo + int(rec["route"].blocks[h])
            w = problem.llm.tau_weight(lo, hi)
            expected = (problem.rtt_token[rec["client"], j]
                        + w * problem.servers[j].tau * slow.get(j, 1.0))
            repl = subchain_route(cur, ctl.placement, dead, lo, hi,
                                  rec["client"])
            if repl is None:
                _fail(rec, "no_route")
                continue
            n_tok = max(0, min(
                int((ev.time - rec["start"] - rec["prefill"])
                    / max(rec["per_token"], 1e-12)),
                lw.l_out - 1))
            repl_spans = []
            e = lo
            for jj, k in zip(repl.servers, repl.blocks):
                repl_spans.append((jj, e, e + int(k)))
                e += int(k)
            replay = recovery_replay_cost(
                problem, rec["client"], repl_spans, n_tok,
                slowdown_of=lambda jj: slow.get(jj, 1.0))
            detect = det.detect_time(expected)
            spliced = Route(
                servers=tuple(rec["route"].servers[:h]) + tuple(repl.servers)
                + tuple(rec["route"].servers[h + 1:]),
                blocks=tuple(rec["route"].blocks[:h])
                + tuple(int(k) for k in repl.blocks)
                + tuple(rec["route"].blocks[h + 1:]))
            per_tok = route_per_token_time(cur, spliced, rec["client"])
            rec["route"] = spliced
            rec["per_token"] = per_tok
            rec["end"] = (ev.time + detect + backoff + replay
                          + (lw.l_out - 1 - n_tok) * per_tok)
            n_detections += 1
            n_replays += 1
            detect_s += detect
            backoff_s += backoff
            replay_s += replay
        ctl.set_suspicion(j, det.suspicion_penalty)
        ctl.replace_servers(cur, R=ctl.R)

    def _advance(now: float):
        nonlocal cursor
        due, cursor = plan.due(cursor, now)
        for ev in due:
            if ev.kind == "crash":
                _crash(ev)
            elif ev.kind == "rejoin":
                if ev.server in dead:
                    dead.discard(ev.server)
                    ctl.replace_servers(
                        _problem_with_faults(problem, dead, slow), R=ctl.R)
            elif ev.kind == "straggler_start":
                slow[ev.server] = ev.factor
                ctl.replace_servers(
                    _problem_with_faults(problem, dead, slow), R=ctl.R)
            elif ev.kind == "straggler_end":
                if slow.pop(ev.server, None) is not None:
                    ctl.replace_servers(
                        _problem_with_faults(problem, dead, slow), R=ctl.R)
            elif ev.kind == "dispatch_error":
                dispatch_faults.add(ev.server)

    for req in requests:
        t = req.arrival
        n_total += 1
        _advance(t)
        _retire(t)
        ctl.gc(t)
        route, start, end, sid = ctl.admit(req.client, t)
        if route is None or not np.isfinite(start):
            n_failed += 1
            fail_reasons["no_route"] = fail_reasons.get("no_route", 0) + 1
            continue
        faulted = [j for j in route.servers if j in dispatch_faults]
        if faulted:
            dispatch_faults.difference_update(faulted)
            n_failed += 1
            fail_reasons["dispatch_error"] = (
                fail_reasons.get("dispatch_error", 0) + 1)
            ctl.finish(sid)
            continue
        cur = _problem_with_faults(problem, dead, slow)
        prefill = route_prefill_time(cur, route, req.client)
        per_tok = route_per_token_time(cur, route, req.client)
        live[sid] = dict(
            sid=sid, client=req.client, route=route, arrival=t,
            wait=start - t, start=start, prefill=prefill,
            per_token=per_tok,
            end=start + prefill + (lw.l_out - 1) * per_tok)
    _advance(np.inf)
    _retire(np.inf)
    return FaultSimResult(
        n_requests=n_total, n_served=n_served, n_failed=n_failed,
        n_detections=n_detections, n_replays=n_replays,
        detect_time=detect_s, backoff_time=backoff_s, replay_time=replay_s,
        fail_reasons=fail_reasons,
        wait=sum_wait / n_served if n_served else np.inf,
        per_token_all=sum_pta / n_served if n_served else np.inf)
