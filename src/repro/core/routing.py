"""Request routing.

* ``shortest_path_route``  — optimal routing given a feasible placement
  (Lemma 3.4): exact DP over the feasible routing DAG in e_j order.
* ``ws_rr``                — Waiting-penalised Shortest-path Request Routing
  (§3.3.2): link cost  t^W_ij(t) + l_max · t^c_ij  with the waiting time from
  the tracked server state, eq. (20).
* ``petals_route``         — the PETALS client heuristic [16]: Dijkstra over
  (progress, server) states with latency+throughput edge weights, ignoring
  memory/waiting (the paper's key comparison point).
* ``jax_shortest_paths``   — jit-able batched min-plus DP over clients —
  the paper's routing as a composable JAX module (tested == numpy).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.perf_model import (Placement, Problem, Route,
                                   route_per_token_time, route_prefill_time)
from repro.core.topology import RoutingGraph, route_blocks


def edge_cost_matrix(problem: Problem, placement: Placement,
                     client: int, avg_over_tokens: bool = False) -> np.ndarray:
    """cost[i, j] = t^c_ij by eq (4) (or eq (8) if avg_over_tokens), for the
    *maximum* processed blocks k_j = e_j − e_i implied by hop (i, j); the
    S-client row is index n (progress 0)."""
    a, m = placement.a, placement.m
    n = problem.n_servers
    e = a + m
    tau = problem.tau()
    lw = problem.workload
    cost = np.full((n + 1, n), np.inf)
    e_from = np.concatenate([e, [0]])  # progress after i (last row = S)
    cumw = problem.llm.tau_cumweights()  # per-family block weights (W[e])
    for row in range(n + 1):
        # weighted blocks processed at j when reached from row; equals
        # e - e_from[row] under the paper's uniform weights
        k = cumw[e] - cumw[e_from[row]]
        t_tok = problem.rtt_token[client] + tau * k
        if avg_over_tokens:
            t_pre = problem.rtt_prefill[client] + problem.tau_prefill() * k
            c = t_pre / lw.l_out + (lw.l_out - 1) / lw.l_out * t_tok
        else:
            c = t_tok
        cost[row] = c
    return cost


class RouteCostCache:
    """Memoized placement-derived routing inputs, shared across arrivals.

    ``edge_cost_matrix`` and ``RoutingGraph.build`` depend only on
    (problem, placement, client) — yet the online controller used to
    rebuild both on EVERY arriving request.  This cache computes the
    routing graph once, one edge-cost matrix per (client, avg_over_tokens),
    and the eq. (20) slot capacities once, and hands them to
    ``shortest_path_route`` / ``ws_rr`` / ``edge_waiting_times`` via their
    ``cache=`` parameter.  The holder must invalidate by REPLACING the
    cache whenever the placement, the RTT matrices, server capacities or
    τ values change (``OnlineBPRR.replace_servers`` does exactly that);
    per-arrival state (waiting times) is never cached here.

    ``suspicion``: optional ``{server: penalty_seconds}`` map — every edge
    INTO a suspected server carries the additive per-token penalty, so
    WS-RR (and the memoized base decisions) steer routes away from
    flapping servers without forbidding them outright.  The penalty
    biases route SELECTION only; ``route_times`` (the billed eq. (1)
    clock of whatever route is chosen) never includes it.
    """

    def __init__(self, problem: Problem, placement: Placement,
                 suspicion: Optional[Dict[int, float]] = None):
        self.problem = problem
        self.placement = placement
        self.suspicion = dict(suspicion) if suspicion else {}
        self.graph = RoutingGraph.build(placement, problem.L)
        # eq. (20) inputs reused by edge_waiting_times on every arrival
        m = placement.m
        self.total_slots = np.floor((problem.mem() - problem.s_m * m)
                                    / problem.s_c)
        self._cost: Dict[Tuple[int, bool], np.ndarray] = {}
        self._route_times: Dict[Tuple[int, Tuple[int, ...]],
                                Tuple[float, float]] = {}
        self._w0: Optional[np.ndarray] = None
        self._kthr: Optional[np.ndarray] = None
        self._base_ws_rr: Optional[List[Tuple[Optional[Route], float]]] = None
        self._petals: Dict[int, Optional[Route]] = {}

    def cost(self, client: int, avg_over_tokens: bool = False) -> np.ndarray:
        key = (int(client), bool(avg_over_tokens))
        if key not in self._cost:
            c = edge_cost_matrix(
                self.problem, self.placement, client, avg_over_tokens)
            for j, pen in self.suspicion.items():
                if 0 <= int(j) < c.shape[1]:
                    c[:, int(j)] += float(pen)
            self._cost[key] = c
        return self._cost[key]

    def route_times(self, client: int, route: Route) -> Tuple[float, float]:
        """(prefill, per_token) for ``route`` — eq. (1) terms, which depend
        only on (problem, route, client), never on the arrival time."""
        key = (int(client), route.servers)
        hit = self._route_times.get(key)
        if hit is None:
            hit = (route_prefill_time(self.problem, route, client),
                   route_per_token_time(self.problem, route, client))
            self._route_times[key] = hit
        return hit

    def empty_waiting(self) -> np.ndarray:
        """The eq. (20) wait matrix of the EMPTY system: entries are 0 where
        k_j = e_j − e_i fits in server j's total slots and inf where the hop
        can never fit (so those edges stay forbidden at any load)."""
        if self._w0 is None:
            self._w0 = edge_waiting_times(
                self.problem, self.placement, {}, cache=self)
        return self._w0

    @property
    def zero_wait_kthr(self) -> np.ndarray:
        """Per-server free-slot threshold for the contention-free fast path:
        while ``free_j >= zero_wait_kthr[j]`` on EVERY server, the full
        eq. (20) wait matrix equals :meth:`empty_waiting` elementwise
        (finite-capacity entries need ``free >= k_needed`` to stay at 0;
        entries with ``k_needed > total_slots`` are inf at any load)."""
        if self._kthr is None:
            a, m = self.placement.a, self.placement.m
            e = a + m
            e_from = np.concatenate([e, [0]])
            k_needed = e[None, :] - e_from[:, None]  # (n+1, n)
            relevant = ((k_needed > 0) & (k_needed <= self.total_slots[None, :])
                        & (m > 0)[None, :])
            self._kthr = np.where(relevant.any(axis=0),
                                  np.where(relevant, k_needed, 0).max(axis=0),
                                  0).astype(float)
        return self._kthr

    def base_ws_rr(self, client: int) -> Tuple[Optional[Route], float]:
        """WS-RR decision of the EMPTY system for ``client`` — exactly what
        :func:`ws_rr` returns whenever the wait matrix equals
        :meth:`empty_waiting`.  All clients' DPs are batched in one
        vectorized pass (same order / tie-breaks as ``_dag_shortest``)."""
        if self._base_ws_rr is None:
            w0 = self.empty_waiting()
            lmax = float(self.problem.workload.l_out)
            costs = np.stack([w0 + lmax * self.cost(c)
                              for c in range(self.problem.n_clients)])
            dist, parent = _dag_shortest_batch(self.graph, costs)
            self._base_ws_rr = [
                _extract_route(self.graph, self.problem, self.placement,
                               dist[c], parent[c])
                for c in range(self.problem.n_clients)]
        return self._base_ws_rr[int(client)]

    def petals(self, client: int) -> Optional[Route]:
        """Memoized :func:`petals_route` — arrival-invariant by construction
        (no waiting/memory terms in the PETALS heuristic)."""
        c = int(client)
        if c not in self._petals:
            self._petals[c] = petals_route(self.problem, self.placement, c)
        return self._petals[c]


def _dag_shortest(graph: RoutingGraph, cost: np.ndarray,
                  extra: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """DP over servers in e_j order.  cost has S-client at row n.

    extra[j]: additive per-node cost (e.g. waiting penalties folded in by the
    caller through ``cost`` directly; kept for clarity).  Returns
    (dist, parent) with parent = n for S-client predecessor.
    """
    a, m = graph.placement.a, graph.placement.m
    n = len(a)
    e = a + m
    dist = np.full(n, np.inf)
    parent = np.full(n, -100, int)
    first = set(graph.first.tolist())
    for j in graph.order:
        if m[j] <= 0:
            continue
        if j in first:
            d = cost[n, j]
            if d < dist[j]:
                dist[j] = d
                parent[j] = n
        # predecessors i with a_j <= e_i <= e_j - 1
        ok = (m > 0) & (a[j] <= e) & (e <= e[j] - 1) & np.isfinite(dist)
        if ok.any():
            cand = dist[ok] + cost[np.where(ok)[0], j]
            b = int(np.argmin(cand))
            if cand[b] < dist[j]:
                dist[j] = cand[b]
                parent[j] = int(np.where(ok)[0][b])
    return dist, parent


def _dag_shortest_batch(graph: RoutingGraph, costs: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """``_dag_shortest`` vectorized over a leading batch axis (one cost
    matrix per client): same e_j relaxation order, same first-min
    tie-breaks, so per-client results are exactly the scalar DP's."""
    a, m = graph.placement.a, graph.placement.m
    n = len(a)
    e = a + m
    nb = costs.shape[0]
    dist = np.full((nb, n), np.inf)
    parent = np.full((nb, n), -100, int)
    first = set(graph.first.tolist())
    rows = np.arange(nb)
    for j in graph.order:
        j = int(j)
        if m[j] <= 0:
            continue
        if j in first:
            d = costs[:, n, j]
            upd = d < dist[:, j]
            dist[upd, j] = d[upd]
            parent[upd, j] = n
        ok = (m > 0) & (a[j] <= e) & (e <= e[j] - 1)
        if ok.any():
            # non-ok / unreachable predecessors masked to inf: argmin then
            # picks the first (lowest-index) minimum exactly like the
            # scalar DP's subset argmin
            cand = np.where(ok[None, :] & np.isfinite(dist),
                            dist + costs[:, :n, j], np.inf)
            b = np.argmin(cand, axis=1)
            cb = cand[rows, b]
            upd = cb < dist[:, j]
            dist[upd, j] = cb[upd]
            parent[upd, j] = b[upd]
    return dist, parent


def _extract_route(graph: RoutingGraph, problem: Problem,
                   placement: Placement, dist: np.ndarray, parent: np.ndarray
                   ) -> Tuple[Optional[Route], float]:
    """Walk the DP parents back from the best terminal server (shared by the
    scalar and batched DPs so route extraction tie-breaks identically)."""
    if len(graph.last) == 0:
        return None, np.inf
    lasts = graph.last[np.isfinite(dist[graph.last])]
    if len(lasts) == 0:
        return None, np.inf
    end = int(lasts[np.argmin(dist[lasts])])
    chain = [end]
    while parent[chain[-1]] != problem.n_servers:
        chain.append(int(parent[chain[-1]]))
        if len(chain) > problem.n_servers + 1:
            return None, np.inf
    chain.reverse()
    return route_blocks(placement, tuple(chain)), float(dist[end])


def shortest_path_route(problem: Problem, placement: Placement, client: int,
                        avg_over_tokens: bool = False,
                        waiting: Optional[np.ndarray] = None,
                        l_max_weight: float = 1.0,
                        cache: Optional[RouteCostCache] = None
                        ) -> Tuple[Optional[Route], float]:
    """Optimal feasible route for ``client`` (Lemma 3.4).

    ``waiting``: optional (n+1, n) per-edge waiting times t^W_ij(t) — when
    given, edge cost becomes  t^W_ij + l_max_weight * t^c_ij  (WS-RR).
    ``cache``: optional :class:`RouteCostCache` for the SAME (problem,
    placement) — skips rebuilding the routing graph and edge-cost matrix
    per call (the online-controller fast path).
    Returns (route, path_cost); (None, inf) if no feasible chain exists.
    """
    if cache is not None:
        graph, cost = cache.graph, cache.cost(client, avg_over_tokens)
    else:
        graph = RoutingGraph.build(placement, problem.L)
        cost = edge_cost_matrix(problem, placement, client, avg_over_tokens)
    if waiting is not None:
        cost = waiting + l_max_weight * cost
    dist, parent = _dag_shortest(graph, cost)
    return _extract_route(graph, problem, placement, dist, parent)


# ---------------------------------------------------------------------------
# WS-RR: waiting times from server state, eq. (20)
# ---------------------------------------------------------------------------


@dataclass
class ServerState:
    """Active sessions at one server: (remaining_time, cache_blocks)."""

    remaining: List[float]
    blocks: List[int]

    def sorted_pairs(self):
        pairs = sorted(zip(self.remaining, self.blocks))
        return pairs


class ServerStateArrays:
    """Array-backed eq. (20) state: per-server ``remaining``/``blocks``
    numpy pairs that :func:`edge_waiting_times` / :func:`ws_rr` consume
    directly — the SoA twin of ``Dict[int, ServerState]`` for callers
    (the fast simulator loop, ``OnlineBPRR``) that already hold session
    state in arrays and should not rebuild Python dicts per arrival."""

    __slots__ = ("n_servers", "remaining", "blocks")

    def __init__(self, n_servers: int):
        self.n_servers = int(n_servers)
        self.remaining: List[Optional[np.ndarray]] = [None] * self.n_servers
        self.blocks: List[Optional[np.ndarray]] = [None] * self.n_servers

    def set(self, j: int, remaining: np.ndarray, blocks: np.ndarray):
        self.remaining[j] = remaining
        self.blocks[j] = blocks

    @staticmethod
    def from_states(states: Dict[int, ServerState],
                    n_servers: int) -> "ServerStateArrays":
        out = ServerStateArrays(n_servers)
        for j, st in states.items():
            if st.remaining:
                out.set(j, np.asarray(st.remaining, float),
                        np.asarray(st.blocks, np.int64))
        return out

    def to_states(self) -> Dict[int, ServerState]:
        return {j: ServerState(self.remaining[j].tolist(),
                               self.blocks[j].tolist())
                for j in range(self.n_servers)
                if self.remaining[j] is not None and len(self.remaining[j])}


def _waits_for_server(rem: Optional[np.ndarray], blk: Optional[np.ndarray],
                      slots_j: float, k_needed: np.ndarray) -> np.ndarray:
    """Vectorized eq. (20) column for one server: wait until ``k_needed``
    slots free, for every progress row at once.

    Exactness vs the dict branch: ``lexsort((blk, rem))`` reproduces
    Python's ``sorted(zip(remaining, blocks))`` order on (remaining, then
    blocks); the running free-slot totals are the same sequential sums
    (slot counts are exact small integers in float64); and
    ``searchsorted(frees, k, side="left")`` is exactly "first fk >= k"
    because ``frees`` is nondecreasing (blocks >= 0)."""
    if rem is None or len(rem) == 0:
        return np.where(k_needed <= slots_j, 0.0, np.inf)
    order = np.lexsort((blk, rem))
    rs = rem[order]
    bs = blk[order]
    free0 = slots_j - float(bs.sum())
    frees = np.concatenate([[free0], free0 + np.cumsum(bs)])
    times = np.concatenate([[0.0], rs])
    idx = np.searchsorted(frees, k_needed, side="left")
    return np.where(idx < len(frees),
                    times[np.minimum(idx, len(frees) - 1)], np.inf)


def edge_waiting_times(problem: Problem, placement: Placement,
                       states: Union[Dict[int, ServerState],
                                     ServerStateArrays],
                       cache: Optional[RouteCostCache] = None) -> np.ndarray:
    """t^W_ij(t) per eq (20) for every (i, j): time until server j frees
    enough cache slots for k_j = e_j − e_i new blocks.  ``cache`` reuses
    the precomputed slot capacities (the per-arrival state lives in
    ``states``, never in the cache).  ``states`` may be the classic
    ``Dict[int, ServerState]`` or a :class:`ServerStateArrays`; both
    produce bit-identical matrices (tests/test_simulator.py)."""
    a, m = placement.a, placement.m
    n = problem.n_servers
    e = a + m
    e_from = np.concatenate([e, [0]])
    total_slots = cache.total_slots if cache is not None else np.floor(
        (problem.mem() - problem.s_m * m)
        / problem.s_c)  # ⌊(M_j − s_m m_j)/s_c⌋
    wait = np.zeros((n + 1, n))
    if isinstance(states, ServerStateArrays):
        for j in range(n):
            if m[j] <= 0:
                continue
            wait[:, j] = _waits_for_server(
                states.remaining[j], states.blocks[j],
                total_slots[j], e[j] - e_from)
        return wait
    for j in range(n):
        if m[j] <= 0:
            continue
        st = states.get(j)
        pairs = st.sorted_pairs() if st else []
        used = float(sum(b for _, b in pairs))
        # free_after[k] = slots free once the k shortest-remaining sessions end
        free0 = total_slots[j] - used
        frees = [free0]
        for rem, blk in pairs:
            frees.append(frees[-1] + blk)
        times = [0.0] + [rem for rem, _ in pairs]
        for row in range(n + 1):
            k_needed = e[j] - e_from[row]
            w = np.inf
            for fk, tk in zip(frees, times):
                if fk >= k_needed:
                    w = tk
                    break
            wait[row, j] = w
    return wait


def ws_rr(problem: Problem, placement: Placement, client: int,
          states: Dict[int, ServerState],
          cache: Optional[RouteCostCache] = None
          ) -> Tuple[Optional[Route], float, float]:
    """Waiting-penalised shortest path (Alg. 2).  Returns
    (route, path_cost, waiting_time) where waiting_time = max hop wait.
    ``cache``: optional :class:`RouteCostCache` reusing the routing graph,
    edge costs and slot capacities across arrivals."""
    wait = edge_waiting_times(problem, placement, states, cache=cache)
    route, cost = shortest_path_route(
        problem, placement, client, avg_over_tokens=False, waiting=wait,
        l_max_weight=float(problem.workload.l_out), cache=cache)
    if route is None:
        return None, np.inf, np.inf
    # actual waiting for this route = max over hops (Cor. 3.7: the session
    # starts once every server on the path has freed enough cache slots)
    w = 0.0
    prev_row = problem.n_servers  # S-client row of the wait matrix
    for j in route.servers:
        w = max(w, wait[prev_row, j])
        prev_row = j
    return route, cost, float(w)


# ---------------------------------------------------------------------------
# PETALS routing heuristic [16]
# ---------------------------------------------------------------------------


def petals_route(problem: Problem, placement: Placement, client: int
                 ) -> Optional[Route]:
    """Dijkstra over (progress e, server) states with heuristic weights:
    edge weight = rtt_cj + k_j · τ_j   (latency + compute throughput), no
    memory/waiting modelling — per [16]'s routing."""
    a, m = placement.a, placement.m
    n = problem.n_servers
    e_arr = a + m
    tau = problem.tau()
    cumw = problem.llm.tau_cumweights()
    L = problem.L
    # Dijkstra over progress states
    best: Dict[int, float] = {0: 0.0}
    parent: Dict[Tuple[int, int], Tuple[int, int]] = {}
    pq = [(0.0, 0, -1)]  # (cost, progress, server reaching it)
    seen = set()
    while pq:
        d, e, i = heapq.heappop(pq)
        if (e, i) in seen:
            continue
        seen.add((e, i))
        if e == L:
            chain = []
            cur = (e, i)
            while cur[1] != -1:
                chain.append(cur[1])
                cur = parent[cur]
            chain.reverse()
            return route_blocks(placement, tuple(chain))
        ok = (m > 0) & (a <= e) & (e <= e_arr - 1)
        for j in np.where(ok)[0]:
            k = cumw[e_arr[j]] - cumw[e]
            nd = d + problem.rtt_token[client, j] + k * tau[j]
            state = (int(e_arr[j]), int(j))
            if state not in seen and nd < best.get(state, np.inf):
                best[state] = nd
                parent[state] = (e, i)
                heapq.heappush(pq, (nd, int(e_arr[j]), int(j)))
    return None


# ---------------------------------------------------------------------------
# JAX batched routing (composable module; == numpy DP, tested)
# ---------------------------------------------------------------------------


def jax_shortest_paths(problem: Problem, placement: Placement,
                       waiting: Optional[np.ndarray] = None,
                       l_max_weight: float = 1.0):
    """Min-plus DP for ALL clients at once, jit-compiled.

    Returns (dist (C,), choice (C,)): best completion cost and best terminal
    server per client.  Used by the online scheduler for fleet-wide routing
    decisions at the fast time scale.
    """
    import jax
    import jax.numpy as jnp

    a, m = placement.a, placement.m
    n = problem.n_servers
    e = a + m
    active = m > 0
    adj = (active[None, :] & active[:, None]
           & (a[None, :] <= e[:, None]) & (e[:, None] <= e[None, :] - 1))
    cumw = problem.llm.tau_cumweights()
    # weighted blocks at j from i (== block count under uniform weights)
    k_edge = np.maximum(cumw[e][None, :] - cumw[e][:, None], 0)
    k_first = cumw[e]  # from S-client (progress 0)
    first_ok = active & (a == 0)
    last_ok = active & (e == problem.L)
    tau = problem.tau()

    rtt = jnp.asarray(problem.rtt_token)  # (C, n)
    adj_j = jnp.asarray(adj)
    k_j = jnp.asarray(k_edge, jnp.float32)
    wait_j = (jnp.asarray(waiting[:n, :]) if waiting is not None
              else jnp.zeros((n, n)))
    wait_s = (jnp.asarray(waiting[n, :]) if waiting is not None
              else jnp.zeros((n,)))

    @jax.jit
    def run(rtt):
        # edge costs per client: (C, n, n)
        cost = l_max_weight * (rtt[:, None, :] + tau[None, None, :] * k_j)
        cost = cost + wait_j[None]
        cost = jnp.where(adj_j[None], cost, jnp.inf)
        start = l_max_weight * (rtt + tau[None, :] * k_first) + wait_s[None]
        dist = jnp.where(jnp.asarray(first_ok)[None, :], start, jnp.inf)

        def body(i, dist):
            relaxed = jnp.min(dist[:, :, None] + cost, axis=1)
            return jnp.minimum(dist, relaxed)

        dist = jax.lax.fori_loop(0, n, body, dist)
        dist = jnp.where(jnp.asarray(last_ok)[None, :], dist, jnp.inf)
        best = jnp.min(dist, axis=1)
        choice = jnp.argmin(dist, axis=1)
        return best, choice

    return run(rtt)
