"""The paper's primary contribution: joint Block Placement and Request
Routing (BPRR) for geographically-distributed pipeline-parallel LLM
inference — performance models, CG-BPRR, the online two-time-scale
controller, MILP reference solvers, and performance bounds."""
from repro.core.bounds import (approximation_ratio, cg_upper_bound,
                               lower_bound)
from repro.core.online import OnlineBPRR, Session
from repro.core.perf_model import (BLOOM_PETALS, GB, MB, LLMSpec, Placement,
                                   Problem, Route, ServerSpec, Workload,
                                   route_avg_per_token_time,
                                   route_per_token_time, route_prefill_time,
                                   route_total_time, server_memory_use,
                                   with_server_taus)
from repro.core.placement import (auto_R, capacity, cg_bp, cg_feasible_R,
                                  conservative_m, max_feasible_R,
                                  optimized_number_bp, optimized_order_bp,
                                  petals_bp, petals_m)
from repro.core.routing import (RouteCostCache, ServerState,
                                ServerStateArrays, edge_waiting_times,
                                jax_shortest_paths, petals_route,
                                shortest_path_route, ws_rr)
from repro.core.topology import (RoutingGraph, edge_feasible, route_blocks,
                                 route_feasible)

__all__ = [
    "BLOOM_PETALS", "GB", "MB", "LLMSpec", "OnlineBPRR", "Placement",
    "Problem", "Route", "RouteCostCache", "RoutingGraph", "ServerSpec",
    "ServerState", "ServerStateArrays",
    "Session", "Workload", "approximation_ratio", "auto_R", "capacity",
    "cg_bp", "cg_feasible_R", "cg_upper_bound", "conservative_m",
    "edge_feasible", "edge_waiting_times", "jax_shortest_paths",
    "lower_bound", "max_feasible_R", "optimized_number_bp",
    "optimized_order_bp", "petals_bp", "petals_m", "petals_route",
    "route_avg_per_token_time", "route_blocks", "route_feasible",
    "route_per_token_time", "route_prefill_time", "route_total_time",
    "server_memory_use", "shortest_path_route", "with_server_taus",
    "ws_rr",
]
