"""Logical routing topology G (paper §3.1, Fig. 4) and Lemma 3.1 feasibility.

Nodes: S-client (one per routing query), servers, D-client.  Internally we
track per-node "progress" e = #blocks served after visiting the node
(0-based): S-client e=0; server j has hosted range [a_j, a_j+m_j); edge
i→j is feasible  ⟺  a_j ≤ e_i ≤ a_j + m_j − 1  (Lemma 3.1), after which
e_j = a_j + m_j (the first server hosting a block processes it, §3.1).
D-client requires e = L.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.perf_model import Placement, Problem, Route

S_NODE = -1  # virtual S-client node id
D_NODE = -2  # virtual D-client node id


def edge_feasible(a: np.ndarray, m: np.ndarray, e_i: int, j: int) -> bool:
    """Lemma 3.1: can a session with progress e_i continue at server j?"""
    return bool(m[j] > 0 and a[j] <= e_i <= a[j] + m[j] - 1)


def route_feasible(placement: Placement, L: int,
                   servers: Tuple[int, ...]) -> bool:
    """Check a full chain via Lemma 3.1 (induction in the paper's proof)."""
    a, m = placement.a, placement.m
    e = 0
    for j in servers:
        if not edge_feasible(a, m, e, j):
            return False
        e = a[j] + m[j]
    return e == L


def route_blocks(placement: Placement, servers: Tuple[int, ...]) -> Route:
    """k_j per hop for a feasible chain (max(a_j, e_i) .. a_j+m_j)."""
    a, m = placement.a, placement.m
    e = 0
    ks = []
    for j in servers:
        e_new = a[j] + m[j]
        ks.append(int(e_new - e))
        e = e_new
    return Route(servers=tuple(servers), blocks=tuple(ks))


@dataclass
class RoutingGraph:
    """Feasible routing DAG for one placement (shared across clients).

    Nodes 0..S-1 are servers; S_NODE/D_NODE virtual.  Topological order is
    by end-progress e_j = a_j + m_j (strictly increases along feasible
    edges).  ``succ[j]`` lists feasible successor servers of j.
    """

    placement: Placement
    L: int
    order: np.ndarray  # server ids sorted by e_j
    first: np.ndarray  # servers reachable from S (host block 0)
    last: np.ndarray  # servers that can end a chain (e_j == L)
    succ: List[np.ndarray]

    @staticmethod
    def build(placement: Placement, L: int) -> "RoutingGraph":
        a, m = placement.a, placement.m
        n = len(a)
        e = a + m
        active = m > 0
        first = np.where(active & (a == 0))[0]
        last = np.where(active & (e == L))[0]
        succ = []
        for i in range(n):
            if not active[i]:
                succ.append(np.empty(0, int))
                continue
            ok = active & (a <= e[i]) & (e[i] <= e - 1)
            succ.append(np.where(ok)[0])
        order = np.argsort(e, kind="stable")
        return RoutingGraph(placement, L, order, first, last, succ)
