"""Experimentally-validated performance models from §2.2 of the paper.

* inference-time model, eq. (1)/(4)/(8):
    per-token time at server j reached from i for client c:
        t_ij^c = t_cj + τ_j · (e_j − e_i)        (decoding phase)
    first-token analogue uses per-input RTT and per-block prefill time.
* memory-consumption model, eq. (2)/(5):
    server j hosting m_j blocks and processing k_j^r blocks per session r:
        s_m·m_j + s_c·Σ_r k_j^r  ≤  M_j
  with  s_c = 2·d_model·(l_in + l_out)·dtype_bytes  per block per session.

``LLMSpec.from_model_config`` bridges the paper's abstract model to every
assigned architecture (MLA latent caches, SSM O(1) states, sliding-window
caches — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

GB = 1 << 30
MB = 1 << 20


# ---------------------------------------------------------------------------
# Model / workload specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LLMSpec:
    """The served model, reduced to what BPRR needs.

    ``block_tau``: optional per-block relative compute weights (length
    ``n_blocks``).  The paper's eq. (1)/(4) charge a uniform ``k_j·τ_j`` per
    hop; heterogeneous stacks (zamba2 hybrids, enc-dec) have per-FAMILY block
    costs, so a hop's compute term becomes ``τ_j · Σ_{b∈hop} w_b``.  ``None``
    keeps the paper's uniform weights (``w_b ≡ 1``).
    """

    name: str
    n_blocks: int  # L
    block_bytes: float  # s_m
    cache_bytes_per_token: float  # per block per session per token
    cache_bytes_const: float = 0.0  # O(1)-state archs (SSM): per block/session
    block_tau: Optional[Tuple[float, ...]] = None  # per-block tau weights

    def __post_init__(self):
        if self.block_tau is not None:
            object.__setattr__(self, "block_tau",
                               tuple(float(w) for w in self.block_tau))
            if len(self.block_tau) != self.n_blocks:
                raise ValueError(
                    f"block_tau has {len(self.block_tau)} weights for "
                    f"{self.n_blocks} blocks")

    def cache_bytes(self, total_tokens: int) -> float:
        """s_c for a session of l_in + l_out = total_tokens."""
        return self.cache_bytes_per_token * total_tokens + self.cache_bytes_const

    def tau_weight(self, lo: int, hi: int) -> float:
        """Σ_{b∈[lo,hi)} w_b — the weighted block count of one hop."""
        if self.block_tau is None:
            return float(hi - lo)
        return float(sum(self.block_tau[lo:hi]))

    def tau_cumweights(self) -> np.ndarray:
        """Prefix sums W with W[e] = Σ_{b<e} w_b, so a hop (e_i → e_j) costs
        ``τ_j · (W[e_j] − W[e_i])`` — the vectorised form the routing DPs
        use."""
        if self.block_tau is None:
            return np.arange(self.n_blocks + 1, dtype=float)
        return np.concatenate([[0.0], np.cumsum(self.block_tau)])

    @staticmethod
    def from_model_config(cfg, dtype_bits: int = 16) -> "LLMSpec":
        """Derive (L, s_m, s_c) from a repro.configs ModelConfig."""
        dtype_bytes = dtype_bits / 8.0
        block_bytes = cfg.block_param_count() * dtype_bytes
        per_tok = 0.0
        const = 0.0
        if cfg.attn_kind == "mla":
            per_tok = (cfg.kv_lora_rank + cfg.rope_head_dim) * 2.0  # bf16 latent
        elif cfg.attn_kind == "gqa" and cfg.n_kv_heads > 0:
            per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2.0
            if cfg.sliding_window and cfg.local_global_period:
                # only 1-in-period layers hold unbounded caches; local layers
                # are window-bounded -> fold into the constant term
                frac_global = 1.0 / cfg.local_global_period
                const = (per_tok * cfg.sliding_window
                         * (1 - frac_global))
                per_tok = per_tok * frac_global
        if cfg.family in ("ssm", "hybrid"):
            # O(1) recurrent state per block per session
            if cfg.family == "ssm":
                h, hd = cfg.ssm_heads, cfg.ssm_head_dim
                const = (h * hd * hd + 2 * cfg.d_model) * 4.0
                per_tok = 0.0
            else:  # zamba2: mamba state + shared-attn KV every Nth block
                h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
                const = (h * p * n + (cfg.conv_width - 1)
                         * (cfg.d_inner + 2 * n)) * 4.0
                per_tok = (2 * cfg.n_kv_heads * cfg.head_dim * 2.0
                           / max(1, cfg.shared_attn_period))
        return LLMSpec(name=cfg.name, n_blocks=cfg.n_layers,
                       block_bytes=block_bytes,
                       cache_bytes_per_token=per_tok,
                       cache_bytes_const=const)


# BLOOM-176B as served by PETALS (NF4-quantised blocks) — the paper's model.
BLOOM_PETALS = LLMSpec(
    name="bloom-176b-nf4",
    n_blocks=70,
    block_bytes=1.4 * GB,
    cache_bytes_per_token=2 * 14336 * 2.0,  # 2 tensors * d_model * bf16
)


@dataclass(frozen=True)
class Workload:
    """Nominal request shape (§4.1): ``l_in`` prompt tokens in,
    ``l_out`` generated tokens out — the lengths the cost and memory
    models are evaluated at."""

    l_in: int = 20
    l_out: int = 128

    @property
    def total_tokens(self) -> int:
        return self.l_in + self.l_out


# ---------------------------------------------------------------------------
# Servers / clients / network
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServerSpec:
    """τ_j, τ_j^I(l) and the effective memory M_j (paper §2.2)."""

    sid: int
    mem_bytes: float  # M_j (effective; overhead already subtracted)
    tau: float  # per-block per-token decode time (s)
    tau_prefill_base: float = 0.0  # τ^I(l) = base + per_token * l
    tau_prefill_per_token: float = 0.0

    def tau_prefill(self, l_in: int) -> float:
        return self.tau_prefill_base + self.tau_prefill_per_token * l_in


@dataclass
class Problem:
    """One BPRR instance: model, servers, clients, network, workload."""

    llm: LLMSpec
    servers: List[ServerSpec]
    n_clients: int
    rtt_token: np.ndarray  # (C, S) per-token RTT t_cj (s)
    rtt_prefill: np.ndarray  # (C, S) per-input RTT t^I_cj(l_in) (s)
    workload: Workload = Workload()

    def __post_init__(self):
        self.rtt_token = np.asarray(self.rtt_token, float)
        self.rtt_prefill = np.asarray(self.rtt_prefill, float)
        assert self.rtt_token.shape == (self.n_clients, len(self.servers))

    @property
    def n_servers(self) -> int:
        return len(self.servers)

    @property
    def L(self) -> int:
        return self.llm.n_blocks

    @property
    def s_m(self) -> float:
        return self.llm.block_bytes

    @property
    def s_c(self) -> float:
        return self.llm.cache_bytes(self.workload.total_tokens)

    def mem(self) -> np.ndarray:
        return np.asarray([s.mem_bytes for s in self.servers])

    def tau(self) -> np.ndarray:
        return np.asarray([s.tau for s in self.servers])

    def tau_prefill(self) -> np.ndarray:
        return np.asarray([s.tau_prefill(self.workload.l_in)
                           for s in self.servers])

    def t_star(self) -> np.ndarray:
        """t_*j = max_c t_cj (worst-case client RTT per server)."""
        return self.rtt_token.max(axis=0)


def with_server_taus(problem: Problem, taus: Dict[int, float]) -> Problem:
    """A copy of ``problem`` with per-server τ replaced for the given sids.

    The calibration entry point for device-group servers: the engine
    measures each server's (sharded) pooled decode step via
    ``launch.costs.tau_from_step_cost`` and this folds the result back into
    the perf model — eq. (1)'s per-token times, eq. (20)'s waiting terms,
    and the placement MILP all read τ from here.  Servers absent from
    ``taus`` keep their spec'd value."""
    servers = [dataclasses.replace(s, tau=float(taus[s.sid]))
               if s.sid in taus else s for s in problem.servers]
    return Problem(problem.llm, servers, problem.n_clients,
                   problem.rtt_token, problem.rtt_prefill, problem.workload)


# ---------------------------------------------------------------------------
# Placement / route containers + the paper's equations
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Placement:
    """Contiguous block ranges: server j hosts blocks [a[j], a[j]+m[j]).

    0-based internally (the paper is 1-based); m[j] == 0 means server unused.
    """

    a: np.ndarray
    m: np.ndarray

    def end(self) -> np.ndarray:
        return self.a + self.m

    def hosts(self, j: int, b: int) -> bool:
        return self.a[j] <= b < self.a[j] + self.m[j]

    def coverage(self, L: int) -> np.ndarray:
        """#servers hosting each block."""
        cov = np.zeros(L, int)
        for aj, mj in zip(self.a, self.m):
            cov[aj: aj + mj] += 1
        return cov

    def feasible_cover(self, L: int) -> bool:
        return bool((self.coverage(L) > 0).all())


@dataclass(frozen=True)
class Route:
    """A server chain with per-hop processed-block counts (Lemma 3.1)."""

    servers: Tuple[int, ...]
    blocks: Tuple[int, ...]  # k_j = e_j - e_i per hop

    def __post_init__(self):
        assert len(self.servers) == len(self.blocks)


def route_per_token_time(problem: Problem, route: Route, client: int) -> float:
    """Σ_{j∈p} (t_cj + k_j τ_j)  — eq (4) summed along the path.

    With per-family block weights (``LLMSpec.block_tau``) the compute term
    is ``τ_j · Σ_{b∈hop} w_b`` instead of ``τ_j · k_j``."""
    t = 0.0
    e = 0
    for j, k in zip(route.servers, route.blocks):
        t += (problem.rtt_token[client, j]
              + problem.llm.tau_weight(e, e + k) * problem.servers[j].tau)
        e += k
    return t


def route_prefill_time(problem: Problem, route: Route, client: int) -> float:
    """Σ_{j∈p} (t^I_cj + k_j τ^I_j)  — first-token part of eq (1), with the
    same per-family block weighting as :func:`route_per_token_time`."""
    t = 0.0
    e = 0
    for j, k in zip(route.servers, route.blocks):
        t += (problem.rtt_prefill[client, j]
              + problem.llm.tau_weight(e, e + k)
              * problem.servers[j].tau_prefill(problem.workload.l_in))
        e += k
    return t


def route_total_time(problem: Problem, route: Route, client: int,
                     l_out: Optional[int] = None) -> float:
    """Total inference time, eq (1)."""
    l_out = problem.workload.l_out if l_out is None else l_out
    return (route_prefill_time(problem, route, client)
            + (l_out - 1) * route_per_token_time(problem, route, client))


def route_avg_per_token_time(problem: Problem, route: Route,
                             client: int) -> float:
    """eq (8): total time amortised over all l_out tokens."""
    return (route_total_time(problem, route, client)
            / problem.workload.l_out)


def server_memory_use(problem: Problem, placement: Placement,
                      blocks_per_session: Dict[int, List[int]]) -> np.ndarray:
    """eq (5): s_m m_j + s_c Σ_sessions k_j."""
    use = problem.s_m * placement.m.astype(float)
    for j, ks in blocks_per_session.items():
        use[j] += problem.s_c * float(sum(ks))
    return use


def route_memory_per_session(problem: Problem, route: Route) -> Dict[int, float]:
    return {j: problem.s_c * k for j, k in zip(route.servers, route.blocks)}
