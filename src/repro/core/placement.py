"""Block placement algorithms.

* ``cg_bp``        — Conservative Greedy Block Placement (Alg. 1 lines 1–8):
                     conservative m_j, greedy ordering by amortised inference
                     time t̃_j = τ_j + t_*j/m_j, need-of-service via (C_b, T_b).
* ``petals_bp``    — the PETALS heuristic [8]/[16]: each joining server takes
                     m_j = ⌊(M_j − reserve)/s_m⌋ blocks and picks the most
                     under-served contiguous span by a throughput metric.
* variants         — 'Optimized Order' / 'Optimized Number' ablations (§4.3).
* ``auto_R``       — the |R| configuration rule after Corollary 3.6 with the
                     feasibility bound (18)/(19).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.perf_model import Placement, Problem


@dataclass
class CGInfo:
    order: np.ndarray  # servers in increasing t̃_j
    t_tilde: np.ndarray
    capacity: np.ndarray  # f̄_j (15)
    K: int  # servers needed to cover all blocks (Thm 3.5)
    feasible: bool


def conservative_m(problem: Problem, R: int) -> np.ndarray:
    """Line 1 of Alg. 1:  m_j = min(⌊M_j/(s_m + s_c·R)⌋, L)."""
    denom = problem.s_m + problem.s_c * R
    return np.minimum(np.floor(problem.mem() / denom), problem.L).astype(int)


def capacity(problem: Problem, m: np.ndarray) -> np.ndarray:
    """f̄_j (15): concurrent sessions guaranteed to fit beside m_j blocks."""
    with np.errstate(divide="ignore", invalid="ignore"):
        cap = np.floor((problem.mem() - problem.s_m * m)
                       / (problem.s_c * np.maximum(m, 1)))
    cap[m == 0] = 0
    return np.maximum(cap, 0).astype(np.int64)


def amortized_time(problem: Problem, m: np.ndarray) -> np.ndarray:
    """t̃_j (14) = τ_j + t_*j / m_j  (inf for unusable servers)."""
    t = np.full(problem.n_servers, np.inf)
    ok = m > 0
    t[ok] = problem.tau()[ok] + problem.t_star()[ok] / m[ok]
    return t


def cg_bp(problem: Problem, R: int) -> Tuple[Placement, CGInfo]:
    """Alg. 1 lines 1–8 (CG-BP)."""
    L = problem.L
    m = conservative_m(problem, R)
    cap = capacity(problem, m)
    t_tilde = amortized_time(problem, m)
    order = np.argsort(t_tilde, kind="stable")

    t0 = (np.nanmax(t_tilde[np.isfinite(t_tilde)]) + 1.0
          if np.isfinite(t_tilde).any() else 1.0)
    C = np.zeros(L, dtype=np.int64)  # C_b: capacity covering block b
    T = np.full(L, t0 * R, dtype=float)  # T_b: total amortised time on b
    a = np.zeros(problem.n_servers, dtype=int)

    K = 0
    covered = False
    for rank, j in enumerate(order):
        mj = int(m[j])
        if mj <= 0:
            continue
        n_starts = L - mj + 1
        if (C < R).any():
            # line 5: contiguous span with max Σ T_b among spans containing
            # at least one under-served block; ties -> smallest start index.
            span_T = np.convolve(T, np.ones(mj), mode="valid")  # Σ over span
            under = (C < R).astype(float)
            has_under = np.convolve(under, np.ones(mj), mode="valid") > 0
            span_T = np.where(has_under, span_T, -np.inf)
            aj = int(np.argmax(span_T))  # argmax returns first max ✓
        else:
            # line 6: span with lexicographically smallest sorted capacities
            best, aj = None, 0
            for s in range(n_starts):
                key = tuple(np.sort(C[s: s + mj]))
                if best is None or key < best:
                    best, aj = key, s
        a[j] = aj
        span = slice(aj, aj + mj)
        fj = int(cap[j])
        T[span] -= (t0 - t_tilde[j]) * np.minimum(
            np.maximum(R - C[span], 0), fj)
        C[span] += fj
        if not covered:
            K = rank + 1
            cov = np.zeros(L, bool)
            for jj in order[: rank + 1]:
                if m[jj] > 0:
                    cov[a[jj]: a[jj] + m[jj]] = True
            covered = bool(cov.all())
    placement = Placement(a=a, m=m)
    feasible = placement.feasible_cover(L)
    info = CGInfo(order=order, t_tilde=t_tilde, capacity=cap,
                  K=K if feasible else -1, feasible=feasible)
    return placement, info


# ---------------------------------------------------------------------------
# |R| configuration (after Corollary 3.6)
# ---------------------------------------------------------------------------


def cg_feasible_R(problem: Problem, R: int) -> bool:
    """Feasibility condition (18)."""
    return int(conservative_m(problem, R).sum()) >= problem.L


def max_feasible_R(problem: Problem) -> int:
    """Upper bound (19) refined by binary search on (18)."""
    hi = int((problem.mem().sum() - problem.s_m
              * (problem.L + problem.n_servers))
             // (problem.s_c * (problem.L + problem.n_servers)))
    hi = max(hi, 0)
    # (19) is sufficient, not tight — extend by doubling then bisect on (18)
    lo = 0
    probe = max(hi, 1)
    while cg_feasible_R(problem, probe):
        lo = probe
        probe *= 2
        if probe > 1 << 24:
            break
    lo_ok, hi_bad = lo, probe
    while lo_ok + 1 < hi_bad:
        mid = (lo_ok + hi_bad) // 2
        if cg_feasible_R(problem, mid):
            lo_ok = mid
        else:
            hi_bad = mid
    return lo_ok


def auto_R(problem: Problem, arrival_rate: float,
           expected_session_s: float) -> int:
    """mean + std of Poisson arrivals during a session, capped by (18)/(19)."""
    mean = arrival_rate * expected_session_s
    target = int(np.ceil(mean + np.sqrt(max(mean, 1e-9))))
    return max(1, min(target, max_feasible_R(problem)))


# ---------------------------------------------------------------------------
# PETALS baseline placement [8]/[16] + ablation variants (§4.3)
# ---------------------------------------------------------------------------


def petals_m(problem: Problem, reserve_fraction: float = 0.05,
             reserve_bytes: float = 1 << 30) -> np.ndarray:
    """PETALS block counts: fixed cache reserve, ignore concurrency."""
    mem = problem.mem()
    usable = mem - reserve_bytes - reserve_fraction * mem
    return np.clip(np.floor(usable / problem.s_m), 0, problem.L).astype(int)


def petals_bp(problem: Problem, join_order: Optional[Sequence[int]] = None,
              m: Optional[np.ndarray] = None) -> Placement:
    """Sequential joins; each server takes the most under-served span as
    measured by per-block total throughput (1/τ_j per hosting server)."""
    L = problem.L
    m = petals_m(problem) if m is None else m
    order = (np.arange(problem.n_servers) if join_order is None
             else np.asarray(join_order))
    thr = 1.0 / np.maximum(problem.tau(), 1e-9)  # tokens/s per block
    block_thr = np.zeros(L)
    a = np.zeros(problem.n_servers, int)
    for j in order:
        mj = int(m[j])
        if mj <= 0:
            continue
        # lexicographically smallest sorted throughput tuple = weakest span
        best, aj = None, 0
        for s in range(L - mj + 1):
            key = tuple(np.sort(block_thr[s: s + mj]))
            if best is None or key < best:
                best, aj = key, s
        a[j] = aj
        block_thr[aj: aj + mj] += thr[j]
    return Placement(a=a, m=m)


def optimized_order_bp(problem: Problem, R: int) -> Placement:
    """'Optimized Order': PETALS placement, servers joining in CG speed order."""
    m = conservative_m(problem, R)
    t_tilde = amortized_time(problem, m)
    order = np.argsort(t_tilde, kind="stable")
    return petals_bp(problem, join_order=order, m=petals_m(problem))


def optimized_number_bp(problem: Problem, R: int) -> Placement:
    """'Optimized Number': PETALS span choice with CG's conservative m_j."""
    return petals_bp(problem, m=conservative_m(problem, R))
