"""Performance bounds: Theorem 3.5 upper bound (17), Lemma B.1 lower bound
(35), and the resulting CG-BPRR approximation ratio (B.4)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.perf_model import Problem
from repro.core.placement import amortized_time, conservative_m


def cg_upper_bound(problem: Problem, R: int) -> float:
    """(17):  T^g ≤ Σ_{j≤K} t̃_j m_j − τ_K (Σ_{j≤K} m_j − L)."""
    m = conservative_m(problem, R)
    t_tilde = amortized_time(problem, m)
    order = np.argsort(t_tilde, kind="stable")
    tau = problem.tau()
    total_m = 0
    bound = 0.0
    for j in order:
        if m[j] <= 0 or not np.isfinite(t_tilde[j]):
            continue
        total_m += int(m[j])
        bound += t_tilde[j] * m[j]
        if total_m >= problem.L:
            bound -= tau[j] * (total_m - problem.L)
            return float(bound)
    return float("inf")  # infeasible placement


def lower_bound_client(problem: Problem, client: int) -> float:
    """(35): block-by-block relaxation with m̄_j = min(⌊M_j/(s_m+s_c)⌋, L)."""
    m_bar = np.minimum(
        np.floor(problem.mem() / (problem.s_m + problem.s_c)),
        problem.L).astype(int)
    ok = m_bar > 0
    if not ok.any():
        return float("inf")
    t = np.full(problem.n_servers, np.inf)
    t[ok] = problem.tau()[ok] + problem.rtt_token[client][ok] / m_bar[ok]
    order = np.argsort(t, kind="stable")
    remaining = problem.L
    total = 0.0
    for j in order:
        if not np.isfinite(t[j]) or remaining <= 0:
            break
        take = min(int(m_bar[j]), remaining)
        total += t[j] * take
        remaining -= take
    return float(total) if remaining <= 0 else float("inf")


def lower_bound(problem: Problem,
                requests_per_client: Optional[np.ndarray] = None) -> float:
    """T^o ≥ (1/|R|) Σ_c |R_c| T_c^o."""
    w = (np.ones(problem.n_clients) if requests_per_client is None
         else np.asarray(requests_per_client, float))
    vals = np.array([lower_bound_client(problem, c)
                     for c in range(problem.n_clients)])
    return float((w * vals).sum() / w.sum())


def approximation_ratio(problem: Problem, R: int) -> float:
    """Upper/lower bound ratio for CG-BPRR (B.4)."""
    ub = cg_upper_bound(problem, R)
    lb = lower_bound(problem)
    return float(ub / lb) if np.isfinite(ub) and lb > 0 else float("inf")
