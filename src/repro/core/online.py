"""Two-time-scale online BPRR (Alg. 2): CG-BP at the slow time scale +
WS-RR per arriving request, with tracked server state for eq. (20).

The controller is the integration point for the serving stack
(repro.serving.scheduler) and the simulator (repro.sim.simulator):

    ctl = OnlineBPRR(problem, R=...)            # CG-BP placement
    route, start_t = ctl.admit(client, now)     # WS-RR + bookkeeping
    ctl.finish(session_id)                      # frees cache slots
    ctl.server_failed(j) / ctl.server_joined()  # elastic re-placement
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bounds import cg_upper_bound
from repro.core.perf_model import (Placement, Problem, Route,
                                   route_per_token_time, route_prefill_time,
                                   route_total_time)
from repro.core.placement import auto_R, cg_bp, max_feasible_R
from repro.core.routing import (RouteCostCache, ServerState,
                                ServerStateArrays, edge_waiting_times, ws_rr)


@dataclass
class Session:
    """One tracked session in the controller's bookkeeping: its committed
    route and [start, end) interval on the virtual clock — the state that
    feeds eq. (20) waiting estimates for later arrivals."""

    sid: int
    client: int
    route: Route
    arrival: float
    start: float
    end: float


class OnlineBPRR:
    """Alg. 2 controller with session bookkeeping."""

    def __init__(self, problem: Problem, R: Optional[int] = None,
                 arrival_rate: Optional[float] = None,
                 slot_scale: float = 1.0):
        # page-granular eq. (5)/(20): when the serving engine books pages
        # instead of worst-case slots, each co-resident session reserves
        # s_c / slot_scale cache bytes — scaling the controller's view of
        # s_c ONCE propagates consistently through CG-BP's conservative_m
        # (Alg. 1 line 1), the eq. (15) capacities, and the eq. (20)
        # waiting times (1.0 keeps the paper's slab worst case)
        self.slot_scale = float(slot_scale)
        self.problem = problem = self._cache_scaled(problem)
        if R is None:
            guess = cg_upper_bound(problem, max(1, min(8, max_feasible_R(
                problem)))) * problem.workload.l_out
            R = auto_R(problem, arrival_rate or 0.1,
                       guess if np.isfinite(guess) else 60.0)
        self.R = int(R)
        self.placement, self.info = cg_bp(problem, self.R)
        self.sessions: Dict[int, Session] = {}
        self._next_sid = itertools.count()
        # flap avoidance: {server: additive per-token cost penalty} for
        # servers the serving layer has seen fail by timeout — survives
        # replace_servers (a rejoined server stays penalized until cleared)
        self._suspicion: Dict[int, float] = {}
        # placement-derived routing inputs (graph, edge costs, slot caps)
        # are arrival-invariant: memoize them across admits and invalidate
        # only when the placement / server set changes (replace_servers)
        self._route_cache = RouteCostCache(self.problem, self.placement,
                                           suspicion=self._suspicion)

    def _cache_scaled(self, problem: Problem) -> Problem:
        if self.slot_scale == 1.0:
            return problem
        llm = problem.llm
        return replace(problem, llm=replace(
            llm,
            cache_bytes_per_token=llm.cache_bytes_per_token
            / self.slot_scale,
            cache_bytes_const=llm.cache_bytes_const / self.slot_scale))

    # ------------------------------------------------------------------
    def server_states(self, now: float) -> Dict[int, ServerState]:
        states: Dict[int, ServerState] = {}
        for s in self.sessions.values():
            for j, k in zip(s.route.servers, s.route.blocks):
                st = states.setdefault(j, ServerState([], []))
                st.remaining.append(max(s.end - now, 0.0))
                st.blocks.append(k)
        return states

    def server_state_arrays(self, now: float) -> ServerStateArrays:
        """Array-backed :meth:`server_states` — same sessions, same
        insertion order, same floats, but in the SoA form the vectorized
        ``edge_waiting_times`` branch consumes without per-arrival dict
        rebuilds (bit-identical wait matrices, tests/test_simulator.py)."""
        rem: Dict[int, List[float]] = {}
        blk: Dict[int, List[int]] = {}
        for s in self.sessions.values():
            for j, k in zip(s.route.servers, s.route.blocks):
                if j in rem:
                    rem[j].append(max(s.end - now, 0.0))
                    blk[j].append(k)
                else:
                    rem[j] = [max(s.end - now, 0.0)]
                    blk[j] = [k]
        out = ServerStateArrays(self.problem.n_servers)
        for j, r in rem.items():
            out.set(j, np.asarray(r, float), np.asarray(blk[j], np.int64))
        return out

    def concurrency(self) -> int:
        return len(self.sessions)

    # ------------------------------------------------------------------
    def admit(self, client: int, now: float
              ) -> Tuple[Optional[Route], float, float, int]:
        """Route a new request.  Returns (route, start_time, end_time, sid)."""
        states = self.server_state_arrays(now)
        route, cost, wait = ws_rr(self.problem, self.placement, client,
                                  states, cache=self._route_cache)
        if route is None:
            return None, np.inf, np.inf, -1
        start = now + wait
        dur = route_total_time(self.problem, route, client)
        end = start + dur
        sid = next(self._next_sid)
        self.sessions[sid] = Session(sid, client, route, now, start, end)
        return route, start, end, sid

    def finish(self, sid: int):
        self.sessions.pop(sid, None)

    def gc(self, now: float):
        """Drop sessions whose end time has passed."""
        done = [sid for sid, s in self.sessions.items() if s.end <= now]
        for sid in done:
            self.finish(sid)

    # ------------------------------------------------------------------
    # Elastic scaling / fault tolerance (slow-time-scale re-placement)
    # ------------------------------------------------------------------
    def replace_servers(self, problem: Problem, R: Optional[int] = None):
        """Re-run CG-BP after a join/leave/failure (Alg. 2 extension,
        §3.3.3).  Running sessions keep their routes; new requests use the
        new placement."""
        self.problem = self._cache_scaled(problem)
        if R is not None:
            self.R = int(R)
        self.placement, self.info = cg_bp(self.problem, self.R)
        # capacities / RTTs / placement changed: drop every memoized input
        # (the suspicion map persists — flap avoidance across rejoins)
        self._route_cache = RouteCostCache(self.problem, self.placement,
                                           suspicion=self._suspicion)

    def set_suspicion(self, j: int, penalty: float):
        """Penalize edges into server ``j`` by ``penalty`` seconds/token
        in every routing decision (timeout-detected failure — see
        ``FailureDetector.suspicion_penalty``).  Rebuilds the memoized
        route cache so the next admit sees it."""
        self._suspicion[int(j)] = float(penalty)
        self._route_cache = RouteCostCache(self.problem, self.placement,
                                           suspicion=self._suspicion)

    def clear_suspicion(self, j: int):
        """Forgive server ``j`` (it has proven itself after a rejoin)."""
        if self._suspicion.pop(int(j), None) is not None:
            self._route_cache = RouteCostCache(self.problem, self.placement,
                                               suspicion=self._suspicion)

    def guarantee(self) -> float:
        """Completion-time guarantee (22) while concurrency <= R."""
        return (cg_upper_bound(self.problem, self.R)
                * self.problem.workload.l_out)
