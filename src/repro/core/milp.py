"""MILP formulations solved with scipy.optimize.milp (HiGHS).

* ``solve_bprr_milp``     — the full joint MILP (13) with the bilinear-term
  linearisation (31)–(34).  Exponential in general (Thm 3.2: NP-hard via
  PARTITION), so used on small instances for optimality-gap studies/tests.
* ``solve_routing_ilp``   — the routing subproblem (16) given a placement
  ('Optimized RR' ablation, §4.3).
* ``solve_online_routing``— the per-request online MILP (21) with the
  waiting variable t^W (the paper solves this with Gurobi; HiGHS here).
* ``brute_force_bprr``    — exhaustive optimum for tiny instances (tests).

Indexing note: this module uses the paper's 1-based block encoding
(a_j, m_j ∈ [L]; S-client a=0,m=1; D-client a=L+1,m=1) and converts to the
0-based ``Placement`` at the boundary.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np
from scipy.optimize import Bounds, LinearConstraint, milp

from repro.core.perf_model import Placement, Problem, Route
from repro.core.routing import edge_cost_matrix, shortest_path_route
from repro.core.topology import RoutingGraph, route_blocks


@dataclass
class MILPResult:
    status: int
    objective: float
    placement: Optional[Placement]
    routes: Optional[List[Route]]
    message: str = ""


def solve_bprr_milp(problem: Problem, client_of_request: List[int],
                    time_limit: float = 120.0) -> MILPResult:
    """Joint BPRR MILP (13).  Requests r have clients client_of_request[r]."""
    n = problem.n_servers
    R = len(client_of_request)
    L = problem.L
    tau = problem.tau()
    Lp1 = L + 1

    # ---- variable layout -------------------------------------------------
    # globals: a_j (n), m_j (n)
    # per request r:
    #   S-edges  (S->j): f, alpha(=a_j f), gamma(=m_j f)          3n vars
    #   mid edges (i->j), i != j: f, alpha, beta, gamma, delta    5n(n-1)
    #   D-edges  (j->D): f                                        n
    idx = {}
    pos = 0

    def add(name):
        nonlocal pos
        idx[name] = pos
        pos += 1

    for j in range(n):
        add(("a", j))
    for j in range(n):
        add(("m", j))
    for r in range(R):
        for j in range(n):
            add(("fS", r, j))
            add(("aS", r, j))
            add(("gS", r, j))
        for i in range(n):
            for j in range(n):
                if i == j:
                    continue
                for v in ("f", "al", "be", "ga", "de"):
                    add((v, r, i, j))
        for j in range(n):
            add(("fD", r, j))
    nv = pos

    lb = np.zeros(nv)
    ub = np.full(nv, np.inf)
    integrality = np.zeros(nv)
    c = np.zeros(nv)
    for j in range(n):
        lb[idx[("a", j)]] = 1
        ub[idx[("a", j)]] = L
        integrality[idx[("a", j)]] = 1
        lb[idx[("m", j)]] = 1
        ub[idx[("m", j)]] = L
        integrality[idx[("m", j)]] = 1
    for key, p in idx.items():
        if key[0] in ("fS", "fD", "f"):
            ub[p] = 1
            integrality[p] = 1

    rows = []
    lo = []
    hi = []

    def row(coeffs: Dict[int, float], lo_v, hi_v):
        rows.append(coeffs)
        lo.append(lo_v)
        hi.append(hi_v)

    # ---- objective (13a) + constraints ------------------------------------
    for r in range(R):
        cl = client_of_request[r]
        for j in range(n):
            # S->j: e_S = 1 (1-based); k_j = a_j + m_j - 1
            c[idx[("fS", r, j)]] += problem.rtt_token[cl, j] - tau[j]
            c[idx[("aS", r, j)]] += tau[j]
            c[idx[("gS", r, j)]] += tau[j]
        for i in range(n):
            for j in range(n):
                if i == j:
                    continue
                c[idx[("f", r, i, j)]] += problem.rtt_token[cl, j]
                c[idx[("al", r, i, j)]] += tau[j]
                c[idx[("ga", r, i, j)]] += tau[j]
                c[idx[("be", r, i, j)]] -= tau[j]
                c[idx[("de", r, i, j)]] -= tau[j]

        # flow conservation (13c)
        row({idx[("fS", r, j)]: 1.0 for j in range(n)}, 1, 1)
        row({idx[("fD", r, j)]: 1.0 for j in range(n)}, 1, 1)
        for j in range(n):
            coeffs = {idx[("fS", r, j)]: 1.0, idx[("fD", r, j)]: -1.0}
            for i in range(n):
                if i == j:
                    continue
                coeffs[idx[("f", r, i, j)]] = coeffs.get(
                    idx[("f", r, i, j)], 0.0) + 1.0
                coeffs[idx[("f", r, j, i)]] = coeffs.get(
                    idx[("f", r, j, i)], 0.0) - 1.0
            row(coeffs, 0, 0)

        for j in range(n):
            # S->j feasibility: a_j f <= 1  and  f <= a_j + m_j - 1
            row({idx[("aS", r, j)]: 1.0}, -np.inf, 1.0)  # alpha_Sj <= e_S=1
            row({idx[("fS", r, j)]: 1.0, idx[("a", j)]: -1.0,
                 idx[("m", j)]: -1.0}, -np.inf, -1.0)  # f <= a_j+m_j-1
            # D-edge feasibility: f_jD = 1 -> a_j + m_j = L+1
            row({idx[("fD", r, j)]: Lp1, idx[("a", j)]: -1.0,
                 idx[("m", j)]: -1.0}, -np.inf, 0.0)  # (L+1) f <= a_j+m_j
            row({idx[("fD", r, j)]: Lp1, idx[("a", j)]: 1.0,
                 idx[("m", j)]: 1.0}, -np.inf, 2 * Lp1)
            # linearisation for S-edge alpha=a_j f, gamma=m_j f (31)/(33)
            _linearize(row, idx, ("aS", r, j), ("fS", r, j), ("a", j), Lp1)
            _linearize(row, idx, ("gS", r, j), ("fS", r, j), ("m", j), Lp1)

        for i in range(n):
            for j in range(n):
                if i == j:
                    continue
                # (13e): alpha_ij <= a_i + m_i
                row({idx[("al", r, i, j)]: 1.0, idx[("a", i)]: -1.0,
                     idx[("m", i)]: -1.0}, -np.inf, 0.0)
                # (13f): beta + delta <= a_j + m_j - 1
                row({idx[("be", r, i, j)]: 1.0, idx[("de", r, i, j)]: 1.0,
                     idx[("a", j)]: -1.0, idx[("m", j)]: -1.0},
                    -np.inf, -1.0)
                # (31)-(34)
                _linearize(row, idx, ("al", r, i, j), ("f", r, i, j),
                           ("a", j), Lp1)
                _linearize(row, idx, ("be", r, i, j), ("f", r, i, j),
                           ("a", i), Lp1)
                _linearize(row, idx, ("ga", r, i, j), ("f", r, i, j),
                           ("m", j), Lp1)
                _linearize(row, idx, ("de", r, i, j), ("f", r, i, j),
                           ("m", i), Lp1)

    # block range validity (13d): a_j + m_j - 1 <= L
    for j in range(n):
        row({idx[("a", j)]: 1.0, idx[("m", j)]: 1.0}, -np.inf, L + 1)

    # memory (13b)
    for j in range(n):
        coeffs = {idx[("m", j)]: float(problem.s_m)}
        for r in range(R):
            coeffs[idx[("aS", r, j)]] = coeffs.get(idx[("aS", r, j)], 0.0) \
                + problem.s_c
            coeffs[idx[("gS", r, j)]] = coeffs.get(idx[("gS", r, j)], 0.0) \
                + problem.s_c
            coeffs[idx[("fS", r, j)]] = coeffs.get(idx[("fS", r, j)], 0.0) \
                - problem.s_c  # k = a_j + m_j - e_S, e_S = 1
            for i in range(n):
                if i == j:
                    continue
                coeffs[idx[("al", r, i, j)]] = problem.s_c
                coeffs[idx[("ga", r, i, j)]] = problem.s_c
                coeffs[idx[("be", r, i, j)]] = -problem.s_c
                coeffs[idx[("de", r, i, j)]] = -problem.s_c
        row(coeffs, -np.inf, float(problem.servers[j].mem_bytes))

    A = np.zeros((len(rows), nv))
    for rr, coeffs in enumerate(rows):
        for p, v in coeffs.items():
            A[rr, p] = v
    res = milp(c=c, constraints=LinearConstraint(A, lo, hi),
               integrality=integrality, bounds=Bounds(lb, ub),
               options={"time_limit": time_limit})
    if not res.success:
        return MILPResult(status=res.status, objective=np.inf,
                          placement=None, routes=None, message=res.message)
    x = res.x
    a1 = np.array([int(round(x[idx[("a", j)]])) for j in range(n)])
    m1 = np.array([int(round(x[idx[("m", j)]])) for j in range(n)])
    placement = Placement(a=a1 - 1, m=m1)  # to 0-based
    routes = []
    for r in range(R):
        chain = []
        cur = None
        for j in range(n):
            if x[idx[("fS", r, j)]] > 0.5:
                cur = j
                break
        while cur is not None:
            chain.append(cur)
            nxt = None
            for j in range(n):
                if j != cur and x[idx[("f", r, cur, j)]] > 0.5:
                    nxt = j
                    break
            cur = nxt
        routes.append(route_blocks(placement, tuple(chain)))
    return MILPResult(status=0, objective=float(res.fun),
                      placement=placement, routes=routes)


def _linearize(row, idx, prod_key, f_key, var_key, big):
    """(31)-style: prod = var * f for binary f, var in [0, big]."""
    p, f, v = idx[prod_key], idx[f_key], idx[var_key]
    row({p: 1.0, f: -float(big)}, -np.inf, 0.0)  # prod <= big f
    row({p: 1.0, v: -1.0}, -np.inf, 0.0)  # prod <= var
    row({v: 1.0, f: float(big), p: -1.0}, -np.inf, float(big))  # prod >= ...


# ---------------------------------------------------------------------------
# Routing-only ILP (16) — 'Optimized RR'
# ---------------------------------------------------------------------------


def solve_routing_ilp(problem: Problem, placement: Placement,
                      client_of_request: List[int],
                      time_limit: float = 60.0) -> Tuple[float, List[Route]]:
    """(16): min Σ t^c_ij f  s.t. memory + flow conservation, given (a,m)."""
    graph = RoutingGraph.build(placement, problem.L)
    n = problem.n_servers
    a, m = placement.a, placement.m
    e = a + m
    R = len(client_of_request)
    edges = []  # (i, j) with i == n meaning S-client
    for j in graph.first:
        edges.append((n, int(j)))
    for i in range(n):
        for j in graph.succ[i]:
            edges.append((i, int(j)))
    dedges = [int(j) for j in graph.last]
    ne = len(edges)
    nv = R * (ne + len(dedges))

    c = np.zeros(nv)
    costs = {cl: edge_cost_matrix(problem, placement, cl)
             for cl in set(client_of_request)}

    def fidx(r, k):
        return r * (ne + len(dedges)) + k

    rows, lo, hi = [], [], []
    for r in range(R):
        cm = costs[client_of_request[r]]
        for k, (i, j) in enumerate(edges):
            c[fidx(r, k)] = cm[i, j]
        # flow conservation
        coeffs = {fidx(r, k): 1.0 for k, (i, j) in enumerate(edges) if i == n}
        rows.append(coeffs)
        lo.append(1)
        hi.append(1)
        coeffs = {fidx(r, ne + k): 1.0 for k in range(len(dedges))}
        rows.append(coeffs)
        lo.append(1)
        hi.append(1)
        for v in range(n):
            if m[v] <= 0:
                continue
            coeffs = {}
            for k, (i, j) in enumerate(edges):
                if j == v:
                    coeffs[fidx(r, k)] = coeffs.get(fidx(r, k), 0) + 1.0
                if i == v:
                    coeffs[fidx(r, k)] = coeffs.get(fidx(r, k), 0) - 1.0
            for k, j in enumerate(dedges):
                if j == v:
                    coeffs[fidx(r, ne + k)] = coeffs.get(
                        fidx(r, ne + k), 0) - 1.0
            rows.append(coeffs)
            lo.append(0)
            hi.append(0)
    # memory (16b)
    for v in range(n):
        if m[v] <= 0:
            continue
        coeffs = {}
        for r in range(R):
            for k, (i, j) in enumerate(edges):
                if j == v:
                    k_blocks = e[v] - (0 if i == n else e[i])
                    coeffs[fidx(r, k)] = problem.s_c * float(k_blocks)
        if coeffs:
            rows.append(coeffs)
            lo.append(-np.inf)
            hi.append(float(problem.servers[v].mem_bytes
                            - problem.s_m * m[v]))
    A = np.zeros((len(rows), nv))
    for rr, coeffs in enumerate(rows):
        for p, vv in coeffs.items():
            A[rr, p] = vv
    res = milp(c=c, constraints=LinearConstraint(A, lo, hi),
               integrality=np.ones(nv),
               bounds=Bounds(np.zeros(nv), np.ones(nv)),
               options={"time_limit": time_limit})
    if not res.success:
        return np.inf, []
    routes = []
    for r in range(R):
        nxt = {}
        start = None
        for k, (i, j) in enumerate(edges):
            if res.x[fidx(r, k)] > 0.5:
                if i == n:
                    start = j
                else:
                    nxt[i] = j
        chain = []
        cur = start
        while cur is not None:
            chain.append(cur)
            cur = nxt.get(cur)
        routes.append(route_blocks(placement, tuple(chain)))
    return float(res.fun), routes


def solve_online_routing(problem: Problem, placement: Placement, client: int,
                         waiting: np.ndarray,
                         time_limit: float = 10.0
                         ) -> Tuple[Optional[Route], float]:
    """Per-request online MILP (21): min t^W + l_max Σ t^c_ij f_ij with
    t^W ≥ t^W_ij f_ij.  (The simulator's 'Optimized RR' arm.)"""
    graph = RoutingGraph.build(placement, problem.L)
    n = problem.n_servers
    edges = [(n, int(j)) for j in graph.first]
    for i in range(n):
        for j in graph.succ[i]:
            edges.append((i, int(j)))
    dedges = [int(j) for j in graph.last]
    ne = len(edges)
    nv = ne + len(dedges) + 1  # + t^W
    TW = nv - 1
    cm = edge_cost_matrix(problem, placement, client)
    lmax = float(problem.workload.l_out)
    c = np.zeros(nv)
    c[TW] = 1.0
    for k, (i, j) in enumerate(edges):
        c[k] = lmax * cm[i, j]
    rows, lo, hi = [], [], []
    rows.append({k: 1.0 for k, (i, j) in enumerate(edges) if i == n})
    lo.append(1)
    hi.append(1)
    rows.append({ne + k: 1.0 for k in range(len(dedges))})
    lo.append(1)
    hi.append(1)
    for v in range(n):
        if placement.m[v] <= 0:
            continue
        coeffs = {}
        for k, (i, j) in enumerate(edges):
            if j == v:
                coeffs[k] = coeffs.get(k, 0) + 1.0
            if i == v:
                coeffs[k] = coeffs.get(k, 0) - 1.0
        for k, j in enumerate(dedges):
            if j == v:
                coeffs[ne + k] = coeffs.get(ne + k, 0) - 1.0
        rows.append(coeffs)
        lo.append(0)
        hi.append(0)
    for k, (i, j) in enumerate(edges):
        w = waiting[i, j]
        if not np.isfinite(w):
            # edge unusable now: forbid
            rows.append({k: 1.0})
            lo.append(0)
            hi.append(0)
        elif w > 0:
            rows.append({TW: 1.0, k: -float(w)})
            lo.append(0)
            hi.append(np.inf)
    A = np.zeros((len(rows), nv))
    for rr, coeffs in enumerate(rows):
        for p, vv in coeffs.items():
            A[rr, p] = vv
    ub = np.ones(nv)
    ub[TW] = np.inf
    integ = np.ones(nv)
    integ[TW] = 0
    res = milp(c=c, constraints=LinearConstraint(A, lo, hi),
               integrality=integ, bounds=Bounds(np.zeros(nv), ub),
               options={"time_limit": time_limit})
    if not res.success:
        return None, np.inf
    nxt = {}
    start = None
    for k, (i, j) in enumerate(edges):
        if res.x[k] > 0.5:
            if i == n:
                start = j
            else:
                nxt[i] = j
    chain = []
    cur = start
    while cur is not None:
        chain.append(cur)
        cur = nxt.get(cur)
    return route_blocks(placement, tuple(chain)), float(res.fun)


# ---------------------------------------------------------------------------
# Brute force (tests only)
# ---------------------------------------------------------------------------


def brute_force_bprr(problem: Problem, client_of_request: List[int]
                     ) -> Tuple[float, Optional[Placement]]:
    """Exhaustive search over placements (m_j >= 1) + optimal routing via
    the routing ILP.  Exponential — tiny instances only."""
    n = problem.n_servers
    L = problem.L
    best = (np.inf, None)
    spans = [(a, m_) for m_ in range(1, L + 1) for a in range(L - m_ + 1)]
    for combo in itertools.product(spans, repeat=n):
        a = np.array([s[0] for s in combo])
        m = np.array([s[1] for s in combo])
        if (problem.s_m * m > problem.mem()).any():
            continue
        placement = Placement(a=a, m=m)
        if not placement.feasible_cover(L):
            continue
        obj, routes = solve_routing_ilp(problem, placement,
                                        client_of_request)
        if obj < best[0]:
            best = (obj, placement)
    return best
