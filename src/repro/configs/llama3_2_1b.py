"""Llama-3.2 1B [hf:meta-llama/Llama-3.2-1B].

16L d_model=2048 32H (GQA kv=8, head_dim=64) d_ff=8192 vocab=128256; tied.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    attn_kind="gqa",
    rope_theta=500_000.0,
    norm_kind="rmsnorm",
    tie_embeddings=True,
    max_seq_len=131072,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama3.2-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
