"""RWKV-6 (Finch) 7B [arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b].

32L d_model=4096 (attention-free), 64 WKV heads x head_dim 64 with
data-dependent decay (low-rank), channel-mix d_ff=14336, vocab=65536.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=14336,
    vocab_size=65536,
    attn_kind="none",
    pos_kind="none",
    ssm_heads=64,
    ssm_head_dim=64,
    ssm_state=64,
    norm_kind="layernorm",
    max_seq_len=1 << 20,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="rwkv6-reduced",
        n_layers=2,
        d_model=64,
        d_ff=128,
        vocab_size=256,
        ssm_heads=4,
        ssm_head_dim=16,
        ssm_state=16,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
