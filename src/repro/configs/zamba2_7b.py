"""Zamba2-7B [arXiv:2411.15242].

81L d_model=3584; Mamba2 backbone (d_inner=7168, 112 SSM heads x head_dim 64,
ssm_state=64, conv width 4) with a parameter-SHARED attention+MLP block applied
every 6th layer on concat(hidden, original_embedding) (width 2*d_model, 32
heads x head_dim 224), d_ff=14336, vocab=32000.

Deviation (DESIGN.md §5): the released model alternates two shared blocks and
adds per-invocation LoRA deltas; we use a single shared block (optional LoRA
path exists in models/blocks.py) — placement/routing semantics identical.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=224,  # attention width = 2*d_model = 7168 = 32*224
    d_ff=14336,
    vocab_size=32000,
    attn_kind="gqa",
    shared_attn_period=6,
    ssm_state=64,
    ssm_heads=112,
    ssm_head_dim=64,
    d_inner=7168,
    conv_width=4,
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    max_seq_len=1 << 20,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="zamba2-reduced",
        n_layers=7,  # 2 mega-blocks of 3 + 1 tail mamba layer
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,  # 2*64/4
        d_ff=128,
        vocab_size=256,
        shared_attn_period=3,
        ssm_state=16,
        ssm_heads=8,
        ssm_head_dim=16,
        d_inner=128,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
