"""Configuration system: architecture configs and input-shape specs.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exporting
``CONFIG`` (the exact full-size config from the assignment table) and
``reduced()`` (a tiny same-family config for CPU smoke tests).

``ModelConfig`` is deliberately a frozen dataclass of plain Python values so a
config hashes/compares cleanly and can be closed over by jitted functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes (assignment: LM transformer shapes, seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    The stack is described as a sequence of *scan segments* (see
    ``repro.models.stacks``); which segments exist is derived from the family
    fields below.  ``n_layers`` always counts BPRR *blocks* — the granularity
    at which the paper's placement algorithm assigns work to servers.
    """

    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm

    n_layers: int
    d_model: int
    n_heads: int  # query heads (0 for attention-free archs)
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention flavour -------------------------------------------------
    attn_kind: str = "gqa"  # "gqa" | "mla" | "none"
    qkv_bias: bool = False
    qk_norm: bool = False
    pos_kind: str = "rope"  # "rope" | "alibi" | "none"
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # >0: window size for local layers
    local_global_period: int = 0  # e.g. 6 => 5 local : 1 global (last in group)
    logit_softcap: float = 0.0

    # --- MLA (deepseek-v2) --------------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0  # per-head rope dims for MLA

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / rwkv6) ------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    d_inner: int = 0
    conv_width: int = 4

    # --- hybrid (zamba2) ------------------------------------------------------
    shared_attn_period: int = 0  # apply the shared attention block every N layers

    # --- encoder-decoder (seamless) -------------------------------------------
    n_enc_layers: int = 0  # if >0, stack is enc-dec; n_layers == n_enc + n_dec
    n_dec_layers: int = 0

    # --- misc ------------------------------------------------------------------
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric
    sandwich_norm: bool = False  # post-attn/post-ffn norms (gemma3)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 1 << 19
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    optimizer: str = "adamw"  # adamw | adafactor (memory knob for huge archs)

    # Input modality of the stub frontend ("tokens" | "frames").
    frontend: str = "tokens"
    frame_dim: int = 0  # embedding dim of precomputed frames (audio stub)

    # ------------------------------------------------------------------
    # Derived helpers
    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 64 so the vocab dim always shards
        over a 16-way model axis (Megatron-style padding; only seamless's
        256206 actually pads, to 256256).  Loss masks padded columns."""
        return ((self.vocab_size + 63) // 64) * 64

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.attn_kind == "none" and self.shared_attn_period == 0

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (assignment: SSM / hybrid / local-global)."""
        return (
            self.family in ("ssm", "hybrid")
            or (self.sliding_window > 0 and self.local_global_period > 0)
        )

    def shapes(self) -> Tuple[ShapeSpec, ...]:
        """The applicable shape cells for this architecture."""
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if self.subquadratic:
            out.append(LONG_500K)
        return tuple(out)

    def skip_reasons(self) -> dict:
        """Shape cells skipped for this arch, with reasons (→ DESIGN.md)."""
        skips = {}
        if not self.subquadratic:
            skips["long_500k"] = (
                "pure full-attention architecture; long_500k requires "
                "sub-quadratic attention per the assignment"
            )
        return skips

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counting (for roofline MODEL_FLOPS and the BPRR s_m model)
    # ------------------------------------------------------------------
    def block_param_count(self) -> int:
        """Parameters in ONE transformer/SSM block (a BPRR placement unit).

        Mixed stacks return the average per-block count so that
        ``n_layers * block_param_count`` matches the stack total.
        """
        return sum(self._per_block_counts()) // max(1, self.n_layers)

    def _attn_params(self, width: Optional[int] = None) -> int:
        d = width or self.d_model
        if self.attn_kind == "mla":
            q_in = self.q_lora_rank or d
            n = 0
            if self.q_lora_rank:
                n += d * self.q_lora_rank
            n += q_in * self.n_heads * (self.head_dim + self.rope_head_dim)
            n += d * (self.kv_lora_rank + self.rope_head_dim)  # down-proj kv
            n += self.kv_lora_rank * self.n_heads * self.head_dim * 2  # k_up, v_up
            n += self.n_heads * self.head_dim * self.d_model  # out proj
            return n
        nq = d * self.n_heads * self.head_dim
        nkv = 2 * d * self.n_kv_heads * self.head_dim
        no = self.n_heads * self.head_dim * self.d_model
        bias = (self.n_heads + 2 * self.n_kv_heads) * self.head_dim if self.qkv_bias else 0
        return nq + nkv + no + bias

    def _mlp_params(self, d_ff: Optional[int] = None, width: Optional[int] = None) -> int:
        d = width or self.d_model
        f = d_ff or self.d_ff
        return 3 * d * f if self.norm_kind != "layernorm" else 2 * d * f  # gated vs plain

    def _moe_params(self) -> int:
        per_expert = 3 * self.d_model * self.d_ff_expert
        shared = self.n_shared_experts * per_expert
        router = self.d_model * self.n_experts
        return self.n_experts * per_expert + shared + router

    def _mamba_params(self) -> int:
        d, di, n, h = self.d_model, self.d_inner, self.ssm_state, self.ssm_heads
        conv_dim = di + 2 * n
        return (
            d * (2 * di + 2 * n + h)  # in_proj -> x, z, B, C, dt
            + self.conv_width * conv_dim  # depthwise conv
            + 2 * h  # A_log, D
            + di * d  # out proj
        )

    def _rwkv_params(self) -> int:
        d, f = self.d_model, self.d_ff
        tm = 4 * d * d + d * self.ssm_heads  # r,k,v,(g),w projections (approx)
        tm += d * d  # output
        lora = 6 * d * 64  # data-dependent decay low-rank (Finch)
        cm = 2 * d * f  # channel mix: key, value
        return tm + lora + cm

    def _per_block_counts(self):
        """List of per-block param counts covering all n_layers blocks."""
        counts = []
        if self.family == "ssm":  # rwkv6
            counts = [self._rwkv_params()] * self.n_layers
        elif self.family == "hybrid":  # zamba2: mamba blocks + amortized shared attn
            mamba = self._mamba_params()
            counts = [mamba] * self.n_layers
            # one shared attention+mlp block (width 2d in, d out), amortized once
            shared = self._attn_params(width=2 * self.d_model) + self._mlp_params(
                width=2 * self.d_model
            )
            counts[0] += shared
        elif self.is_enc_dec:
            enc = self._attn_params() + self._mlp_params()
            dec = 2 * self._attn_params() + self._mlp_params()  # self + cross
            counts = [enc] * self.n_enc_layers + [dec] * self.n_dec_layers
        elif self.is_moe:
            blk = self._attn_params() + self._moe_params()
            counts = [blk] * self.n_layers
        else:
            blk = self._attn_params() + self._mlp_params()
            counts = [blk] * self.n_layers
        return counts

    def param_count(self) -> int:
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        if self.frontend == "frames":
            emb += self.frame_dim * self.d_model
        return sum(self._per_block_counts()) + emb + head

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count()
        per_expert = 3 * self.d_model * self.d_ff_expert
        dense_moe = self.n_experts * per_expert
        active_moe = self.moe_top_k * per_expert
        return self.param_count() - self.n_layers * (dense_moe - active_moe)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "deepseek_v2_236b",
    "llama4_scout_17b_a16e",
    "qwen2_5_32b",
    "gemma3_4b",
    "llama3_2_1b",
    "olmo_1b",
    "chameleon_34b",
    "seamless_m4t_large_v2",
    "zamba2_7b",
    "rwkv6_7b",
)

# The paper's own model (used by the simulator / BPRR benchmarks).
PAPER_ARCH_IDS = ("bloom_176b",)


def get_config(arch_id: str) -> ModelConfig:
    import importlib

    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def get_reduced_config(arch_id: str) -> ModelConfig:
    import importlib

    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.reduced()
