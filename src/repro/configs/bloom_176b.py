"""BLOOM-176B — the paper's evaluation model (BigScience, ref [3]).

70 transformer blocks, d_model=14336, 112 MHA heads (head_dim 128),
d_ff=57344, vocab=250880, ALiBi positions, LayerNorm, tied embeddings.

Used by the BPRR simulator and benchmarks to reproduce the paper's numbers
(L=70 blocks; s_c = 2*d_model*(l_in+l_out)*dtype_bytes per block per session).
Not part of the assigned 40 dry-run cells.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="bloom-176b",
    family="dense",
    n_layers=70,
    d_model=14336,
    n_heads=112,
    n_kv_heads=112,
    head_dim=128,
    d_ff=57344,
    vocab_size=250880,
    attn_kind="gqa",
    pos_kind="alibi",
    norm_kind="layernorm",
    tie_embeddings=True,
    max_seq_len=2048,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="bloom-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
