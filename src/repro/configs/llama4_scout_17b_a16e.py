"""Llama-4 Scout 17B-active / 16 experts [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff(expert)=8192 vocab=202048, MoE 16 experts
top-1 + 1 shared expert, early fusion (text + image tokens share the stack).

Deviation (DESIGN.md §5): uniform MoE layers (released model interleaves
dense/MoE); shared-expert and top-1 routing semantics preserved.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attn_kind="gqa",
    n_experts=16,
    n_shared_experts=1,
    moe_top_k=1,
    d_ff_expert=8192,
    rope_theta=500_000.0,
    norm_kind="rmsnorm",
    max_seq_len=131072,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="llama4-scout-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=96,
        d_ff_expert=96,
        vocab_size=256,
        n_experts=4,
        n_shared_experts=1,
        moe_top_k=1,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
