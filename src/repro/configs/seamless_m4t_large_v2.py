"""SeamlessM4T-large v2 [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large].

Encoder-decoder backbone: 24 encoder + 24 decoder layers, d_model=1024,
16H (MHA kv=16, head_dim=64), d_ff=8192, vocab=256206.  "24L" in the
assignment table names the per-stack depth; the BPRR chain has
n_layers = 48 blocks (24 enc then 24 dec).

The speech frontend (fbank + conv subsampling) is a stub per the assignment:
``input_specs()`` provides precomputed frame embeddings of dim ``frame_dim``.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=48,
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    attn_kind="gqa",
    rope_theta=10_000.0,
    norm_kind="layernorm",
    frontend="frames",
    frame_dim=160,
    max_seq_len=32768,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="seamless-reduced",
        n_layers=4,
        n_enc_layers=2,
        n_dec_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        frame_dim=24,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
