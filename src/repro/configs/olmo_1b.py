"""OLMo 1B [arXiv:2402.00838; hf:allenai/OLMo-1B].

16L d_model=2048 16H (MHA kv=16, head_dim=128) d_ff=8192 vocab=50304;
non-parametric LayerNorm (no scale/bias), tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    attn_kind="gqa",
    rope_theta=10_000.0,
    norm_kind="nonparametric",
    tie_embeddings=True,
    max_seq_len=4096,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="olmo-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
