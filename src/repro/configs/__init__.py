from repro.configs.base import (
    ALL_SHAPES,
    ARCH_IDS,
    PAPER_ARCH_IDS,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeSpec,
    get_config,
    get_reduced_config,
)

__all__ = [
    "ALL_SHAPES",
    "ARCH_IDS",
    "PAPER_ARCH_IDS",
    "SHAPES_BY_NAME",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "get_reduced_config",
]
