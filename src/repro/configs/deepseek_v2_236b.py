"""DeepSeek-V2 236B [arXiv:2405.04434; hf].

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400, MoE 160 routed experts
top-6 + 2 shared, MLA with kv_lora_rank=512 (+64 rope dims), q_lora_rank=1536.

Deviation (documented in DESIGN.md §5): uniform MoE layers (the released model
uses a dense first layer); per-device expert balance, routing, and cache
behaviour are unaffected.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    attn_kind="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    d_ff_expert=1536,
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    max_seq_len=131072,
    # factored second moments: the 236B cell is HBM-bound on 16 GB v5e
    # chips (EXPERIMENTS.md §Perf iter A4)
    optimizer="adafactor",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-v2-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=48,
        d_ff_expert=48,
        vocab_size=256,
        kv_lora_rank=32,
        q_lora_rank=48,
        rope_head_dim=8,
        n_experts=8,
        n_shared_experts=1,
        moe_top_k=2,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
