"""Qwen2.5-32B [hf:Qwen/Qwen2.5-32B].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064; QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    attn_kind="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    max_seq_len=131072,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="qwen2.5-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
