"""Gemma-3 4B [hf:google/gemma-3-4b-pt].

34L d_model=2560 8H (GQA kv=4, head_dim=256) d_ff=10240 vocab=262144;
5 local (sliding-window 1024) : 1 global pattern, 128k context, qk-norm,
tied embeddings.

Deviation (DESIGN.md §5): one rope theta (1e6) for both local and global
layers (released model uses 10k local / 1M global).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    attn_kind="gqa",
    qk_norm=True,
    sliding_window=1024,
    local_global_period=6,  # layers 5, 11, 17, ... are global
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    sandwich_norm=True,
    tie_embeddings=True,
    max_seq_len=131072 * 8,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="gemma3-reduced",
        n_layers=7,  # exercises the 5:1 pattern + a tail local layer
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        sliding_window=16,
        local_global_period=3,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
