"""Chameleon 34B [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8, head_dim=128) d_ff=22016 vocab=65536;
early-fusion VLM: VQ-VAE image tokens share the text vocabulary, so the
backbone is a plain decoder-only LM over mixed token streams.  QK-norm
(Chameleon's training-stability fix).

The modality frontend (VQ tokenizer) is a stub per the assignment:
``input_specs()`` provides already-tokenized mixed sequences.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    attn_kind="gqa",
    qk_norm=True,
    rope_theta=10_000.0,
    norm_kind="rmsnorm",
    max_seq_len=32768,
    optimizer="adamw",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="chameleon-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        max_seq_len=512,
        param_dtype="float32",
        act_dtype="float32",
    )
