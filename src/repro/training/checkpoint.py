"""Checkpoint/restore for fault tolerance (DESIGN.md §7).

Atomic step-tagged snapshots of arbitrary pytrees: leaves are saved into a
single ``.npz`` plus a structure manifest, written to a temp path and renamed
(crash-safe).  ``latest_step``/``restore`` support resume-after-failure; the
resume-equivalence property is tested in tests/test_checkpoint.py.

At real multi-pod scale each host saves only its addressable shards; here the
single-host layout keeps the same interface.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _ckpt_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:010d}")


def save(root: str, step: int, tree: Any) -> str:
    """Atomically save a pytree snapshot for ``step``.  Returns the path."""
    os.makedirs(root, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    final = _ckpt_dir(root, step)
    tmp = tempfile.mkdtemp(dir=root, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "shapes": [list(np.asarray(x).shape) for x in leaves],
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):  # overwrite an existing snapshot atomically
            os.rename(final, tmp + ".old")
        os.rename(tmp, final)
    finally:
        import shutil

        for stale in (tmp, tmp + ".old"):
            if os.path.exists(stale):
                shutil.rmtree(stale, ignore_errors=True)
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(root, name, _MANIFEST)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(root: str, like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``.  Returns (tree, step)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    path = _ckpt_dir(root, step)
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "leaves.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)}")
    leaves = [jax.numpy.asarray(data[f"leaf_{i}"])
              for i in range(manifest["n_leaves"])]
    return treedef.unflatten(leaves), step
