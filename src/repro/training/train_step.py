"""Training step: loss + grad (with remat), grad-accum microbatching,
optimizer update, and the int8-compressed inter-pod gradient sync primitive.

The returned ``train_step(state, batch)`` is pure and jit-able; sharding comes
entirely from the ShardingCtx constraints inside the model plus the
in/out_shardings attached by the caller (launch/dryrun.py, launch/train.py).

Distributed-optimization tricks implemented here (DESIGN.md §7):
* grad-accum microbatching via ``lax.scan`` (activation-memory knob),
* optional int8-quantized all-reduce for the inter-pod (DCI, slow-link)
  gradient reduction — the TPU analogue of gradient compression over the
  paper's WAN links (``int8_allreduce``; numerically tested),
* donated state buffers; XLA latency-hiding scheduler flags in launch/.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ShardingCtx
from repro.models.model import train_loss
from repro.training.optimizer import Optimizer, make_optimizer


@dataclass(frozen=True)
class TrainHParams:
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    grad_accum: int = 1  # microbatches per step
    remat: bool = True


def init_train_state(key, cfg: ModelConfig, opt: Optimizer, params=None):
    from repro.models.model import init_params

    if params is None:
        params, _ = init_params(key, cfg)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, sh: ShardingCtx, opt: Optimizer,
                    hp: TrainHParams = TrainHParams()):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        return train_loss(params, cfg, sh, mb, remat=hp.remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    def accumulated(params, batch):
        n = hp.grad_accum

        def split(x):
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            g_acc = carry
            (loss, metrics), g = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return g_acc, metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        g_acc, metrics = jax.lax.scan(body, zeros, micro)
        grads = jax.tree.map(lambda g: g / n, g_acc)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        return grads, metrics

    def train_step(state, batch):
        params = state["params"]
        if hp.grad_accum > 1:
            grads, metrics = accumulated(params, batch)
        else:
            grads, metrics = single(params, batch)
        new_params, new_opt = opt.update(params, grads, state["opt"],
                                         state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_optimizer_for(cfg: ModelConfig, hp: TrainHParams) -> Optimizer:
    return make_optimizer(cfg.optimizer, lr=hp.learning_rate,
                          weight_decay=hp.weight_decay,
                          **({"grad_clip": hp.grad_clip}
                             if cfg.optimizer == "adamw" else {}))


# ---------------------------------------------------------------------------
# int8 gradient compression (inter-pod slow-link all-reduce)
# ---------------------------------------------------------------------------


def int8_quantize(x, axis=-1):
    """Symmetric per-slice int8 quantisation.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def int8_allreduce(x, axis_name: str):
    """All-reduce with int8-compressed payloads (use inside shard_map).

    Reduce-scatter in int8 (via all_to_all), dequantised local sum, then an
    int8 all-gather — ~4x less wire traffic than a bf16 ring all-reduce on the
    slow inter-pod links.  Mean (not sum) semantics are NOT applied; caller
    divides if needed.  x: any float array with leading dim divisible by the
    axis size.
    """
    n = jax.lax.psum(1, axis_name)
    orig_shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, -1)
    q, scale = int8_quantize(chunks, axis=-1)
    # reduce-scatter: each member receives its chunk from everyone
    q_t = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)
    s_t = jax.lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0,
                             tiled=False)
    local_sum = jnp.sum(int8_dequantize(q_t, s_t), axis=0)  # (chunk,)
    # second compression stage for the gather
    q2, s2 = int8_quantize(local_sum[None], axis=-1)
    q_all = jax.lax.all_gather(q2[0], axis_name)  # (n, chunk)
    s_all = jax.lax.all_gather(s2[0], axis_name)  # (n, 1)
    out = int8_dequantize(q_all, s_all)
    out = out.reshape(-1)[: int(np_prod(orig_shape))]
    return out.reshape(orig_shape).astype(x.dtype)


def np_prod(shape):
    out = 1
    for s in shape:
        out *= s
    return out
