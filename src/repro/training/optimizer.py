"""Optimizers (pure-jax pytree implementations): AdamW and Adafactor.

Adafactor keeps factored second moments (row/col means) for matrices — the
memory knob for the largest assigned archs (DESIGN.md §6).  Both optimizers
keep state in f32 regardless of param dtype and share the same interface:

    opt = make_optimizer(name, lr=..., ...)
    state = opt.init(params)
    params, state = opt.update(params, grads, state, step)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    name: str


def _map_flat(fn, ref_tree, *trees):
    """Map ``fn`` over leaves of ``ref_tree`` with parallel trees whose
    per-leaf entries may themselves be pytrees (e.g. adafactor stats)."""
    flat, treedef = jax.tree_util.tree_flatten(ref_tree)
    others = [treedef.flatten_up_to(t) for t in trees]
    results = [fn(*args) for args in zip(flat, *others)]
    n_out = len(results[0])
    return tuple(treedef.unflatten([r[i] for r in results])
                 for i in range(n_out))


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.float32(0.0)
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def make_adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.95,
               eps: float = 1e-8, weight_decay: float = 0.0,
               grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(params, grads, state, step):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * jnp.square(g)
            step_ = lr * ((m / bc1) / (jnp.sqrt(v / bc2) + eps)
                          + weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        params, m, v = _map_flat(upd, params, grads, state["m"], state["v"])
        return params, {"m": m, "v": v}

    return Optimizer(init=init, update=update, name="adamw")


def make_adafactor(lr: float = 1e-4, decay: float = 0.8, eps: float = 1e-30,
                   clip_threshold: float = 1.0,
                   weight_decay: float = 0.0) -> Optimizer:
    """Factored Adafactor (no momentum) — O(rows+cols) second-moment state."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"stats": jax.tree.map(one, params)}

    def update(params, grads, state, step):
        t = step.astype(jnp.float32) + 1.0
        rho = 1.0 - t ** (-decay)

        def one(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = rho * s["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * s["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                prec = (vr / denom)[..., None] * vc[..., None, :]
                upd = g * jax.lax.rsqrt(jnp.maximum(prec, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = rho * s["v"] + (1 - rho) * g2
                upd = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * (
                upd + weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_s

        params, stats = _map_flat(one, params, grads, state["stats"])
        return params, {"stats": stats}

    return Optimizer(init=init, update=update, name="adafactor")


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return make_adamw(**kw)
    if name == "adafactor":
        return make_adafactor(**kw)
    raise ValueError(f"unknown optimizer {name}")
