from repro.training.optimizer import make_adafactor, make_adamw, make_optimizer
from repro.training.train_step import (
    TrainHParams,
    init_train_state,
    int8_allreduce,
    make_optimizer_for,
    make_train_step,
)
from repro.training import checkpoint

__all__ = [
    "TrainHParams",
    "checkpoint",
    "init_train_state",
    "int8_allreduce",
    "make_adafactor",
    "make_adamw",
    "make_optimizer",
    "make_optimizer_for",
    "make_train_step",
]
